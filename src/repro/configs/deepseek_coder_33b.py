"""deepseek-coder-33b — dense llama-arch, 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256.  [arXiv:2401.14196; hf]"""
from . import register
from .base import ArchConfig


@register
def deepseek_coder_33b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-coder-33b",
        family="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv=8,
        d_ff=19200,
        vocab=32256,
        rope="full",
        act="swiglu",
        fsdp_train=True,   # 33B does not fit unsharded per-chip at TP=16
        source="arXiv:2401.14196; hf:deepseek-ai/deepseek-coder-33b-base",
    )
