"""deepseek-moe-16b — fine-grained MoE, 28L d_model=2048 16H (kv=16)
d_ff_expert=1408 vocab=102400; 2 shared + 64 routed top-6; first layer
dense.  [arXiv:2401.06066; hf]"""
from . import register
from .base import ArchConfig, MoEConfig


@register
def deepseek_moe_16b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv=16,
        d_ff=10944,                 # dense first-layer FFN width
        vocab=102400,
        rope="full",
        act="swiglu",
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                      capacity_factor=1.25, first_layer_dense=True),
        fsdp_train=True,   # 10 GiB/chip of AdamW state at TP-only sharding
        source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
    )
