"""whisper-tiny — encoder-decoder audio transformer backbone, 4L (enc+dec)
d_model=384 6H d_ff=1536 vocab=51865; conv frontend STUBBED (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from . import register
from .base import ArchConfig


@register
def whisper_tiny() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,          # decoder layers
        enc_layers=4,        # encoder layers
        d_model=384,
        n_heads=6,
        n_kv=6,
        d_ff=1536,
        vocab=51865,
        rope="none",         # whisper uses learned/sinusoidal abs positions
        act="gelu",
        tie_embeddings=True,
        seq_parallel=False,  # d_model=384: TP=16 gives 24-wide shards; no SP
        source="arXiv:2212.04356; hf:openai/whisper-tiny (unverified)",
    )
