"""grok-1-314b — MoE, 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072; 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from . import register
from .base import ArchConfig, MoEConfig


@register
def grok1_314b() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        d_ff=32768,
        vocab=131072,
        rope="full",
        act="gelu",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768, n_shared=0,
                      capacity_factor=1.25),
        fsdp_train=True,   # 314B params require ZeRO-3 over data axis
        fsdp_serve=True,   # 628 GB of bf16 weights > 16 pod-row HBMs: gather per layer
        source="hf:xai-org/grok-1 (unverified)",
    )
