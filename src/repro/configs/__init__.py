"""Architecture config registry.  ``get_config(arch_id)`` returns the exact
published config; ``get_smoke_config(arch_id)`` a reduced same-family config
for CPU smoke tests."""
from .base import SHAPES, ArchConfig, MoEConfig, ShapeConfig, SSMConfig

_REGISTRY = {}


def register(cfg_fn):
    import functools

    cfg = cfg_fn()
    _REGISTRY[cfg.name] = cfg_fn
    return cfg_fn


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ArchConfig:
    return get_config(name).smoke()


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from . import (chatglm3_6b, deepseek_coder_33b, smollm_135m,  # noqa
                   minitron_8b, deepseek_moe_16b, grok1_314b, mamba2_2p7b,
                   whisper_tiny, qwen2_vl_7b, zamba2_1p2b)


_ensure_loaded_on_import = False
