"""Architecture + shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int          # routed experts
    top_k: int
    d_ff_expert: int        # per-expert FFN width
    n_shared: int = 0       # shared experts (always-on)
    capacity_factor: float = 1.25
    first_layer_dense: bool = False   # deepseek-moe style


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 128        # N, SSM state size
    head_dim: int = 64      # P
    expand: int = 2         # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128        # SSD chunk length
    n_groups: int = 1
    attn_every: int = 0     # hybrid: shared attn block every k ssm layers


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str             # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None     # default d_model // n_heads
    rope: str = "full"                 # full | partial2d | mrope | none
    rope_kw: tuple = ()                # frozen kv pairs
    act: str = "swiglu"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    tie_embeddings: bool = False
    seq_parallel: bool = True          # SP for train/prefill sections
    fsdp_train: bool = False           # ZeRO-3 sharding for train
    fsdp_serve: bool = False           # ZeRO-3 weight sharding for serving
    enc_layers: int = 0                # encdec: encoder layer count
    source: str = ""
    subquadratic: bool = False         # supports long_500k decode

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def rope_kwargs(self) -> dict:
        return dict(self.rope_kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        moe = None
        if self.moe:
            moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                            n_shared=min(self.moe.n_shared, 1),
                            capacity_factor=2.0,
                            first_layer_dense=self.moe.first_layer_dense)
        ssm = None
        if self.ssm:
            ssm = SSMConfig(state=16, head_dim=8, expand=2, conv_width=4,
                            chunk=8,
                            attn_every=2 if self.ssm.attn_every else 0)
        rope_kw = self.rope_kw
        if self.rope == "mrope":
            rope_kw = (("sections", (2, 1, 1)),)   # sums to head_dim//2 = 4
        return dataclasses.replace(
            self, name=self.name + "-smoke",
            n_layers=2 if not self.ssm else 4,
            d_model=32, n_heads=4, n_kv=min(self.n_kv, 2), d_ff=64,
            vocab=128, head_dim=8, moe=moe, ssm=ssm, rope_kw=rope_kw,
            enc_layers=min(self.enc_layers, 2), fsdp_train=False)

    # -- parameter count (for MODEL_FLOPS = 6 N D roofline term) -----------
    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts (embedding included)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "hybrid"):
            s = self.ssm
            d_in = s.expand * d
            conv_ch = d_in + 2 * s.n_groups * s.state
            nheads = d_in // s.head_dim
            per = (d * (2 * d_in + 2 * s.n_groups * s.state + nheads)  # in_proj
                   + conv_ch * s.conv_width
                   + d_in * d                                          # out_proj
                   + 2 * nheads + d)                                   # A, D, norm
            tot = L * per + emb
            if s.attn_every:
                attn_blk = (2 * d) * d * 4 + (2 * d) * self.d_ff * 3 + 2 * d
                tot += attn_blk  # shared (reused) block counted once
            return tot, tot
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv * hd) \
            + (self.n_heads * hd) * d
        if self.moe:
            m = self.moe
            expert = 3 * d * m.d_ff_expert
            shared = 3 * d * (m.d_ff_expert * m.n_shared)
            router = d * m.n_experts
            per_total = attn + m.n_experts * expert + shared + router + 2 * d
            per_active = attn + m.top_k * expert + shared + router + 2 * d
            n_moe = L - (1 if m.first_layer_dense else 0)
            n_dense = L - n_moe
            dense_l = attn + 3 * d * self.d_ff + 2 * d if n_dense else 0
            return (n_moe * per_total + n_dense * dense_l + emb,
                    n_moe * per_active + n_dense * dense_l + emb)
        ff_mult = 3 if self.act == "swiglu" else 2
        per = attn + ff_mult * d * self.d_ff + 2 * d
        tot = (L + self.enc_layers) * per + emb
        if self.enc_layers:  # cross-attn adds another attn block per dec layer
            tot += L * attn
        return tot, tot


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
