"""mamba2-2.7b — attention-free SSM (SSD), 64L d_model=2560 vocab=50280,
ssm_state=128.  [arXiv:2405.21060; unverified]"""
from . import register
from .base import ArchConfig, SSMConfig


@register
def mamba2_2p7b() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,          # attention-free
        n_kv=0,
        d_ff=0,
        vocab=50280,
        head_dim=64,        # SSM head dim P
        rope="none",
        ssm=SSMConfig(state=128, head_dim=64, expand=2, conv_width=4,
                      chunk=128, n_groups=1),
        tie_embeddings=True,
        seq_parallel=False,
        subquadratic=True,   # O(1)-state decode => long_500k runs
        source="arXiv:2405.21060; hf:state-spaces/mamba2-2.7b (unverified)",
    )
