"""smollm-135m — small dense llama-arch, 30L d_model=576 9H (GQA kv=3)
d_ff=1536 vocab=49152.  [hf:HuggingFaceTB/SmolLM-135M]"""
from . import register
from .base import ArchConfig


@register
def smollm_135m() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv=3,
        d_ff=1536,
        vocab=49152,
        head_dim=64,
        rope="full",
        act="swiglu",
        tie_embeddings=True,
        seq_parallel=False,   # d_model=576 not divisible by TP*... keep simple
        source="hf:HuggingFaceTB/SmolLM-135M",
    )
