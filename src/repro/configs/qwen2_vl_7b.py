"""qwen2-vl-7b — VLM; transformer BACKBONE only (ViT frontend stubbed:
input_specs provides precomputed patch embeddings).  28L d_model=3584 28H
(GQA kv=4) d_ff=18944 vocab=152064; M-RoPE.  [arXiv:2409.12191; hf]"""
from . import register
from .base import ArchConfig


@register
def qwen2_vl_7b() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv=4,
        d_ff=18944,
        vocab=152064,
        rope="mrope",
        rope_kw=(("sections", (16, 24, 24)),),
        act="swiglu",
        fsdp_train=True,   # 7.6B: AdamW state > HBM at TP-only sharding
        source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B-Instruct",
    )
