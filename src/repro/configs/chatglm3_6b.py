"""chatglm3-6b — dense, 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, RoPE 2d (partial rotation), GQA.  [arXiv:2406.12793; hf]"""
from . import register
from .base import ArchConfig


@register
def chatglm3_6b() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv=2,
        d_ff=13696,
        vocab=65024,
        rope="partial2d",
        rope_kw=(("fraction", 0.5),),
        act="swiglu",
        fsdp_train=True,   # AdamW state > HBM at TP-only sharding
        source="arXiv:2406.12793; hf:THUDM/chatglm3-6b",
    )
