"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block, 38L
d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242; hf]"""
from . import register
from .base import ArchConfig, SSMConfig


@register
def zamba2_1p2b() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv=32,
        d_ff=8192,
        vocab=32000,
        head_dim=128,        # shared attn block runs at 2*d_model = 4096
        rope="full",
        ssm=SSMConfig(state=64, head_dim=64, expand=2, conv_width=4,
                      chunk=128, n_groups=1, attn_every=6),
        tie_embeddings=True,
        seq_parallel=False,
        subquadratic=True,   # SSM backbone; shared-attn KV grows but state O(1)
        source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
    )
