"""minitron-8b — pruned nemotron dense, 32L d_model=4096 32H (GQA kv=8)
d_ff=16384 vocab=256000.  [arXiv:2407.14679; hf]"""
from . import register
from .base import ArchConfig


@register
def minitron_8b() -> ArchConfig:
    return ArchConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv=8,
        d_ff=16384,
        vocab=256000,
        rope="full",
        act="swiglu",   # published uses squared-relu; swiglu width matches d_ff
        fsdp_train=True,   # 8B + 256k vocab: AdamW state > HBM at TP-only
        source="arXiv:2407.14679; hf:nvidia/Minitron-8B-Base",
    )
