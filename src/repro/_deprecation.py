"""Warn-once plumbing for the pre-facade entry points.

The PR-5 frontend redesign keeps every old builder working (they are thin
shims over the same machinery the ``repro.api`` facade routes through)
but each one announces its replacement exactly once per process, so a
long-running trainer or server is not spammed per step rebuild.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()


def warn_once(name: str, replacement: str, stacklevel: int = 3) -> None:
    """Emit one ``DeprecationWarning`` per process for ``name``."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} instead",
        DeprecationWarning, stacklevel=stacklevel)


def reset() -> None:
    """Forget which warnings already fired (test isolation only)."""
    _WARNED.clear()
