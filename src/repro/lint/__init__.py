"""``python -m repro.lint`` — the schedule lint CLI.

Runs the static plan verifier + linter (``core.verify``) over every
registered strategy for an architecture's segment graphs, one row per
(strategy, phase, segment), and prints a diagnostic table.  The CI
``verify-gate`` job runs this across the arch families and fails on any
error-severity diagnostic::

    python -m repro.lint transformer                    # all strategies
    python -m repro.lint moe --strategy nanoflow        # one strategy
    python -m repro.lint mamba2-2.7b --phase decode --show-clean

Family aliases map to smoke configs (``transformer`` -> smollm-135m,
``moe`` -> deepseek-moe-16b, ``mamba2`` -> mamba2-2.7b); any registered
arch name works directly.  A strategy that crashes during recording is
reported as a diagnostic row too (code = the exception class), never a
CLI crash — the entire point is surveying all of them.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..core.partition import partition
from ..core.scheduler import ScheduleContext, record_plan
from ..core.strategies import registry
from ..core.verify import (Diagnostic, VerifyReport, lint_table, verify)

#: family alias -> registered arch name (smoke configs keep this fast)
ARCH_ALIASES = {
    "transformer": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mamba2": "mamba2-2.7b",
}

PHASES = ("train", "prefill", "decode")


def resolve_arch(name: str) -> str:
    return ARCH_ALIASES.get(name, name)


def _phase_shapes(phase: str, batch: int, seq: int):
    """(B, S, s_max) per phase — decode is single-token with a short
    KV horizon; the verifier only needs representative shapes."""
    if phase == "decode":
        return batch, 1, max(seq, 16)
    return batch, seq, seq


def lint_arch(arch: str, strategies: Optional[Sequence[str]] = None,
              phases: Sequence[str] = PHASES, batch: int = 4,
              seq: int = 16, lint: bool = True) -> list:
    """Verify every (strategy × phase × segment) plan for ``arch``.

    Returns ``[(label, VerifyReport), ...]`` with labels of the form
    ``"arch/strategy/phase/segment"``.  Recording failures become a
    single-diagnostic report (severity error, code = exception class) so
    one broken strategy cannot hide the rest of the table.
    """
    from ..configs import get_smoke_config
    from ..models.layers import MeshInfo
    from ..models.registry import build_model

    arch = resolve_arch(arch)
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    names = list(strategies) if strategies else [
        n for n in registry.strategy_names()
        if registry.get_entry(n).tunable or n == "sequential"]
    rows = []
    for phase in phases:
        B, S, s_max = _phase_shapes(phase, batch, seq)
        segs, _ = model.build_segments(phase, B, S, s_max=s_max)
        info = ScheduleContext(local_batch=B, global_batch=B, seq_len=S,
                               phase=phase, arch=cfg.name)
        for name in names:
            for seg in segs:
                label = f"{arch}/{name}/{phase}/{seg.key}"
                try:
                    sched = registry.make_scheduler(name)
                    g = partition(seg.graph, sched.partition_rules())
                    plan = record_plan(g, sched, info)
                except Exception as e:                  # noqa: BLE001
                    rows.append((label, VerifyReport((Diagnostic(
                        "error", type(e).__name__, -1, (),
                        f"recording failed: {str(e)[:200]}",
                        "fix the strategy's schedule()"),))))
                    continue
                rows.append((label, verify(g, plan, lint=lint)))
    return rows


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Static plan verification & lint across registered "
                    "strategies (see repro.core.verify.CODES)")
    p.add_argument("arch", help="arch name or family alias "
                   f"({', '.join(sorted(ARCH_ALIASES))})")
    p.add_argument("--strategy", action="append", default=None,
                   help="limit to this strategy (repeatable; default: "
                   "all tunable strategies + sequential)")
    p.add_argument("--phase", action="append", default=None,
                   choices=PHASES, help="limit to this phase (repeatable)")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=16)
    p.add_argument("--no-lint", action="store_true",
                   help="errors only; skip warning-severity smells")
    p.add_argument("--show-clean", action="store_true",
                   help="also print rows with no diagnostics")
    p.add_argument("--codes", action="store_true",
                   help="print the diagnostic code table and exit")
    args = p.parse_args(argv)
    if args.codes:
        from ..core.verify import CODES
        for code, (sev, desc) in sorted(CODES.items()):
            print(f"{code}  {sev:<8} {desc}")
        return 0
    rows = lint_arch(args.arch, strategies=args.strategy,
                     phases=tuple(args.phase or PHASES),
                     batch=args.batch, seq=args.seq,
                     lint=not args.no_lint)
    print(lint_table(rows, include_clean=args.show_clean))
    n_err = sum(len(r.errors) for _, r in rows)
    n_warn = sum(len(r.warnings) for _, r in rows)
    print(f"\n{len(rows)} plan(s) checked: {n_err} error(s), "
          f"{n_warn} warning(s)")
    return 1 if n_err else 0
