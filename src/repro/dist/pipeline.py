"""Inter-stage pipeline driver (GPipe-style fill/drain over a mesh axis).

``pipeline_apply`` runs one stage function per device along ``axis``:
microbatch ``j`` visits stage ``i`` at tick ``i + j``; activations move to
the next stage over a ring ``ppermute`` each tick (XLA overlaps the send
with the next tick's compute).  Each device returns its local buffer of
stage outputs — the *last* stage's buffer holds the fully-processed
microbatches.  Stage functions must be shape-preserving (uniform
activation shape between stages), the usual pipeline contract.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from . import collectives as col


def pipeline_apply(fn, stage_params, mbs, axis: str = "pod"):
    """Apply ``fn(stage_params, mb)`` pipelined over mesh axis ``axis``.

    ``mbs`` is a stacked ``(n_mb, ...)`` array of microbatches, replicated
    on every stage; ``stage_params`` are this device's stage weights.
    Returns an ``(n_mb, ...)`` buffer; on stage ``i`` row ``j`` holds
    microbatch ``j`` after stages ``0..i``.
    """
    n_stages = int(col.axis_size(axis))
    n_mb = mbs.shape[0]
    if n_stages == 1:
        return lax.map(lambda mb: fn(stage_params, mb), mbs)
    idx = col.axis_index(axis)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    outs = jnp.zeros_like(mbs)
    state = jnp.zeros_like(mbs[0])

    def tick(t, carry):
        state, outs = carry
        # stage 0 feeds fresh microbatches; later stages consume the ring
        mb_i = jnp.clip(t, 0, n_mb - 1)
        x_in = jnp.where(idx == 0,
                         lax.dynamic_index_in_dim(mbs, mb_i, keepdims=False),
                         state)
        y = fn(stage_params, x_in)
        slot = t - idx                      # microbatch this stage just ran
        valid = jnp.logical_and(slot >= 0, slot < n_mb)
        upd = lax.dynamic_update_index_in_dim(
            outs, y, jnp.clip(slot, 0, n_mb - 1), 0)
        outs = jnp.where(valid, upd, outs)
        return col.ppermute(y, axis, perm), outs

    _, outs = lax.fori_loop(0, n_mb + n_stages - 1, tick, (state, outs))
    return outs
