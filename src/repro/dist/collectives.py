"""Axis-optional collectives — no-ops without a mesh, real inside shard_map.

Model code calls these unconditionally; whether they lower to actual
collectives is decided by the axis environment at trace time.  Outside
shard_map (single-device tests, symbolic tracing) a named axis is unbound
and every collective degenerates to its single-participant identity:
``psum`` -> x, ``all_gather`` -> x, ``axis_index`` -> 0, ``axis_size`` -> 1.
This is what keeps the same model source runnable on one chip and on a
512-chip mesh without edits (paper's transparency requirement).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def _bound(axis: str) -> bool:
    """True iff ``axis`` is a live mesh axis in the current trace."""
    try:
        lax.axis_index(axis)
        return True
    except NameError:
        return False


def axis_size(axis: str) -> int:
    if not _bound(axis):
        return 1
    return lax.psum(1, axis)


def axis_index(axis: str):
    if not _bound(axis):
        return jnp.int32(0)
    return lax.axis_index(axis)


def psum(x, axis: str):
    if not _bound(axis):
        return x
    return lax.psum(x, axis)


def pmax(x, axis: str):
    if not _bound(axis):
        return x
    return lax.pmax(x, axis)


def all_gather(x, axis: str, dim: int = 0):
    if not _bound(axis):
        return x
    return lax.all_gather(x, axis, axis=dim, tiled=True)


def reduce_scatter(x, axis: str, dim: int = 0):
    if not _bound(axis):
        return x
    return lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)


def all_to_all(x, axis: str, split_dim: int, concat_dim: int):
    if not _bound(axis):
        return x
    return lax.all_to_all(x, axis, split_axis=split_dim,
                          concat_axis=concat_dim, tiled=True)


def ppermute(x, axis: str, perm):
    if not _bound(axis):
        return x
    return lax.ppermute(x, axis, perm)


def compressed_psum(x, axis: str, err: Optional[jax.Array] = None):
    """int8 block-quantized psum with error feedback.

    The quantization residual is carried in ``err`` and re-injected next
    step, so the *accumulated* compressed sum is unbiased (the standard
    EF-SGD guarantee).  Scales are pmax'd across the axis so every
    participant dequantizes identically.  Returns ``(reduced, new_err)``.
    """
    val = x if err is None else x + err
    f32 = val.astype(jnp.float32)
    scale = pmax(jnp.max(jnp.abs(f32)), axis) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(f32 / scale), -127, 127).astype(jnp.int8)
    deq_local = q.astype(jnp.float32) * scale
    new_err = (f32 - deq_local).astype(x.dtype)
    reduced = psum(q.astype(jnp.int32), axis).astype(jnp.float32) * scale
    return reduced.astype(x.dtype), new_err
