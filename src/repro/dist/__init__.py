"""Distributed substrate: mesh-aware collectives + pipeline driver.

``collectives`` are the only collective entry points model code uses:
no-ops outside a mesh (single-device tests, ``jax.eval_shape`` tracing),
real ``lax`` collectives when the named axis is bound inside shard_map.
"""
from . import collectives, pipeline

__all__ = ["collectives", "pipeline"]
