"""Fault-tolerant training loop.

Host-side responsibilities: data cursor, checkpoint cadence (async),
straggler deadline with retry, crash-restart (restores params/opt/data
cursor from the latest atomic checkpoint), metrics log.  The jitted step
itself is built by ``train/step.py`` and passed in — the loop never
touches model internals.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from ..ft.checkpoint import CheckpointManager
from ..ft.elastic import FailureSimulator


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    step_deadline_s: float = 0.0       # 0 = no straggler deadline
    max_retries: int = 2


def train_loop(train_step: Callable, params, opt_state, pipeline,
               cfg: TrainLoopConfig,
               failure_sim: Optional[FailureSimulator] = None,
               to_device: Optional[Callable] = None,
               log: Optional[Callable] = None):
    """Run ``cfg.steps`` optimizer steps.  Returns (params, opt, history).

    Crash-restart contract: on any step exception the loop restores the
    last checkpoint (params, opt, data cursor) and retries the step; after
    ``max_retries`` consecutive failures it re-raises (a real deployment
    would fall back to the cluster scheduler).
    """
    mgr = CheckpointManager(cfg.ckpt_dir) if cfg.ckpt_dir else None
    history = []
    start = 0
    if mgr is not None:
        restored = mgr.restore({"params": params, "opt": opt_state})
        if restored is not None:
            start, tree, data_state = restored
            params, opt_state = tree["params"], tree["opt"]
            if data_state:
                pipeline.load_state_dict(data_state)
            if log:
                log(f"restored checkpoint at step {start}")
    pipeline.seek(start)
    it = iter(pipeline)
    step = start
    retries = 0
    first_step = True       # first step pays trace+compile, not a straggler
    while step < cfg.steps:
        batch = next(it)
        if to_device:
            batch = to_device(batch)
        t0 = time.perf_counter()
        try:
            if failure_sim:
                failure_sim.maybe_fail(step)
            params, opt_state, metrics = train_step(
                params, opt_state, batch, jnp.int32(step))
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
        except Exception:
            retries += 1
            if retries > cfg.max_retries or mgr is None:
                raise
            restored = mgr.restore({"params": params, "opt": opt_state})
            if restored is not None:
                step, tree, data_state = restored
                params, opt_state = tree["params"], tree["opt"]
                if data_state:
                    pipeline.load_state_dict(data_state)
            pipeline.seek(step)
            it = iter(pipeline)
            if log:
                log(f"step failed; restarted from checkpoint at {step}")
            continue
        dt = time.perf_counter() - t0
        if cfg.step_deadline_s and dt > cfg.step_deadline_s \
                and not first_step:
            if log:
                log(f"straggler: step {step} took {dt:.3f}s "
                    f"(deadline {cfg.step_deadline_s:.3f}s)")
            metrics["straggler"] = 1.0
        first_step = False
        retries = 0
        metrics.update(step=step, step_time_s=dt)
        history.append(metrics)
        if log and step % cfg.log_every == 0:
            log(f"step {step}: loss={metrics['loss']:.4f} "
                f"({dt*1e3:.0f} ms)")
        step += 1
        if mgr is not None and step % cfg.ckpt_every == 0:
            mgr.save_async(step, {"params": params, "opt": opt_state},
                           data_state=pipeline.state_dict())
    if mgr is not None:
        mgr.save(cfg.steps, {"params": params, "opt": opt_state},
                 data_state=pipeline.state_dict())
    return params, opt_state, history
