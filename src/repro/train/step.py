"""Train-step builder: DynaFlow forward -> loss -> grads -> AdamW.

The step function is pure and shard_map-friendly: all collectives go
through ``repro.dist.collectives`` (no-ops without a mesh, real
collectives inside shard_map).  Gradient reduction rules:

  * grads are partial over the data axes (different samples) -> psum over
    ('pod','data') — optionally int8-compressed with error feedback;
  * under sequence-parallel training, grads of params *replicated* over
    'model' (norm gains, routers, shared experts) are partial over the
    sequence shards -> additional psum over 'model';
  * params sharded over 'data' (FSDP WeightGather) skip the data psum:
    the all-gather's AD transpose already reduce-scatters them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .._deprecation import warn_once
from ..core.plan_store import checkpoint_plan_store, resolve_plan_store
from ..core.scheduler import ScheduleContext
from ..dist import collectives as col
from ..models.base import build_forward
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..optim.schedules import cosine_schedule


@dataclasses.dataclass
class TrainStepConfig:
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    remat: bool = True
    remat_policy: str = "full"     # full | dots
    grad_accum: int = 1
    compress_grads: bool = False     # int8 DP all-reduce + error feedback
    warmup: int = 100
    total_steps: int = 10000
    lowered: bool = True             # slot-based lowered plan replay


def _flat_axes(pspec) -> set:
    out = set()
    for entry in pspec:
        if isinstance(entry, str):
            out.add(entry)
        elif entry:
            out.update(entry)
    return out


def reduce_grads(grads, pspecs, mesh_info, sp_train: bool,
                 compress: bool = False, errors=None):
    """Apply the reduction rules above.  Returns (grads, new_errors)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_s = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_g) == len(flat_s), (len(flat_g), len(flat_s))
    flat_e = (jax.tree_util.tree_leaves(errors) if errors is not None
              else [None] * len(flat_g))
    outs, new_errs = [], []
    for g, spec, err in zip(flat_g, flat_s, flat_e):
        axes = _flat_axes(spec)
        red = g
        new_err = err
        for ax in mesh_info.dp_axes:
            if ax in axes:
                continue  # FSDP leaf: already reduce-scattered on this axis
            if compress and ax == "data":
                red, new_err = col.compressed_psum(red, ax, err)
            else:
                red = col.psum(red, ax)
        if sp_train and "model" not in axes:
            red = col.psum(red, "model")
        outs.append(red)
        new_errs.append(new_err if new_err is not None
                        else jnp.zeros_like(g))
    return (jax.tree_util.tree_unflatten(tdef, outs),
            jax.tree_util.tree_unflatten(tdef, new_errs))


def global_grad_norm(grads, pspecs, mesh_info):
    """Global ||g||² under SPMD: per-leaf local sum-of-squares, psum'd over
    the axes the leaf is *sharded* on (replicated leaves count once) —
    every chip gets the identical norm, so clipping stays consistent."""
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_s = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, tuple))
    by_axes: dict = {}
    for g, spec in zip(flat_g, flat_s):
        # sum sq over exactly the mesh axes this (post-reduction) grad leaf
        # is sharded on; replicated leaves count once
        axes = tuple(sorted(_flat_axes(spec) & {"data", "model"}))
        by_axes[axes] = by_axes.get(axes, 0.0) + jnp.sum(
            g.astype(jnp.float32) ** 2)
    total = 0.0
    for axes, sq in by_axes.items():
        for ax in axes:
            sq = col.psum(sq, ax)
        total = total + sq
    return jnp.sqrt(total)


def build_train_step(model, scheduler, B_loc: int, S: int,
                     cfg: TrainStepConfig,
                     info: Optional[ScheduleContext] = None,
                     plan_store=None, plan_store_path: Optional[str] = None):
    """Deprecated pre-facade entry point — build the Program instead:
    ``repro.api.compile(model, policy=...).train_step(...)``."""
    warn_once("repro.train.build_train_step",
              "repro.api.compile(...).train_step(...)")
    return _build_train_step(model, scheduler, B_loc, S, cfg, info,
                             plan_store=plan_store,
                             plan_store_path=plan_store_path)


def _build_train_step(model, scheduler, B_loc: int, S: int,
                      cfg: TrainStepConfig,
                      info: Optional[ScheduleContext] = None,
                      plan_store=None,
                      plan_store_path: Optional[str] = None,
                      verify: str = "off",
                      verify_sink: Optional[list] = None):
    """Returns (train_step, segments, binputs, init_opt).

    ``scheduler`` may be an ``OpSchedulerBase`` or a ``StrategyPolicy``
    (``build_forward`` resolves policies per segment context).

    ``train_step(params, opt_state, batch, step) ->
        (params, opt_state, metrics)``.

    ``plan_store``: optional shared ``PlanStore`` so rebuilding the step
    (new seq-len bucket, restart after preemption) specializes the
    already-lowered segment plans instead of re-running analysis+lowering.
    ``plan_store_path``: persist that store on disk — a relaunched
    trainer restores the canonical lowerings and rebuilds its step
    without a single ``lower`` call (the store is checkpointed right
    after the forward is built).
    """
    plan_store = resolve_plan_store(plan_store, plan_store_path)
    segs, binputs = model.build_segments("train", B_loc, S)
    info = info or ScheduleContext(
        local_batch=B_loc, global_batch=B_loc, seq_len=S, phase="train",
        arch=model.cfg.name)
    fwd = build_forward(segs, scheduler, info, remat=cfg.remat,
                        remat_policy=cfg.remat_policy, lowered=cfg.lowered,
                        plan_cache=plan_store,
                        op_config=model.op_closure_config(),
                        verify=verify, verify_sink=verify_sink)
    checkpoint_plan_store(plan_store)
    pspecs = model.param_pspecs(segs)
    sp_train = bool(getattr(model.cfg, "seq_parallel", False))
    mesh_info = model.mesh

    def loss_fn(params, batch):
        out = fwd(params, batch)
        local_sum = jnp.sum(out["loss_sum"])
        local_cnt = jnp.sum(out["token_count"])
        total_cnt = local_cnt
        for ax in mesh_info.dp_axes:
            total_cnt = col.psum(total_cnt, ax)
        total_cnt = jax.lax.stop_gradient(jnp.maximum(total_cnt, 1.0))
        return local_sum / total_cnt, (local_sum, local_cnt)

    def one_batch_grads(params, batch):
        (_, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, aux

    def train_step(params, opt_state, batch, step):
        if cfg.grad_accum > 1:
            # micro-batch scan over a leading accum dim of the batch
            def body(acc, mb):
                g, aux = one_batch_grads(params, mb)
                return (jax.tree_util.tree_map(jnp.add, acc[0], g),
                        (acc[1][0] + aux[0], acc[1][1] + aux[1])), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, aux), _ = jax.lax.scan(
                body, (zeros, (jnp.zeros(()), jnp.zeros(()))), batch)
        else:
            grads, aux = one_batch_grads(params, batch)
        errors = opt_state.get("grad_errors") if cfg.compress_grads else None
        grads, new_errors = reduce_grads(
            grads, pspecs, mesh_info, sp_train,
            compress=cfg.compress_grads, errors=errors)
        lr = cosine_schedule(step, cfg.warmup, cfg.total_steps,
                             cfg.optimizer.lr)
        gnorm = global_grad_norm(grads, pspecs, mesh_info)
        new_params, new_opt, gnorm = adamw_update(
            params, grads, opt_state, cfg.optimizer, lr=lr, gnorm=gnorm)
        if cfg.compress_grads:
            new_opt["grad_errors"] = new_errors
        loss_sum, cnt = aux
        for ax in mesh_info.dp_axes:
            loss_sum = col.psum(loss_sum, ax)
            cnt = col.psum(cnt, ax)
        metrics = {"loss": loss_sum / jnp.maximum(cnt, 1.0),
                   "grad_norm": gnorm, "lr": lr,
                   "tokens": cnt}
        return new_params, new_opt, metrics

    def init_opt(params):
        opt = adamw_init(params, cfg.optimizer)
        if cfg.compress_grads:
            opt["grad_errors"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return opt

    return train_step, segs, binputs, init_opt
