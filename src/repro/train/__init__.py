from .step import TrainStepConfig, build_train_step, reduce_grads
from .loop import TrainLoopConfig, train_loop
