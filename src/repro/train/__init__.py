from .loop import TrainLoopConfig, train_loop
from .step import TrainStepConfig, build_train_step, reduce_grads
