"""Elastic scaling + failure handling.

Checkpoints store *global* (host) arrays, so restoring onto a different
mesh is a pure re-sharding problem: ``elastic_restore`` loads the tree and
``jax.device_put``s it under the new mesh's shardings.  Combined with the
seekable data pipeline, a job can restart on N-k pods with bit-identical
sample order.

``FailureSimulator`` injects the failure modes the train loop must
survive (used by tests and the ft example):
  * ``crash``     — raises mid-step (process dies, restart from ckpt)
  * ``straggler`` — delays the step past the deadline (loop re-dispatches)

It is a thin specialization of the shared chaos injector
(``repro.serve.faults.FaultInjector``), so serve and train exercise one
deterministic fault mechanism with one ``injected`` event log.
"""
from __future__ import annotations

import time

import jax

from ..serve.faults import FaultInjector
from .checkpoint import restore_latest


def elastic_restore(directory: str, example_tree,
                    shardings=None, process_index: int = 0):
    """Load the latest checkpoint and (optionally) re-shard onto a new
    mesh.  Returns (step, tree, data_state) or None."""
    out = restore_latest(directory, example_tree, process_index)
    if out is None:
        return None
    step, tree, data_state = out
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return step, tree, data_state


class FailureSimulator(FaultInjector):
    """Train-loop view of the shared injector: ``maybe_fail(step)`` is
    the single site the loop consults (a step either crashes once, or
    straggles once)."""

    def __init__(self, crash_steps=(), straggle_steps=(),
                 straggle_s: float = 0.5, seed: int = 0):
        super().__init__(slow_s=straggle_s, seed=seed)
        self.crash_steps = set(crash_steps)
        self.straggle_steps = set(straggle_steps)
        self.straggle_s = straggle_s

    def maybe_fail(self, step: int):
        if step in self.crash_steps:
            self.crash_steps.discard(step)     # fail once, then recover
            self.injected.append(("crash", step))
            raise RuntimeError(f"simulated node failure at step {step}")
        if step in self.straggle_steps:
            self.straggle_steps.discard(step)
            self.injected.append(("straggler", step))
            time.sleep(self.straggle_s)
