"""Sharded, atomic, async checkpointing.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json          # step, tree structure, shard index, mesh
        shard_00000.npz        # this process's param/opt leaves
        data_state.json        # pipeline cursor
    <dir>/LATEST               # atomic pointer file

Atomicity: write into ``step_N.tmp/``, fsync, then ``os.replace`` the
directory name and rewrite LATEST.  A crash mid-save leaves only a .tmp
directory that restore ignores.  Async: ``save_async`` snapshots arrays
to host memory synchronously (cheap) and writes in a daemon thread so the
train loop never blocks on storage.

Multi-host: every process writes shards it owns (addressable shards);
here n_proc == 1, but the manifest/shard-index format is per-process so
the same code scales out.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def _encode(v):
    """npz-safe encoding; bfloat16 round-trips via a uint16 view."""
    a = np.asarray(v)
    if a.dtype == jnp.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode(a, dtype: str):
    if dtype == "bfloat16":
        return a.view(jnp.bfloat16)
    return a


def save_checkpoint(directory: str, step: int, tree: Any,
                    data_state: Optional[dict] = None,
                    process_index: int = 0, meta: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.isdir(final):
        return final            # this step is already durably saved
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, vals, _ = _flatten(tree)
    enc = [_encode(v) for v in vals]
    arrays = {f"a{i}": a for i, (a, _) in enumerate(enc)}
    np.savez(os.path.join(tmp, f"shard_{process_index:05d}.npz"), **arrays)
    manifest = {"step": step, "keys": keys, "n_processes": 1,
                "dtypes": [d for _, d in enc], "meta": meta or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if data_state is not None:
        with open(os.path.join(tmp, "data_state.json"), "w") as f:
            json.dump(data_state, f)
    os.replace(tmp, final)                      # atomic publish
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


def restore_latest(directory: str, example_tree: Any,
                   process_index: int = 0):
    """Returns (step, tree, data_state) or None when no checkpoint."""
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.isdir(path):                  # stale pointer
        steps = sorted(d for d in os.listdir(directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            return None
        path = os.path.join(directory, steps[-1])
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shard = np.load(os.path.join(path, f"shard_{process_index:05d}.npz"))
    dtypes = manifest.get("dtypes") or [None] * len(manifest["keys"])
    vals = [_decode(shard[f"a{i}"], dtypes[i])
            for i in range(len(manifest["keys"]))]
    treedef = jax.tree_util.tree_structure(example_tree)
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    data_state = None
    ds = os.path.join(path, "data_state.json")
    if os.path.exists(ds):
        with open(ds) as f:
            data_state = json.load(f)
    return manifest["step"], tree, data_state


class CheckpointManager:
    """Async save + retention.  ``save_async`` returns immediately."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any,
                   data_state: Optional[dict] = None, meta=None):
        self.wait()
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            save_checkpoint(self.directory, step, host, data_state,
                            meta=meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step, tree, data_state=None, meta=None):
        self.wait()   # an in-flight async save may target the same step
        save_checkpoint(self.directory, step, tree, data_state, meta=meta)
        self._gc()

    def restore(self, example_tree):
        self.wait()
        return restore_latest(self.directory, example_tree)

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)
