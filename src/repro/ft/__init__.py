from .checkpoint import (CheckpointManager, restore_latest, save_checkpoint)
from .elastic import elastic_restore, FailureSimulator
