"""``repro.api`` — one entry point from model to scheduled execution.

The paper's claim is *transparent* integration: the logical model is
decoupled from the physical schedule, and strategies plug in with minimal
code change (§3; contrast with Opara's per-model stream capture).  The
backend has delivered that since PR 1-4 (plan IR, unified persistent
PlanStore, tiered serve runtime) — this module makes the *frontend* match
it.  One call::

    program = repro.api.compile("chatglm3-6b", policy=my_policy,
                                plan_store_path="plans.dfps", smoke=True)
    params  = program.init_params(jax.random.PRNGKey(0))
    engine  = program.serve(ServeConfig(max_batch=8))          # serving
    step    = program.train_step(global_batch=8, seq_len=128)  # training

replaces threading ``scheduler`` / ``plan_store`` / ``lowered`` / mesh
info through five separate builders.  The :class:`Program`:

  * owns the **PlanStore lifecycle** — open/warm-start at compile time,
    checkpoint after every build and on ``close()``, one store shared by
    every step the program ever builds (train, prefill buckets, decode
    tiers, serve engine);
  * resolves the **ScheduleContext** from actual inputs (shapes or an
    example batch), so callers never construct one by hand;
  * accepts a **StrategyPolicy** (or bare scheduler, or strategy name)
    whose identity salts every PlanStore outer key — swapping policies
    can never replay the wrong cached plan.

``compile`` also accepts a plain traced ``Module`` or ``OpGraph`` (the
quickstart path): the returned program records/lowers/realizes plans per
shape bucket through the same store.

Old entry points (``build_train_step``, ``build_global_*``) remain as
thin shims that warn once and route through the same machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from .core.backend import Realizer
from .core.graph import OpGraph
from .core.module import Module, trace
from .core.plan import strategy_salt
from .core.plan_store import (PlanStore, checkpoint_plan_store,
                              resolve_plan_store)
from .core.policy import StrategyPolicy, as_policy, resolve_strategy
from .core.scheduler import ScheduleContext, record_plan


PROGRAM_MAGIC = "dynaflow-program"
PROGRAM_FORMAT_VERSION = 1


class ProgramBundleError(ValueError):
    """A ``Program.save`` bundle that cannot be loaded: wrong magic,
    incompatible format/fingerprint versions, or a saved policy that
    cannot be reconstructed without the caller's help."""


def _arch_from_dict(d: dict):
    """Rebuild an ``ArchConfig`` from its JSON dict (the inverse of
    ``dataclasses.asdict`` after a JSON round-trip turned every tuple —
    including the nested ones inside ``rope_kw`` — into a list)."""
    from .configs.base import ArchConfig, MoEConfig, SSMConfig
    from .core.plan_serde import deep_tuple
    d = dict(d)
    if d.get("moe"):
        d["moe"] = MoEConfig(**d["moe"])
    if d.get("ssm"):
        d["ssm"] = SSMConfig(**d["ssm"])
    d = {k: deep_tuple(v) if isinstance(v, list) else v
         for k, v in d.items()}
    return ArchConfig(**d)


@dataclasses.dataclass
class CompiledStep:
    """A built step function plus everything needed to feed it.

    Single-host steps fill ``fn`` / ``segments`` / ``batch_inputs`` (+
    ``init_opt`` for training); mesh-global steps additionally carry the
    global ``in_sdss`` ShapeDtypeStructs, ``in_shardings`` and the
    ``donate`` argnums to pass to ``jax.jit``."""

    fn: Callable
    segments: Any = None
    batch_inputs: Any = None
    init_opt: Optional[Callable] = None
    in_sdss: Any = None
    in_shardings: Any = None
    donate: tuple = ()

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)


def compile(model, policy=None, mesh=None, plan_store=None,
            plan_store_path: Optional[str] = None, example_inputs=None,
            smoke: bool = False, cache=None,
            mesh_info=None, verify: str = "warn") -> "Program":
    """Build a :class:`Program` — the single frontend entry point.

    ``model``   — an arch name (``"chatglm3-6b"``), an ``ArchConfig``, a
                  built ``LMBase`` model, or (toy/prototyping path) a
                  traced ``core.Module`` / ``OpGraph``.
    ``policy``  — a ``StrategyPolicy``, a bare ``OpSchedulerBase``, or a
                  registry name (``core.strategies.registry`` — e.g.
                  ``"nanoflow"``, ``"dynamic"``, or ``"auto"`` for the
                  cost-model autotuner, whose verdicts persist in the
                  plan store); default: the built-in dynamic policy.
                  ``Program.explain()`` shows the per-context decisions.
    ``mesh``    — ``None`` (single host), a ``models.layers.MeshInfo``
                  (single host, explicit tp/dp for model construction),
                  or a ``jax.sharding.Mesh`` — steps then come back
                  shard_mapped with global shardings (the launch layer).
    ``plan_store`` / ``plan_store_path`` — share/persist lowered plans;
                  a path warm-starts the store at compile time and the
                  program checkpoints it after every build.
    ``example_inputs`` — name -> ShapeDtypeStruct, required when
                  ``model`` is an untraced ``Module``.
    ``smoke``   — with an arch name: the reduced same-family config.
    ``cache``   — KV cache backend for ``serve()``: a
                  ``serve.CacheBackend`` (``DenseCache``/``PagedCache``),
                  the names ``"dense"``/``"paged"``, or ``None`` to leave
                  the choice to ``ServeConfig``.  The backend identity
                  salts the serve PlanStore keys and rides along in
                  ``Program.save`` bundles.
    ``mesh_info`` — explicit ``MeshInfo`` for model construction when
                  ``mesh`` is a ``jax.sharding.Mesh`` whose derived
                  defaults (fsdp, attn impl) are not what you want — the
                  dryrun launcher's path.
    ``verify``  — static plan verification (``core.verify``) applied to
                  every plan the program records or redeems from the
                  store: ``"strict"`` raises ``PlanVerificationError``
                  on error-severity diagnostics, ``"warn"`` (default)
                  emits a Python warning, ``"off"`` skips.  All modes
                  except ``"off"`` feed ``Program.verify()``.
    """
    from .models.layers import MeshInfo

    # remember how the policy was spelled: Program.save can persist a
    # name or "the default" but not an opaque object (load() then needs
    # policy= re-supplied and verifies it against the saved salt)
    policy_spec = ("<default>" if policy is None
                   else policy if isinstance(policy, str) else None)
    if policy is None:
        from .core.strategies.dynamic import dynamic_policy
        policy = dynamic_policy()
    policy = as_policy(policy)
    store = resolve_plan_store(plan_store, plan_store_path)
    if store is None:
        store = PlanStore()
    # store-aware policies (AutoPolicy) persist tuning verdicts alongside
    # the plans they decided — bind before any step builds
    bind = getattr(policy, "bind_store", None)
    if callable(bind):
        bind(store)

    if isinstance(model, Module):
        if example_inputs is None:
            raise ValueError(
                "compile(Module, ...) needs example_inputs= "
                "(name -> ShapeDtypeStruct) to trace the graph")
        graph = trace(model, dict(example_inputs))
        return Program(graph=graph, policy=policy, store=store,
                       verify=verify)
    if isinstance(model, OpGraph):
        return Program(graph=model, policy=policy, store=store,
                       verify=verify)

    jax_mesh = mesh if _is_jax_mesh(mesh) else None
    if mesh_info is None:
        mesh_info = mesh if isinstance(mesh, MeshInfo) else None
    if mesh_info is None:
        if jax_mesh is not None:
            from .launch.mesh import make_mesh_info
            mesh_info = make_mesh_info(jax_mesh)
        else:
            mesh_info = MeshInfo(tp=1, dp=1)

    if isinstance(model, str):
        from .configs import get_config, get_smoke_config
        model = get_smoke_config(model) if smoke else get_config(model)
    if not hasattr(model, "build_segments"):       # ArchConfig -> LMBase
        from .models.registry import build_model
        model = build_model(model, mesh_info)
    return Program(model=model, policy=policy, store=store,
                   mesh=jax_mesh, cache=cache, policy_spec=policy_spec,
                   verify=verify)


def _is_jax_mesh(mesh) -> bool:
    return mesh is not None and hasattr(mesh, "devices") \
        and hasattr(mesh, "axis_names")


class Program:
    """A model bound to a strategy policy and a PlanStore.

    Every ``*_step`` builder below routes through the same machinery the
    old entry points used (``build_forward`` -> PlanStore lowering ->
    capture/replay; the launch shardings under a mesh) — the program
    only owns what used to be the caller's burden: context resolution,
    store lifecycle, and strategy identity.
    """

    def __init__(self, model=None, graph: Optional[OpGraph] = None,
                 policy: StrategyPolicy = None, store: PlanStore = None,
                 mesh=None, cache=None, policy_spec: Optional[str] = None,
                 verify: str = "warn"):
        self.model = model
        self.graph = graph
        self.policy = policy
        self.store = store
        self.mesh = mesh
        self.verify_mode = verify
        self._verify_reports: list = []   # (label, VerifyReport)
        if cache is not None:
            from .serve.kv_cache import resolve_cache_backend
            cache = resolve_cache_backend(cache)
        self.cache_backend = cache      # None: ServeConfig decides
        self.policy_spec = policy_spec  # "<default>" | name | None(opaque)
        self._engines: list = []
        self._graph_cache: dict = {}    # shape bucket -> (graph, realizer)

    # -- lifecycle ---------------------------------------------------------
    def checkpoint(self) -> int:
        """Persist the PlanStore if it is path-bound (else no-op)."""
        return checkpoint_plan_store(self.store)

    def close(self) -> int:
        """Shut down every engine this program created, checkpoint the
        store, and drop the engine references; the program stays usable
        after (new builds/engines re-attach)."""
        for engine in self._engines:
            engine.shutdown()
        self._engines.clear()
        return self.checkpoint()

    def __enter__(self) -> "Program":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def stats(self) -> dict:
        return self.store.snapshot()

    def explain(self) -> list:
        """The policy's decision table: one dict per scheduling decision.

        Policies that keep per-context verdicts (``policy="auto"``)
        report them in full — winner, parameterization, modeled vs
        sequential time, memory, measurement provenance; every other
        policy reports a single identity row (what it is and the salt
        under which its plans persist)."""
        table = getattr(self.policy, "explain", None)
        if callable(table):
            return table()
        return [{"policy": self.policy_spec or self.policy.name,
                 "salt": strategy_salt(self.policy)}]

    def verify(self):
        """Aggregated :class:`~repro.core.verify.VerifyReport` over every
        plan this program has built so far (one verification per segment
        per step builder, run at build time under the program's
        ``verify`` mode).  Labels enter each diagnostic's provenance via
        :meth:`verify_reports`; an empty report means either every plan
        was clean or ``verify="off"`` suppressed collection."""
        from .core.verify import VerifyReport
        out = VerifyReport()
        for _label, report in self._verify_reports:
            out = out.merged(report)
        return out

    def verify_reports(self) -> list:
        """The raw ``(label, VerifyReport)`` pairs behind
        :meth:`verify` — one per (phase, segment) built."""
        return list(self._verify_reports)

    # -- one-file deployment -----------------------------------------------
    def save(self, path: str) -> int:
        """Write a one-file deployment bundle: a versioned JSON header
        (model config, mesh info, policy spec + salt, cache-backend
        identity) followed by the PlanStore artifact, atomically.  A
        restarted server is then one :func:`load` call instead of
        re-threading arch / policy / ``plan_store_path`` by hand.
        Returns the number of persisted plan entries."""
        import json
        import os
        import tempfile

        from .core.plan import FINGERPRINT_VERSION
        from .core.plan_serde import FORMAT_VERSION
        self._require_lm("save")
        if self.mesh is not None:
            raise ProgramBundleError(
                "Program.save is single-host: a jax.sharding.Mesh is "
                "process-local; load() the bundle and recompile with "
                "mesh= instead")
        header = {
            "magic": PROGRAM_MAGIC,
            "format_version": PROGRAM_FORMAT_VERSION,
            "fingerprint_version": FINGERPRINT_VERSION,
            "plan_format_version": FORMAT_VERSION,
            "arch": dataclasses.asdict(self.model.cfg),
            "mesh_info": dataclasses.asdict(self.model.mesh),
            "policy_spec": self.policy_spec,
            "policy_salt": strategy_salt(self.policy),
            "cache_backend": (list(self.cache_backend.identity())
                              if self.cache_backend is not None else None),
        }
        path = os.path.abspath(path)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=".program-", suffix=".tmp")
        store_tmp = tmp + ".store"
        try:
            n = self.store.save(store_tmp)
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(header, sort_keys=True) + "\n")
                with open(store_tmp) as sf:
                    f.write(sf.read())
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        finally:
            if os.path.exists(store_tmp):
                os.unlink(store_tmp)
        return n

    @staticmethod
    def load(path: str, policy=None, cache=None) -> "Program":
        """Rebuild a :class:`Program` from a :meth:`save` bundle: model
        from the persisted config, policy from its saved spec, cache
        backend from its identity, and the PlanStore warm-started from
        the embedded artifact — every previously-captured plan restores
        with zero ``lower()`` calls.

        ``policy=`` overrides (and is required when the bundle was saved
        with an opaque policy object — the bundle records its salt, and
        a mismatched policy is rejected rather than silently missing
        every cached plan).  ``cache=`` overrides the saved backend."""
        import json
        import os
        import tempfile

        from .core.plan import FINGERPRINT_VERSION
        from .core.plan_serde import FORMAT_VERSION, deep_tuple
        with open(path) as f:
            head_line = f.readline()
            payload = f.read()
        try:
            header = json.loads(head_line)
            if not isinstance(header, dict):
                raise ValueError("header is not an object")
        except ValueError as e:
            raise ProgramBundleError(
                f"{path!r} is not a program bundle: {e}") from None
        if header.get("magic") != PROGRAM_MAGIC:
            raise ProgramBundleError(
                f"{path!r} is not a program bundle "
                f"(magic {header.get('magic')!r})")
        for field, want in (("format_version", PROGRAM_FORMAT_VERSION),
                            ("fingerprint_version", FINGERPRINT_VERSION),
                            ("plan_format_version", FORMAT_VERSION)):
            if header.get(field) != want:
                raise ProgramBundleError(
                    f"bundle {field} {header.get(field)} != {want}; "
                    "re-save the bundle with this version")
        from .models.layers import MeshInfo
        arch = _arch_from_dict(header["arch"])
        minfo = MeshInfo(**header["mesh_info"])
        spec = header.get("policy_spec")
        explicit_policy = policy is not None
        if policy is None:
            if spec == "<default>":
                policy = None
            elif isinstance(spec, str):
                policy = spec
            else:
                raise ProgramBundleError(
                    "bundle was saved with an opaque policy (salt "
                    f"{header.get('policy_salt')}); pass policy= to "
                    "Program.load")
        if cache is None and header.get("cache_backend") is not None:
            from .serve.kv_cache import backend_from_identity
            cache = backend_from_identity(
                deep_tuple(header["cache_backend"]))
        store = PlanStore()
        fd, tmp = tempfile.mkstemp(prefix=".program-", suffix=".store")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
            store.load(tmp)
        finally:
            os.unlink(tmp)
        program = compile(arch, policy=policy, mesh_info=minfo,
                          plan_store=store, cache=cache)
        if not explicit_policy \
                and strategy_salt(program.policy) != header["policy_salt"]:
            raise ProgramBundleError(
                f"reconstructed policy {spec!r} hashes to "
                f"{strategy_salt(program.policy)} but the bundle was "
                f"saved under {header['policy_salt']} — the policy "
                "definition drifted; pass policy= explicitly")
        return program

    # -- context resolution ------------------------------------------------
    def _context(self, phase: str, B_loc: int, S: int,
                 global_batch: Optional[int] = None) -> ScheduleContext:
        mesh_shape = {}
        if self.mesh is not None:
            from .launch.mesh import mesh_shape_dict
            mesh_shape = mesh_shape_dict(self.mesh)
        return ScheduleContext(
            local_batch=B_loc, global_batch=global_batch or B_loc,
            seq_len=S, phase=phase, arch=self.model.cfg.name,
            mesh_shape=mesh_shape)

    @staticmethod
    def _shape_of(batch) -> tuple:
        ids = batch["ids"]
        return int(ids.shape[0]), int(ids.shape[1])

    def _verify_args(self) -> dict:
        """kwargs threading the program's verification mode + report sink
        into ``build_forward`` (``verify="off"`` disables both)."""
        if self.verify_mode == "off":
            return {"verify": "off", "verify_sink": None}
        return {"verify": self.verify_mode,
                "verify_sink": self._verify_reports}

    def _require_lm(self, what: str):
        if self.model is None:
            raise TypeError(
                f"Program.{what} needs an LM program; this program wraps "
                "a raw Module/OpGraph — call it directly instead")

    # -- LM path -----------------------------------------------------------
    def init_params(self, key=0, phase: str = "prefill") -> dict:
        """Initialize the model's parameter tree (any phase's segments —
        parameter shapes are phase-independent)."""
        self._require_lm("init_params")
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        return self.model.init_params(key, phase=phase)

    def train_step(self, global_batch: Optional[int] = None,
                   seq_len: Optional[int] = None, *, batch=None,
                   cfg=None, remat_policy: str = "full") -> CompiledStep:
        """Build the train step for a (batch, seq) bucket.

        Shapes come from ``global_batch``/``seq_len`` or from an example
        ``batch`` dict (``batch["ids"].shape``).  Single host: the handle
        carries ``fn(params, opt, batch, step)``, ``init_opt``,
        ``segments`` and ``batch_inputs``.  Under a mesh: additionally
        the global sdss/shardings/donation for ``jax.jit``.
        """
        self._require_lm("train_step")
        from .train.step import TrainStepConfig, _build_train_step
        if batch is not None:
            global_batch, seq_len = self._shape_of(batch)
        if not global_batch or not seq_len:
            raise ValueError("train_step needs global_batch+seq_len or an "
                             "example batch")
        tcfg = cfg or TrainStepConfig(remat=True,
                                      remat_policy=remat_policy)
        if self.mesh is not None:
            from .configs.base import ShapeConfig
            from .launch.steps import _build_global_train_step
            shape = ShapeConfig(f"train_{seq_len}", seq_len, global_batch,
                                "train")
            fn, in_sdss, in_shd, donate, init_opt, segs = \
                _build_global_train_step(
                    self.model, self.policy, shape, self.mesh, tcfg=tcfg,
                    remat_policy=remat_policy, plan_store=self.store)
            self.checkpoint()
            return CompiledStep(fn=fn, segments=segs, init_opt=init_opt,
                                in_sdss=in_sdss, in_shardings=in_shd,
                                donate=donate)
        info = self._context("train", global_batch, seq_len)
        fn, segs, binputs, init_opt = _build_train_step(
            self.model, self.policy, global_batch, seq_len, tcfg, info,
            plan_store=self.store, **self._verify_args())
        self.checkpoint()
        return CompiledStep(fn=fn, segments=segs, batch_inputs=binputs,
                            init_opt=init_opt)

    def prefill(self, global_batch: Optional[int] = None,
                seq_len: Optional[int] = None, *, batch=None,
                s_max: Optional[int] = None) -> CompiledStep:
        """Build the prefill step for a (batch, seq-bucket) shape."""
        self._require_lm("prefill")
        if batch is not None:
            global_batch, seq_len = self._shape_of(batch)
        if not global_batch or not seq_len:
            raise ValueError("prefill needs global_batch+seq_len or an "
                             "example batch")
        if self.mesh is not None:
            from .configs.base import ShapeConfig
            from .launch.steps import _build_global_prefill_step
            shape = ShapeConfig(f"prefill_{seq_len}", seq_len,
                                global_batch, "prefill")
            fn, in_sdss, in_shd, donate, segs = _build_global_prefill_step(
                self.model, self.policy, shape, self.mesh,
                plan_store=self.store)
            self.checkpoint()
            return CompiledStep(fn=fn, segments=segs, in_sdss=in_sdss,
                                in_shardings=in_shd, donate=donate)
        from .models.base import build_forward
        s_max = s_max or seq_len
        segs, binputs = self.model.build_segments(
            "prefill", global_batch, seq_len, s_max=s_max)
        info = self._context("prefill", global_batch, seq_len)
        fwd = build_forward(segs, self.policy, info, lowered=True,
                            plan_cache=self.store,
                            op_config=self.model.op_closure_config(),
                            **self._verify_args())
        self.checkpoint()
        return CompiledStep(fn=fwd, segments=segs, batch_inputs=binputs)

    def decode_tiers(self, max_batch: int, s_max: int,
                     tiers=None) -> dict:
        """Decode steps at every batch tier against the program's store:
        the first tier lowers, the rest specialize (zero extra
        ``lower()`` calls).  Returns ``{tier: CompiledStep}``."""
        self._require_lm("decode_tiers")
        from .serve.engine import pow2_tiers
        tiers = tuple(tiers or pow2_tiers(max_batch))
        if self.mesh is not None:
            from .configs.base import ShapeConfig
            from .launch.steps import _build_global_decode_tiers
            shape = ShapeConfig(f"decode_{s_max}", s_max, max_batch,
                                "decode")
            out = {}
            built = _build_global_decode_tiers(
                self.model, self.policy, shape, self.mesh, tiers=tiers,
                plan_store=self.store)
            for tier, (fn, in_sdss, in_shd, donate, segs) in built.items():
                out[tier] = CompiledStep(fn=fn, segments=segs,
                                         in_sdss=in_sdss,
                                         in_shardings=in_shd,
                                         donate=donate)
            self.checkpoint()
            return out
        from .models.base import build_forward
        out = {}
        for tier in tiers:
            segs, binputs = self.model.build_segments(
                "decode", tier, 1, s_max=s_max)
            info = self._context("decode", tier, s_max)
            fwd = build_forward(segs, self.policy, info, lowered=True,
                                plan_cache=self.store,
                                op_config=self.model.op_closure_config(),
                                **self._verify_args())
            out[tier] = CompiledStep(fn=fwd, segments=segs,
                                     batch_inputs=binputs)
        self.checkpoint()
        return out

    def serve(self, params, cfg=None, **overrides):
        """Construct a :class:`ServeEngine` over the program's model,
        policy and (shared, already warm-started) PlanStore.  Pass a
        ``ServeConfig`` or its fields as keyword overrides — including
        ``sampling=SamplingConfig(...)`` for on-device sampled decode
        and ``spec=SpecConfig(...)`` for speculative multi-token decode
        (both route through the same tier/specialize machinery and the
        program's store)."""
        self._require_lm("serve")
        if self.mesh is not None:
            raise NotImplementedError(
                "Program.serve is single-host (the engine's host loop); "
                "use decode_tiers()/prefill() for mesh-global serving "
                "steps")
        from .serve.engine import ServeConfig, ServeEngine
        if cfg is None:
            cfg = ServeConfig(**overrides)
        elif overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        # the program's cache backend is the default; an explicit
        # ServeConfig.cache / cache= override wins
        if cfg.cache is None and self.cache_backend is not None:
            cfg = dataclasses.replace(cfg, cache=self.cache_backend)
        engine = ServeEngine(self.model, params, self.policy, cfg,
                             plan_store=self.store)
        self._engines.append(engine)
        return engine

    # -- raw-graph path (prototyping / quickstart) -------------------------
    def plan(self, local_batch: Optional[int] = None, phase: str = "train",
             **ctx_overrides):
        """Record (and cache) the execution plan the policy chooses for a
        context — introspection for the Fig. 6/7 workflow."""
        if self.graph is None:
            raise TypeError("Program.plan is the raw-graph path; LM "
                            "programs plan per step builder")
        if local_batch is None:
            local_batch = self._graph_batch()
        info = ScheduleContext(local_batch=local_batch,
                               global_batch=local_batch, phase=phase,
                               **ctx_overrides)
        _, _, plan = self._graph_program(info)
        return plan

    def __call__(self, params, inputs: dict) -> dict:
        """Raw-graph execution: resolve the context from the concrete
        inputs, record/lower the plan once per shape bucket (through the
        program's PlanStore), and realize."""
        if self.graph is None:
            raise TypeError("this Program wraps an LM; build a step with "
                            "train_step()/prefill()/decode_tiers()")
        info = ScheduleContext(local_batch=self._graph_batch(inputs),
                               global_batch=self._graph_batch(inputs),
                               phase="train")
        _, realizer, _ = self._graph_program(info)
        return realizer(params, inputs)

    def _graph_batch(self, inputs: Optional[dict] = None) -> int:
        g = self.graph
        for name, tid in sorted(g.inputs.items()):
            ref = g.tensors[tid]
            if ref.batch_dim is None:
                continue
            shape = (inputs[name].shape if inputs is not None
                     else ref.shape)
            return int(shape[ref.batch_dim])
        return 0

    def _graph_program(self, info: ScheduleContext):
        from .core.partition import partition
        key = (info.local_batch, info.phase)
        hit = self._graph_cache.get(key)
        if hit is not None:
            return hit
        sched = resolve_strategy(self.policy, info, graph=self.graph)
        g = self.graph
        # policy rule union, not the branch's rules — same invariant as
        # build_forward: every bucket of one program sees one graph
        rules = self.policy.partition_rules()
        if rules:
            g = partition(g, rules, default_depth=2)
        plan = record_plan(g, sched, info)
        salt = f"graph|{info.phase}|{strategy_salt(self.policy)}"
        realizer = Realizer(g, plan, plan_cache=self.store,
                            plan_salt=salt)
        if self.verify_mode != "off":
            from .core.verify import enforce, verify as run_verify
            report = run_verify(g, plan, lowered=realizer.lowered,
                                lint=True)
            self._verify_reports.append(
                (f"graph/{info.phase}/b{info.local_batch}", report))
            enforce(report, self.verify_mode, what="graph plan")
        self._graph_cache[key] = (g, realizer, plan)
        self.checkpoint()
        return self._graph_cache[key]
