"""Hardware model constants for the roofline target (TPU v5e-class chip).

The container is CPU-only; these constants parameterize the roofline
analysis of the compiled (dry-run) artifacts, per the assignment:
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW_PER_LINK = 50e9    # bytes/s per link
ICI_LINKS_PER_CHIP = 4    # 2D torus within a pod: +x,-x,+y,-y (v5e-256 is a 16x16 torus)
COLL_LATENCY_S = 20e-6    # collective launch latency: ring setup + per-hop
VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB VMEM per chip (v5e class)
MXU_TILE = 128            # systolic array native tile edge
HBM_BYTES = 16e9          # 16 GiB HBM per v5e chip

DTYPE_BYTES = {
    "float32": 4, "f32": 4,
    "bfloat16": 2, "bf16": 2,
    "float16": 2, "f16": 2,
    "int8": 1, "s8": 1, "u8": 1, "uint8": 1,
    "int32": 4, "s32": 4, "u32": 4, "uint32": 4,
    "int64": 8, "s64": 8, "u64": 8, "uint64": 8,
    "float64": 8, "f64": 8,
    "bool": 1, "pred": 1,
    "int16": 2, "s16": 2, "u16": 2, "uint16": 2,
    "float8_e4m3fn": 1, "f8e4m3fn": 1, "float8_e5m2": 1, "f8e5m2": 1,
}
