"""Three-term roofline model over the compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips · peak_FLOP/s)
    memory     = HLO_bytes / (chips · HBM_bw)
    collective = wire_bytes / (chips · links · link_bw)

cost_analysis() reports *global* flops/bytes (whole-mesh program), so both
are divided by chip count.  Collective wire bytes are derived from the
HLO payload bytes with ring-efficiency factors (payload P on an N-ring:
all-reduce moves 2P(N-1)/N per link-step chain, reduce-scatter/all-gather
P(N-1)/N, all-to-all P(N-1)/N split across opposing directions,
collective-permute P).  The per-collective payloads from hlo.py are
already per-chip (operand shapes are the per-participant tensors).

Also reports MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import dataclasses

from .. import hw

RING_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 0.25,        # bidirectional ring halves each direction
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_payload: dict                  # kind -> bytes (per chip, payload)
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_ratio: float
    notes: str = ""

    @property
    def t_total_seq(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def t_bound(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_total_seq=self.t_total_seq, t_bound=self.t_bound)
        return d


def wire_bytes(coll_payload: dict, axis_size: int = 16) -> float:
    total = 0.0
    for kind, nbytes in coll_payload.items():
        if kind == "total":
            continue
        eff = RING_FACTOR.get(kind, 1.0) * (axis_size - 1) / max(axis_size, 1)
        total += nbytes * eff
    return total


def roofline_terms(*, arch: str, shape: str, mesh: str, chips: int,
                   hlo_flops: float, hlo_bytes: float, coll_payload: dict,
                   n_params: float, n_active: float, tokens: float,
                   train: bool, axis_size: int = 16,
                   notes: str = "") -> RooflineResult:
    # inputs from roofline.hlo.analyze() are already per-chip (the module
    # is the SPMD-partitioned per-device program)
    t_compute = hlo_flops / hw.PEAK_FLOPS_BF16
    t_memory = hlo_bytes / hw.HBM_BW
    wire = wire_bytes(coll_payload, axis_size)
    t_coll = wire / (hw.ICI_LINKS_PER_CHIP * hw.ICI_BW_PER_LINK)
    mult = 3.0 if train else 1.0       # fwd+bwd ≈ 3x fwd matmul flops
    model_flops = 2.0 * n_active * tokens * mult
    useful = (model_flops / chips) / max(hlo_flops, 1.0)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bott = max(terms, key=terms.get)
    return RooflineResult(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        coll_payload=coll_payload, model_flops=model_flops,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bott, useful_ratio=useful, notes=notes)
