"""Plan-aware overlap model: how much collective time a schedule hides.

The dry-run gives per-(arch × shape) totals; this model explains how the
*plan order* changes exposed time, which is the quantity DynaFlow's
strategies optimize.  Semantics mirror XLA's latency-hiding scheduler on
TPU: an async collective issued at plan position i overlaps every
independent compute/memory step between i and its first dependent
consumer; whatever the window cannot cover is exposed.

Per-op costs come from the traced graph's flops/bytes estimates and the
hardware model (one compute pipe, one HBM pipe, one ICI pipe), plus a
per-collective launch latency α (ring setup + per-hop latency) that makes
chunked collectives (Flux) pay for their message count — reproducing the
paper's §5.3.5 negative result.

Fused steps are modeled by kind:
  tokenweave — AR becomes RS+AG (same wire bytes) and the add+norm memory
               work shrinks by tp (runs on the scattered shard);
  comet      — the a2a pipeline exposes ~1/n_chunks of the wire time plus
               whatever the expert GEMM cannot cover;
  flux       — chunked GEMM+AR: same wire bytes, n_chunks x the latency.
"""
from __future__ import annotations

import dataclasses

from .. import hw
from ..core.graph import FULL, OpGraph
from ..core.plan import ExecutionPlan, PlanStep

# Back-compat alias: the canonical constant lives in hw.py so the whole
# hardware model is calibrated in one place; prefer the ``coll_latency_s``
# parameter of ``plan_overlap`` for per-fabric calibration.
COLL_LATENCY_S = hw.COLL_LATENCY_S


def _wire_seconds(node, scale: float, bw_scale: float = 1.0,
                  coll_latency_s: float = hw.COLL_LATENCY_S) -> float:
    """ICI time of a network node; for composite (coalesced) units only
    the network members' bytes travel the wire — the fused memory ops
    (dispatch build etc.) are charged to the HBM pipe separately.
    ``bw_scale`` < 1 models a slower fabric (multi-node DCN — the paper's
    Appendix B low-bandwidth study)."""
    members = node.members or (node,)
    nets = [m for m in members if m.resource == "network"]
    wire = 0.0
    for m in nets:
        payload = m.bytes_moved * scale / 2.0     # in+out counted once
        kind = m.name
        factor = 2.0 if ("ar_" in kind or "allreduce" in kind
                         or "psum" in kind or "embed_ar" in kind) else \
            (0.25 if "a2a" in kind or "all_to_all" in kind else 1.0)
        wire += (payload * factor
                 / (hw.ICI_LINKS_PER_CHIP * hw.ICI_BW_PER_LINK * bw_scale)
                 + coll_latency_s)
    return wire


def _local_seconds(node, scale: float) -> float:
    """Compute/memory time of a node's non-network work."""
    members = node.members or (node,)
    t = 0.0
    for m in members:
        if m.resource == "network":
            continue
        t += max(m.flops * scale / hw.PEAK_FLOPS_BF16,
                 m.bytes_moved * scale / hw.HBM_BW)
    return t


def _op_seconds(graph, node, scale: float = 1.0, bw_scale: float = 1.0,
                coll_latency_s: float = hw.COLL_LATENCY_S):
    """(engine, t_total, t_wire) — wire is the collective part only."""
    has_net = node.resource == "network" or (
        node.members and any(m.resource == "network" for m in node.members))
    if has_net:
        w = _wire_seconds(node, scale, bw_scale, coll_latency_s)
        return "ici", w + _local_seconds(node, scale), w
    t_c = node.flops * scale / hw.PEAK_FLOPS_BF16
    t_m = node.bytes_moved * scale / hw.HBM_BW
    return ("mxu", t_c, 0.0) if t_c >= t_m else ("hbm", t_m, 0.0)


def _fused_seconds(graph, step: PlanStep, scales, tp: int,
                   bw_scale: float = 1.0,
                   coll_latency_s: float = hw.COLL_LATENCY_S):
    """(engine, t_total, t_wire) for a fused step, by replacement kind."""
    nets = [(h, graph.nodes[h.oid]) for h in step.handles
            if graph.nodes[h.oid].resource == "network"]
    rest = [(h, graph.nodes[h.oid]) for h in step.handles
            if graph.nodes[h.oid].resource != "network"]
    t_wire = sum(_wire_seconds(n, scales[h], bw_scale, coll_latency_s)
                 - coll_latency_s
                 for h, n in nets)
    t_rest = sum(_op_seconds(graph, n, scales[h],
                             coll_latency_s=coll_latency_s)[1]
                 for h, n in rest)
    name = step.replace_name
    if name == "tokenweave":
        # RS + AG (same bytes as AR); elementwise work on 1/tp tokens
        w = t_wire + 2 * coll_latency_s
        return "ici", w + t_rest / max(tp, 1), w
    if name == "comet":
        # self-overlapped pipeline: GEMM-dominated, charge compute engine;
        # only the un-hidden wire remains collective
        G = 4
        exposed_wire = (t_wire / G + max(0.0, t_wire * (G - 1) / G - t_rest)
                        + G * 2 * coll_latency_s)
        return "mxu", exposed_wire + t_rest, exposed_wire
    if name == "flux":
        G = 4
        w = t_wire + G * coll_latency_s
        return "ici", w + t_rest, w
    w = t_wire + len(nets) * coll_latency_s
    return "ici", w + t_rest, w


@dataclasses.dataclass
class OverlapReport:
    t_sequential: float        # every step serialized
    t_overlapped: float        # collectives hidden behind their windows
    coll_total: float
    coll_exposed: float

    @property
    def speedup(self) -> float:
        return self.t_sequential / max(self.t_overlapped, 1e-12)


def plan_overlap(graph: OpGraph, plan: ExecutionPlan, tp: int = 16,
                 extra_weight_read_bytes: float = 0.0,
                 bw_scale: float = 1.0,
                 coll_latency_s: float = hw.COLL_LATENCY_S) -> OverlapReport:
    """Model the plan.  ``extra_weight_read_bytes``: additional HBM reads
    from micro-batch splitting (each extra micro-batch re-reads weights —
    the paper's Fig. 2a penalty), charged to the memory pipe.
    ``coll_latency_s`` calibrates the per-collective launch latency for
    the target fabric (default: the hw.py TPU-pod ICI figure)."""
    nparts = plan.num_mb
    sizes = plan.split_sizes or (1,)
    total = float(sum(sizes))

    def scale_of(handle, merged):
        if (merged or handle.mb == FULL
                or not graph.splittable(handle.oid)):
            return 1.0
        return sizes[handle.mb] / total

    costs, reads, writes = [], [], []
    for step in plan.steps:
        merged = step.kind == "merged"
        if step.kind == "fused":
            scales = {h: scale_of(h, False) for h in step.handles}
            eng, t, w = _fused_seconds(graph, step, scales, tp, bw_scale,
                                       coll_latency_s)
        else:
            h = step.handles[0]
            eng, t, w = _op_seconds(graph, graph.nodes[h.oid],
                                    scale_of(h, merged), bw_scale,
                                    coll_latency_s)
        costs.append((eng, t, w))
        r, w = set(), set()
        for h in step.handles:
            n = graph.nodes[h.oid]
            mb = FULL if merged else h.mb
            r |= {(t_, mb) for t_ in n.inputs}
            w |= {(t_, mb) for t_ in n.outputs}
        reads.append(r)
        writes.append(w)

    t_seq = sum(t for _, t, _ in costs) \
        + extra_weight_read_bytes / hw.HBM_BW
    coll_total = sum(w for _, _, w in costs)

    # overlap pass: collective i's WIRE time covers steps j in
    # (i, first_dependent); its own local (fused compute) part serializes
    exposed = 0.0
    for i, (_eng, _t, w) in enumerate(costs):
        if w <= 0.0:
            continue
        window = 0.0
        produced = writes[i]
        for j in range(i + 1, len(costs)):
            dep = any((tid, mb) in reads[j] or (tid, FULL) in reads[j]
                      or any((tid, p) in reads[j] for p in range(nparts))
                      for (tid, mb) in produced)
            if dep:
                break
            window += costs[j][1] - costs[j][2]
        exposed += max(0.0, w - window)
    t_over = (sum(t - w for _, t, w in costs)
              + extra_weight_read_bytes / hw.HBM_BW + exposed)
    return OverlapReport(t_seq, t_over, coll_total, exposed)


def split_weight_penalty(graph: OpGraph, nparts: int) -> float:
    """Extra HBM bytes from re-reading weights once per extra micro-batch
    (paper §2.1 Splitting / Fig. 2a)."""
    if nparts <= 1:
        return 0.0
    wbytes = sum(n.param_bytes for n in graph.nodes.values())
    return (nparts - 1) * wbytes
