from .hlo import collective_bytes, parse_hlo_collectives
from .model import RooflineResult, roofline_terms
