"""HLO text analyzer: trip-count-aware flops / HBM bytes / collective
payloads from a compiled (scheduled, SPMD-partitioned) module.

Why not ``compiled.cost_analysis()``: XLA's aggregate counts while-loop
bodies ONCE — a scan-over-layers model under-reports by ~n_layers.  This
parser recovers per-computation multipliers from the ``while`` ops'
``backend_config known_trip_count`` (with a condition-constant fallback)
and attributes:

  * flops     — every ``dot`` (2 · result_elems · contraction), inside
                fusion bodies too;
  * HBM bytes — operand + result bytes of top-level fusion/dot/reduce/
                copy/dus/gather/... instructions in entry and control-flow
                bodies (fusion internals excluded: a fused kernel touches
                HBM only at its boundary — this approximates TPU traffic
                far better than 'bytes accessed');
  * collective payload bytes by kind.

All shapes come from the per-device module, so results are per-chip.
"""
from __future__ import annotations

import re
from typing import Optional

from ..hw import DTYPE_BYTES

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\S.*?)\s+"
                       r"([\w\-]+)\((.*)$")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "custom-call",
               "after-all", "iota", "partition-id", "replica-id",
               "broadcast", "reshape"}


def _shape_elems_bytes(shape_str: str):
    elems, nbytes = 0, 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * DTYPE_BYTES[dtype]
    return elems, nbytes


class Instr:
    __slots__ = ("name", "shape", "op", "rest", "line")

    def __init__(self, name, shape, op, rest, line):
        self.name, self.shape, self.op = name, shape, op
        self.rest, self.line = rest, line


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[Instr] = []
        self.shapes: dict[str, str] = {}     # instr name -> shape string


def parse_module(hlo: str) -> tuple:
    """(computations dict, entry name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            head = line.strip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].strip()
            name = head.split()[0].split("(")[0].lstrip("%")
            if not name or name == "HloModule":
                cur = None
                continue
            cur = Computation(name)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op, rest = m.groups()
        ins = Instr(name, shape, op, rest, line.strip())
        cur.instrs.append(ins)
        cur.shapes[name] = shape
    return comps, entry


def _trip_count_of(instr: Instr, comps) -> int:
    m = re.search(r'known_trip_count[":{]+n[":]+(\d+)', instr.line)
    if m:
        return int(m.group(1))
    cond = re.search(r"condition=%?([\w\.\-]+)", instr.line)
    if cond and cond.group(1) in comps:
        consts = {}
        for ins in comps[cond.group(1)].instrs:
            if ins.op == "constant":
                mc = re.search(r"constant\((\d+)\)", ins.line)
                if mc:
                    consts[ins.name] = int(mc.group(1))
        for ins in comps[cond.group(1)].instrs:
            if ins.op == "compare":
                for nm in re.findall(r"%([\w\.\-]+)", ins.rest):
                    if nm in consts:
                        return consts[nm]
    return 1


def computation_multipliers(comps: dict, entry: str):
    """(multiplier, kind) per computation.  kind: 'body' (entry/control
    flow — counts bytes) or 'fusion' (counts flops only)."""
    mult = {name: 0 for name in comps}
    kind = {name: "body" for name in comps}
    if entry in mult:
        mult[entry] = 1
    for _ in range(16):
        changed = False
        for cname, comp in comps.items():
            m0 = mult.get(cname, 0)
            if not m0:
                continue
            for ins in comp.instrs:
                refs = []
                if ins.op == "while":
                    body = re.search(r"body=%?([\w\.\-]+)", ins.line)
                    if body and body.group(1) in comps:
                        t = _trip_count_of(ins, comps)
                        refs.append((body.group(1), m0 * t, "body"))
                elif ins.op == "conditional":
                    for br in re.findall(
                            r"(?:branch_computations=\{([^}]*)\}|"
                            r"(?:true|false)_computation=%?([\w\.\-]+))",
                            ins.line):
                        for b in (br[0].split(",") if br[0] else [br[1]]):
                            b = b.strip().lstrip("%")
                            if b in comps:
                                refs.append((b, m0, "body"))
                elif ins.op in ("fusion",):
                    c = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                    if c and c.group(1) in comps:
                        refs.append((c.group(1), m0, "fusion"))
                elif ins.op in ("call", "async-start"):
                    c = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)",
                                  ins.line)
                    if c and c.group(1) in comps:
                        refs.append((c.group(1), m0, "body"))
                for ref, m1, k in refs:
                    if mult.get(ref, 0) < m1:
                        mult[ref] = m1
                        kind[ref] = k
                        changed = True
                    elif kind.get(ref) == "body" and k == "fusion":
                        pass
        if not changed:
            break
    return mult, kind


def _operand_names(rest: str) -> list:
    # operands are the leading %name references before any attr k=v
    head = rest.split("),")[0] if ")," in rest else rest.split(")")[0]
    return re.findall(r"%([\w\.\-]+)", head)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res_elems, _ = _shape_elems_bytes(ins.shape)
    ops = _operand_names(ins.rest)
    if not ops:
        return 0.0
    lhs_shape = comp.shapes.get(ops[0], "")
    dims = _SHAPE_RE.findall(lhs_shape)
    if not dims:
        return 0.0
    lhs_dims = [int(d) for d in dims[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    k = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * res_elems * k


_NARROW_READS = {"dynamic-slice", "slice", "gather"}
_VIEW_OPS = {"bitcast", "reshape", "transpose", "copy", "convert"}


def _real_root(comp: Computation) -> Optional[Instr]:
    """Root instruction, looking through bitcast/convert view chains."""
    roots = [i for i in comp.instrs if i.line.startswith("ROOT")
             or " ROOT " in ("  " + i.line)]
    root = roots[-1] if roots else (comp.instrs[-1] if comp.instrs else None)
    seen = 0
    while root is not None and root.op in _VIEW_OPS and seen < 8:
        ops = _operand_names(root.rest)
        nxt = next((i for i in comp.instrs if ops and i.name == ops[0]),
                   None)
        if nxt is None:
            break
        root = nxt
        seen += 1
    return root


def _terminal_consumers(comp: Computation, name: str, depth: int = 0):
    """Non-view consumers of ``name``, following view/convert chains
    (inside a fusion those are register renames, not HBM traffic)."""
    if depth > 10:
        return []
    out = []
    for i in comp.instrs:
        if name not in _operand_names(i.rest):
            continue
        if i.op in _VIEW_OPS:
            out.extend(_terminal_consumers(comp, i.name, depth + 1))
        else:
            out.append((i, _operand_names(i.rest).index(name)
                        if name in _operand_names(i.rest) else -1))
    return out


def _views_of(comp: Computation, name: str, depth: int = 0) -> set:
    """name + all its view/convert aliases downstream."""
    out = {name}
    if depth > 10:
        return out
    for i in comp.instrs:
        if i.op in _VIEW_OPS and name in _operand_names(i.rest):
            out |= _views_of(comp, i.name, depth + 1)
    return out


def _fusion_bytes(ins: Instr, comps) -> Optional[float]:
    """HBM traffic of a fusion (TPU-normative model):
    * a parameter consumed only through narrow reads (dynamic-slice /
      slice / gather, across view chains) charges the slice bytes;
    * the destination buffer of a dynamic-update-slice / scatter root is
      aliased in place: charge 2x the update payload, not the buffer;
    * converts/bitcasts/reshapes inside the fusion are register renames;
    * otherwise: full operand bytes + result bytes."""
    c = re.search(r"calls=%?([\w\.\-]+)", ins.line)
    if not c or c.group(1) not in comps:
        return None
    comp = comps[c.group(1)]

    root = _real_root(comp)
    root_ops = _operand_names(root.rest) if root is not None else []
    inplace_update_bytes = None
    aliased_src = None
    if root is not None and root.op in ("dynamic-update-slice", "scatter"):
        upd_idx = 1 if root.op == "dynamic-update-slice" else 2
        if len(root_ops) > upd_idx and root_ops[upd_idx] in comp.shapes:
            _, ub = _shape_elems_bytes(comp.shapes[root_ops[upd_idx]])
            inplace_update_bytes = 2.0 * ub
            aliased_src = root_ops[0]

    total = 0.0
    for p in comp.instrs:
        if p.op != "parameter":
            continue
        _, pbytes = _shape_elems_bytes(p.shape)
        if aliased_src is not None and aliased_src in _views_of(comp, p.name):
            continue                      # in-place destination buffer
        terms = _terminal_consumers(comp, p.name)
        if terms and all(t.op in _NARROW_READS for t, _ in terms):
            total += sum(_shape_elems_bytes(t.shape)[1] for t, _ in terms)
        else:
            total += pbytes

    if inplace_update_bytes is not None:
        return total + inplace_update_bytes
    _, rbytes = _shape_elems_bytes(ins.shape)
    return total + rbytes


def _dus_inplace_bytes(ins: Instr, comps) -> Optional[float]:
    """Bare (unfused) in-place update ops."""
    if ins.op == "dynamic-update-slice":
        _, rbytes = _shape_elems_bytes(ins.shape)
        return 0.02 * rbytes     # update slice unavailable: small fraction
    return None


def analyze(hlo: str, substitute_scopes: tuple = ()) -> dict:
    """{'flops', 'hbm_bytes', 'collectives': {kind: payload_bytes},
       'n_collectives'} — per chip, trip-count weighted.

    ``substitute_scopes``: named_scope labels whose instructions lower to
    a single Pallas kernel on TPU.  Their *flops* still count, but their
    HBM bytes are replaced by the kernel-boundary traffic (the q/k/v/o
    tensors cross HBM; the score matrix lives in VMEM).  The per-scope
    boundary traffic is approximated as the bytes of the scope's dots'
    operands/results that are NOT scope-internal — here simplified to the
    dot operand/result bytes at the scope frontier divided by 2 (each
    internal edge counted at one end)."""
    comps, entry = parse_module(hlo)
    mult, kind = computation_multipliers(comps, entry)
    flops = 0.0
    hbm = 0.0
    sub_hbm: dict = {s: 0.0 for s in substitute_scopes}
    coll: dict = {}
    n_coll = 0

    def scope_of(ins):
        for sc in substitute_scopes:
            if sc in ins.line:
                return sc
        return None

    for cname, comp in comps.items():
        m0 = mult.get(cname, 0)
        if not m0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m0 * _dot_flops(ins, comp)
            is_coll = any(ins.op.startswith(k) for k in COLLECTIVE_KINDS)
            if is_coll:
                base = next(k for k in COLLECTIVE_KINDS
                            if ins.op.startswith(k))
                if ins.op.endswith("-done"):
                    continue
                _, nbytes = _shape_elems_bytes(ins.shape)
                coll[base] = coll.get(base, 0) + nbytes * m0
                n_coll += m0
                continue
            if kind.get(cname) == "fusion":
                continue
            if ins.op in _SKIP_BYTES:
                continue
            sc = scope_of(ins)
            if ins.op == "fusion":
                fb = _fusion_bytes(ins, comps)
                if fb is not None:
                    if sc is None:
                        hbm += m0 * fb
                    else:
                        # kernel-internal traffic: boundary ops only
                        sub_hbm[sc] += m0 * fb
                    continue
            dus_bytes = _dus_inplace_bytes(ins, comps)
            if dus_bytes is not None:
                hbm += m0 * dus_bytes
                continue
            _, rbytes = _shape_elems_bytes(ins.shape)
            obytes = 0
            for op_name in _operand_names(ins.rest):
                if op_name in comp.shapes:
                    _, b = _shape_elems_bytes(comp.shapes[op_name])
                    obytes += b
            if sc is None:
                hbm += m0 * (rbytes + obytes)
            else:
                sub_hbm[sc] += m0 * (rbytes + obytes)
    # substituted scopes: charge 10% of their naive traffic as the kernel
    # boundary (q/k/v/o + partial-block spill), a measured-shape-level
    # bound validated against the interpret-mode kernel's operand set
    for _sc, b in sub_hbm.items():
        hbm += 0.1 * b
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    return {"flops": flops, "hbm_bytes": hbm, "collectives": coll,
            "n_collectives": n_coll, "substituted_bytes": dict(sub_hbm)}


def collective_bytes(hlo: str) -> dict:
    return analyze(hlo)["collectives"]


def parse_hlo_collectives(hlo: str) -> list:
    """Back-compat shim: [(kind, bytes, mult)] list."""
    comps, entry = parse_module(hlo)
    mult, _ = computation_multipliers(comps, entry)
    out = []
    for cname, comp in comps.items():
        m0 = mult.get(cname, 0)
        if not m0:
            continue
        for ins in comp.instrs:
            for k in COLLECTIVE_KINDS:
                if ins.op.startswith(k) and not ins.op.endswith("-done"):
                    _, nbytes = _shape_elems_bytes(ins.shape)
                    out.append((k, nbytes, m0))
                    break
    return out
