"""Jitted on-device sampling for the serve engine.

Until PR 9 the captured decode/prefill steps ended in a hardcoded
``jnp.argmax`` — greedy was the only policy that never left the device.
This module supplies the general policy as a pure jittable function so
temperature / top-k / top-p sampling stays inside the captured step
(zero extra host syncs) and so speculative verification can sample all
k+1 positions of a draft window in one call.

Determinism contract
--------------------
Every sampled token is drawn with a PRNG key derived *only* from
``(seed, rid, position)`` — the request seed, the request id, and the
absolute stream position of the token being emitted::

    key = fold_in(fold_in(PRNGKey(seed), rid), position)

No batch index, tier, iteration count or wall clock enters the key, so
a sampled run is bitwise reproducible across batch compositions, across
preemption-resume (the re-prefill re-derives the same positions), and
across process restarts.  It is also what makes speculative decoding
*lossless* under sampling: the verify step re-samples position ``p``
with the same key the plain decode path would have used, so accepted
tokens are exactly the tokens plain decode would have produced.

``seed``/``rid``/``position`` are runtime arguments of the captured
step — they never salt a PlanStore key (asserted by the determinism
tests).  Only the *policy* (temperature/top-k/top-p, static under jit)
salts the executable cache, via :func:`sampling_salt`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """On-device sampling policy.

    ``temperature == 0`` selects greedy argmax (the exact pre-PR-9
    compiled graph — bitwise identical tokens).  ``top_k == 0`` and
    ``top_p == 1.0`` disable the respective filters.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError("SamplingConfig: temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("SamplingConfig: top_k must be >= 0")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError("SamplingConfig: top_p must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0

    def identity(self) -> tuple:
        if self.greedy:
            return ("sampling", "greedy")
        return ("sampling", float(self.temperature), int(self.top_k),
                float(self.top_p))


GREEDY = SamplingConfig()


def resolve_sampling(cfg: Optional[SamplingConfig]) -> SamplingConfig:
    """``None`` means greedy — the historical engine default."""
    return GREEDY if cfg is None else cfg


def sampling_salt(cfg: Optional[SamplingConfig]) -> str:
    """Printable policy identity for executable-cache keys.  The policy
    is baked into the captured step closure, so two policies must never
    share an executable; seeds/rids/positions are runtime args and do
    NOT appear here."""
    cfg = resolve_sampling(cfg)
    if cfg.greedy:
        return "greedy"
    return f"t{cfg.temperature:g}k{cfg.top_k}p{cfg.top_p:g}"


def row_keys(seeds, rids, positions):
    """Per-element PRNG keys from the (seed, rid, position) fold chain.
    All args must share one flat shape."""
    def one(seed, rid, pos):
        key = jax.random.PRNGKey(seed)
        key = jax.random.fold_in(key, rid)
        return jax.random.fold_in(key, pos)
    return jax.vmap(one)(seeds, rids, positions)


def _filter_logits(logits, cfg: SamplingConfig):
    """Temperature + top-k + top-p filters over (N, V) f32 logits."""
    scaled = logits / jnp.asarray(cfg.temperature, logits.dtype)
    vocab = scaled.shape[-1]
    if cfg.top_k and cfg.top_k < vocab:
        kth = jnp.sort(scaled, axis=-1)[:, vocab - cfg.top_k][:, None]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if cfg.top_p < 1.0:
        desc = jnp.sort(scaled, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep a token while the cumulative mass *before* it is < top_p,
        # which always keeps the most-likely token
        keep = (cum - probs) < cfg.top_p
        floor = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1,
                        keepdims=True)
        scaled = jnp.where(scaled < floor, -jnp.inf, scaled)
    return scaled


def sample_tokens(logits, cfg: Optional[SamplingConfig], *, seeds, rids,
                  positions):
    """Sample int32 token ids from ``logits`` (..., V).

    ``seeds``/``rids``/``positions`` broadcast against the leading dims
    of ``logits``.  Greedy policy compiles to a pure argmax — the same
    graph the engine captured before sampling existed.
    """
    cfg = resolve_sampling(cfg)
    if cfg.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lead = logits.shape[:-1]
    vocab = logits.shape[-1]
    flat = logits.reshape((-1, vocab)).astype(jnp.float32)
    seeds = jnp.broadcast_to(jnp.asarray(seeds, jnp.uint32), lead).reshape(-1)
    rids = jnp.broadcast_to(jnp.asarray(rids, jnp.int32), lead).reshape(-1)
    pos = jnp.broadcast_to(jnp.asarray(positions, jnp.int32),
                           lead).reshape(-1)
    filt = _filter_logits(flat, cfg)
    keys = row_keys(seeds, rids, pos)
    toks = jax.vmap(jax.random.categorical)(keys, filt)
    return toks.reshape(lead).astype(jnp.int32)
