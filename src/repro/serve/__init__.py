from .engine import Request, ServeConfig, ServeEngine
from .kv_cache import KVCacheManager
