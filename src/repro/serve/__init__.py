from .admission import (
    AdmissionContext,
    AdmissionPolicy,
    AdmitAll,
    BoundedQueue,
    ChunkingDisabled,
    DeadlineExceeded,
    DeadlineGate,
    EmptyPrompt,
    EngineDraining,
    Failed,
    Finished,
    Overloaded,
    PagePressure,
    PriorityFloor,
    PromptOverflow,
    RejectedRequest,
    Shed,
    UnchunkablePrompt,
    admission_chain,
)
from .engine import Request, ServeConfig, ServeEngine
from .faults import FaultInjector, InjectedFault, PoisonedRequest
from .kv_cache import (
    CacheBackend,
    CacheRowError,
    DenseCache,
    KVCacheManager,
    PagedCache,
    PagedKVCacheManager,
    UnpageableCache,
    resolve_cache_backend,
)
