from .engine import ServeConfig, ServeEngine, Request
from .kv_cache import KVCacheManager
