from .admission import (
    AdmissionContext,
    AdmissionPolicy,
    AdmitAll,
    BoundedQueue,
    ChunkingDisabled,
    DeadlineExceeded,
    DeadlineGate,
    EmptyPrompt,
    EngineDraining,
    Failed,
    Finished,
    Overloaded,
    PagePressure,
    PriorityFloor,
    PromptOverflow,
    RejectedRequest,
    Shed,
    UnchunkablePrompt,
    admission_chain,
)
from .engine import Request, ServeConfig, ServeEngine
from .faults import FaultInjector, InjectedFault, PoisonedRequest
from .kv_cache import (
    CacheBackend,
    CacheRowError,
    DenseCache,
    KVCacheManager,
    PagedCache,
    PagedKVCacheManager,
    UnpageableCache,
    resolve_cache_backend,
)
from .sampling import GREEDY, SamplingConfig, resolve_sampling, sampling_salt
from .speculative import (
    DRAFT_K_CANDIDATES,
    NGramProposer,
    Proposer,
    SelfSpecProposer,
    SpecConfig,
    resolve_proposer,
)
