"""Speculative multi-token decode: proposers and configuration.

A *proposer* guesses k draft tokens per active row; the engine then runs
the target model once at query width k+1 (the ``(tier, k)`` pair is just
another shape bucket of the canonical decode lowering) and accepts the
longest draft prefix that matches what the target itself would have
emitted, plus one corrected token.  Greedy speculative decode is
bitwise identical to plain greedy decode; sampled speculative decode is
lossless too because sampling keys are position-derived
(``serve.sampling``), so the verify step re-samples each position with
exactly the key plain decode would have used.

Two built-in proposers:

* :class:`NGramProposer` — host-side prompt-lookup drafting.  Finds the
  most recent earlier occurrence of the stream's trailing n-gram and
  proposes its continuation.  Zero extra device FLOPs; strong on
  repetitive/structured continuations (code, retrieval, summaries).
* :class:`SelfSpecProposer` — self-speculative drafting: re-runs the
  first ``n_layers`` of the *same* model (truncated-layer reuse of the
  same params and KV cache) k times at width 1.  Because the layer-stack
  scan infers its length from the sliced leading dim, the draft loop
  replays the already-lowered decode plans — no new lowerings.

Custom proposers implement the :class:`Proposer` protocol: host-side
ones override :meth:`Proposer.draft`; device-side ones set
``device = True`` and the engine builds the draft step from the model
(see ``ServeEngine._spec_draft_fn``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from .sampling import SamplingConfig

#: draft-k candidates registered as the ``spec_decode`` tunable
#: param_space in the strategy registry (``core.strategies.registry``);
#: ``SpecConfig(k="auto")`` picks among these from measured acceptance.
DRAFT_K_CANDIDATES = (2, 4, 8)


class Proposer:
    """Draft-token source for speculative decode.

    Host proposers implement :meth:`draft`; device proposers set
    ``device = True`` (drafts are then produced inside the captured
    step and never leave the device).
    """

    name = "proposer"
    device = False

    def draft(self, streams: Sequence[Sequence[int]], k: int) -> np.ndarray:
        """(len(streams), k) int32 draft tokens; ``streams[i]`` is row
        i's full token stream so far (prompt + generated)."""
        raise NotImplementedError

    def identity(self) -> tuple:
        return (self.name,)


class NGramProposer(Proposer):
    """Prompt-lookup drafting (host-side, zero device FLOPs).

    For each row, scan for the most recent earlier occurrence of the
    stream's trailing n-gram (longest first, ``max_ngram`` down to
    ``min_ngram``) and draft its continuation; fall back to repeating
    the last token when nothing matches.
    """

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not (1 <= min_ngram <= max_ngram):
            raise ValueError("NGramProposer: need 1 <= min_ngram <= max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def identity(self) -> tuple:
        return (self.name, self.max_ngram, self.min_ngram)

    def draft(self, streams, k):
        out = np.empty((len(streams), k), np.int32)
        for i, stream in enumerate(streams):
            out[i] = self._draft_one(np.asarray(stream, np.int32), k)
        return out

    def _draft_one(self, stream: np.ndarray, k: int) -> np.ndarray:
        n = len(stream)
        for g in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            suffix = stream[n - g:]
            # most recent earlier occurrence wins (locality: recent
            # continuations predict the next tokens best)
            windows = np.lib.stride_tricks.sliding_window_view(
                stream[:n - 1], g)
            hits = np.nonzero((windows == suffix).all(axis=1))[0]
            if hits.size == 0:
                continue
            start = int(hits[-1]) + g
            cont = stream[start:start + k]
            if cont.size == 0:
                continue
            if cont.size < k:
                cont = np.concatenate(
                    [cont, np.full(k - cont.size, cont[-1], np.int32)])
            return cont
        return np.full(k, stream[-1] if n else 0, np.int32)


class SelfSpecProposer(Proposer):
    """Self-speculative drafting: the first ``n_layers`` of the target
    model act as the draft model (same params, same KV cache — read
    only; draft-step cache writes are discarded).  ``n_layers=None``
    defaults to half the stack.  Requires a model whose decode phase is
    a single scanned layer stack (e.g. the dense transformer family).
    """

    name = "selfspec"
    device = True

    def __init__(self, n_layers: Optional[int] = None):
        if n_layers is not None and n_layers < 1:
            raise ValueError("SelfSpecProposer: n_layers must be >= 1")
        self.n_layers = n_layers

    def identity(self) -> tuple:
        return (self.name, self.n_layers)


def resolve_proposer(proposer: Union[str, Proposer]) -> Proposer:
    if isinstance(proposer, Proposer):
        return proposer
    if proposer == "ngram":
        return NGramProposer()
    if proposer in ("self", "selfspec"):
        return SelfSpecProposer()
    raise ValueError(
        f"unknown proposer {proposer!r}: expected 'ngram', 'self', or a "
        "Proposer instance")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs for ``ServeConfig(spec=...)``.

    ``proposer``: ``"ngram"``, ``"self"``, or a :class:`Proposer`.
    ``k``: draft tokens per verify step (>= 1), or ``"auto"`` to pick
    per context from the registered ``spec_decode`` param_space using
    acceptance rates fed through ``AutoPolicy.observe``.
    ``sampling``: overrides the engine-wide sampling policy for decode.
    """

    proposer: Union[str, Proposer] = "ngram"
    k: Union[int, str] = 4
    sampling: Optional[SamplingConfig] = None

    def __post_init__(self):
        if self.k != "auto" and (not isinstance(self.k, int) or self.k < 1):
            raise ValueError("SpecConfig: k must be an int >= 1 or 'auto'")
        resolve_proposer(self.proposer)  # fail fast on typos
