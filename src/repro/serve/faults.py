"""Deterministic fault injection — the chaos harness shared by serve
and train.

A :class:`FaultInjector` is a *schedule* of failures, not a random
process: every fault fires at an exact, reproducible point (an
allocation attempt index, the N-th dispatch at a site, a named request
id, an engine iteration window), so a chaos test can assert the exact
recovery path and a degraded-mode benchmark run is replayable.  The
``seed`` only feeds derived randomized schedules (none built-in today);
the injector never consults wall-clock entropy.

Injection sites (all consulted by :class:`~repro.serve.engine.ServeEngine`
when ``ServeConfig.faults`` is set):

  * **allocation**   — ``deny_alloc()``: the k-th KV-row allocation
    attempt fails as if the pool were exhausted (exercises the
    admission/requeue path without actually filling the pool).
  * **dispatch**     — ``check_dispatch(site, rids)``: the k-th dispatch
    at a site ("prefill" / "decode" / "chunk") raises
    :class:`InjectedFault`, or any dispatch containing a *poisoned*
    request id raises :class:`PoisonedRequest` (targeted — the engine's
    error boundary can blame and excise exactly one request).
  * **harvest**      — ``check_harvest(rid)``: host-side bookkeeping for
    one request raises (a poisoned request on the harvest path).
  * **slow step**    — ``on_iter(it)``: the engine iteration sleeps
    ``slow_s`` (straggler; deadline/TTFT budgets see real delay).
  * **memory pressure** — ``pressure_rows(it)``: during ``[start, stop)``
    iteration windows the KV pool's effective capacity shrinks by
    ``rows`` (the engine must shed, queue, or preempt to fit).

``ft.elastic.FailureSimulator`` subclasses this injector so the train
loop's crash/straggler simulation and the serve chaos harness share one
mechanism and one ``injected`` event log.
"""
from __future__ import annotations

import random
import time
from typing import Optional


class InjectedFault(RuntimeError):
    """A scheduled, untargeted fault: the whole dispatch fails.

    The engine's error boundary treats it like any real dispatch
    exception — blast radius is the dispatch (prefill group / chunk /
    active decode rows), never the engine."""


class PoisonedRequest(RuntimeError):
    """A targeted fault naming the request that caused it.  The engine's
    error boundary excises exactly ``rid`` (it terminates as ``Failed``)
    and retries the dispatch with the survivors."""

    def __init__(self, rid: int, site: str):
        super().__init__(f"poisoned request {rid} at {site}")
        self.rid = rid
        self.site = site


class FaultInjector:
    """Deterministic fault schedule.

    Args:
      alloc_fail:    allocation-attempt indices (0-based, global) that
                     are denied.
      dispatch_fail: ``(site, index)`` pairs — the index-th dispatch at
                     that site raises :class:`InjectedFault` (one-shot).
      poison:        ``{rid: site}`` — any dispatch/harvest at ``site``
                     ("prefill" / "decode" / "chunk" / "harvest" /
                     "any") containing ``rid`` raises
                     :class:`PoisonedRequest` (persistent: a poisoned
                     request stays poisoned on retry).
      slow_iters:    engine iteration indices that sleep ``slow_s``.
      pressure:      ``(start, stop, rows)`` windows — during iterations
                     ``start <= it < stop`` the KV pool's effective
                     capacity shrinks by ``rows``.
    """

    def __init__(self, alloc_fail=(), dispatch_fail=(), poison=None,
                 slow_iters=(), slow_s: float = 0.05, pressure=(),
                 seed: int = 0):
        self.alloc_fail = set(alloc_fail)
        self.dispatch_fail = set(tuple(x) for x in dispatch_fail)
        self.poison = dict(poison or {})
        self.slow_iters = set(slow_iters)
        self.slow_s = slow_s
        self.pressure = tuple(tuple(w) for w in pressure)
        self.rng = random.Random(seed)
        self.injected: list = []           # (kind, detail) event log
        self._alloc_attempts = 0
        self._dispatches: dict[str, int] = {}

    # -- serve sites --------------------------------------------------------
    def on_iter(self, it: int):
        """Called once at the top of every engine iteration."""
        if it in self.slow_iters:
            self.slow_iters.discard(it)
            self.injected.append(("slow", it))
            time.sleep(self.slow_s)

    def pressure_rows(self, it: int) -> int:
        """Rows embargoed from the KV pool at iteration ``it``."""
        k = 0
        for start, stop, rows in self.pressure:
            if start <= it < stop:
                k = max(k, rows)
        return k

    def deny_alloc(self) -> bool:
        """True when this KV-row allocation attempt is scheduled to fail."""
        i, self._alloc_attempts = self._alloc_attempts, \
            self._alloc_attempts + 1
        if i in self.alloc_fail:
            self.injected.append(("alloc_fail", i))
            return True
        return False

    def check_dispatch(self, site: str, rids=()):
        """Raise if this dispatch is scheduled to fail.  Targeted
        (poison) faults outrank untargeted ones so the engine's blame
        path is exercised first."""
        for rid in rids:
            at = self.poison.get(rid)
            if at == site or at == "any":
                self.injected.append(("poison", site, rid))
                raise PoisonedRequest(rid, site)
        i = self._dispatches.get(site, 0)
        self._dispatches[site] = i + 1
        if (site, i) in self.dispatch_fail:
            self.dispatch_fail.discard((site, i))
            self.injected.append(("dispatch_fail", site, i))
            raise InjectedFault(f"injected {site} dispatch failure "
                                f"(dispatch #{i})")

    def check_harvest(self, rid: int):
        """Raise if host-side bookkeeping for ``rid`` is poisoned."""
        at = self.poison.get(rid)
        if at in ("harvest", "any"):
            self.injected.append(("poison", "harvest", rid))
            raise PoisonedRequest(rid, "harvest")

    # -- introspection ------------------------------------------------------
    @property
    def counts(self) -> dict:
        out: dict = {}
        for ev in self.injected:
            out[ev[0]] = out.get(ev[0], 0) + 1
        return out
