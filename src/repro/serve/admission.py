"""Admission control for the serve runtime — robustness policy as a
first-class, pluggable object.

DynaFlow's frontend thesis is that *execution* policy lives outside the
model definition; this module applies the same decoupling to
*robustness* policy.  An :class:`AdmissionPolicy` mirrors the shape of
``core.policy.StrategyPolicy``: frozen-dataclass policies with a stable
``identity()``, composable through :func:`admission_chain`, resolved per
request against an :class:`AdmissionContext` snapshot of engine load.
The engine consults the policy at ``submit()`` and again on every
admission pass (a request that was admissible when queued may have
blown its deadline by the time a KV row frees up).

A policy returns an :class:`Admit` or :class:`Shed` decision — or
``None`` to *decline*, meaningful inside :func:`admission_chain`, where
the first non-``None`` decision wins and the chain defaults to admit.
Shedding is a **typed result, not a stranded queue entry**: the request
terminates as ``Shed(reason)`` (reason is a :class:`RejectedRequest`
instance) and is returned from ``run()``/``drain()`` like any finished
request, with ``stats["shed"]`` counting it.

This module also owns the request-terminal taxonomy (every submitted
request ends in exactly one of :class:`Finished` / :class:`Shed` /
:class:`Failed`) and the typed :class:`RejectedRequest` exception
hierarchy that ``submit()`` raises for malformed requests — shared with
admission results so ``Overloaded`` can either be raised (hard reject)
or carried inside a ``Shed`` (soft shed), with identical ``str()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# -- typed rejects -----------------------------------------------------------
# ``RejectedRequest`` subclasses ValueError so every pre-existing caller
# (and test) catching the engine's old bare ValueErrors keeps working;
# the old messages are preserved verbatim as the subclass __str__s.


class RejectedRequest(ValueError):
    """A request the engine refuses to take responsibility for."""

    kind = "rejected"


class EmptyPrompt(RejectedRequest):
    kind = "empty_prompt"

    def __init__(self, msg: str = "empty prompt"):
        super().__init__(msg)


class PromptOverflow(RejectedRequest):
    """Prompt cannot fit ``s_max`` (needs at least one decode slot)."""

    kind = "prompt_overflow"


class ChunkingDisabled(RejectedRequest):
    """Prompt exceeds the largest prefill bucket and chunked prefill is
    off."""

    kind = "chunking_disabled"


class UnchunkablePrompt(RejectedRequest):
    """No chunk schedule fits the prompt within ``s_max``."""

    kind = "unchunkable"


class Overloaded(RejectedRequest):
    """Load shed: the engine cannot serve this request in time.  Raised
    by ``submit()`` for hard rejects (e.g. draining) and carried as the
    ``Shed.reason`` for soft sheds."""

    kind = "overloaded"


class EngineDraining(Overloaded):
    kind = "draining"

    def __init__(self, msg: str = "engine is draining"):
        super().__init__(msg)


class DeadlineExceeded(Overloaded):
    """The request's deadline or TTFT budget expired before service."""

    kind = "deadline"


# -- terminal results --------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finished:
    """The request ran to completion (eos / max_new_tokens / length)."""

    reason: str = "completed"
    ok = True


@dataclasses.dataclass(frozen=True)
class Shed:
    """The request was load-shed before completing; ``reason`` is a
    :class:`RejectedRequest` instance (or a string for engine-internal
    sheds)."""

    reason: object
    ok = False

    def __str__(self):
        return f"shed: {self.reason}"


@dataclasses.dataclass(frozen=True)
class Failed:
    """The request terminated abnormally (fault, poisoned dispatch,
    deadline blown mid-generation, stranded at drain/shutdown)."""

    reason: str
    ok = False

    def __str__(self):
        return f"failed: {self.reason}"


# -- context + policy protocol ----------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdmissionContext:
    """Engine-load snapshot a policy decides against.  ``queue_depth``
    counts *other* waiting requests (at submit time: the queue the new
    request would join)."""

    queue_depth: int
    active: int                 # decoding rows
    chunking: int               # in-progress chunked prefills
    free_rows: int              # usable KV rows (after pressure embargo)
    max_batch: int
    prompt_len: int
    priority: int
    waited_s: float             # time spent in the queue so far
    deadline_left_s: Optional[float]   # None: no deadline
    ttft_left_s: Optional[float]       # None: no TTFT budget
    # KV-capacity signals from the cache backend (serve/kv_cache.py):
    # token-granular under PagedCache, row-granular under DenseCache.
    # Defaulted so pre-paging call sites keep constructing by keyword.
    free_tokens: int = -1              # -1: backend reported nothing
    capacity_tokens: int = -1

    @property
    def occupancy(self) -> int:
        return self.active + self.chunking

    @property
    def kv_util(self) -> float:
        """Fraction of KV token capacity in use (0.0 when unreported)."""
        if self.capacity_tokens <= 0 or self.free_tokens < 0:
            return 0.0
        return 1.0 - self.free_tokens / self.capacity_tokens


@dataclasses.dataclass(frozen=True)
class Admit:
    ok = True


class AdmissionPolicy:
    """Protocol base, mirroring ``core.policy.StrategyPolicy``:
    subclasses implement ``__call__`` (returning :class:`Admit`,
    :class:`Shed`, or ``None`` to decline — meaningful only inside
    :func:`admission_chain`) and ``identity()`` (a stable hashable
    tuple, reproducible across processes).  Prefer frozen dataclasses —
    like strategy predicates, an ad-hoc closure still works but its
    identity degrades to ``id()``."""

    name = "admission"

    def __call__(self, ctx: AdmissionContext):
        raise NotImplementedError

    def identity(self) -> tuple:
        raise NotImplementedError


def _identity_of(policy) -> tuple:
    if dataclasses.is_dataclass(policy) and not isinstance(policy, type):
        return (type(policy).__module__, type(policy).__qualname__,
                dataclasses.astuple(policy))
    ident = getattr(policy, "identity", None)
    if callable(ident):
        return ident()
    return ("opaque", id(policy))


@dataclasses.dataclass(frozen=True)
class AdmitAll(AdmissionPolicy):
    """The default: every well-formed request is admitted (the
    pre-hardening engine's behavior — requests queue without bound)."""

    name = "admit_all"

    def __call__(self, ctx):
        return Admit()

    def identity(self):
        return ("admit_all",)


@dataclasses.dataclass(frozen=True)
class BoundedQueue(AdmissionPolicy):
    """Shed when the waiting queue is already ``depth`` deep — bounded
    queueing instead of unbounded latency.  Declines (defers to the
    rest of the chain) while the queue has room."""

    depth: int
    name = "bounded_queue"

    def __call__(self, ctx):
        if ctx.queue_depth >= self.depth:
            return Shed(Overloaded(
                f"queue depth {ctx.queue_depth} >= bound {self.depth}"))
        return None

    def identity(self):
        return ("bounded_queue", self.depth)


@dataclasses.dataclass(frozen=True)
class DeadlineGate(AdmissionPolicy):
    """Shed requests whose deadline or TTFT budget has already expired
    while waiting — serving them would waste decode steps on an answer
    nobody is waiting for."""

    name = "deadline_gate"

    def __call__(self, ctx):
        for left, what in ((ctx.deadline_left_s, "deadline"),
                           (ctx.ttft_left_s, "TTFT budget")):
            if left is not None and left <= 0:
                return Shed(DeadlineExceeded(
                    f"{what} expired after waiting {ctx.waited_s:.3f}s"))
        return None

    def identity(self):
        return ("deadline_gate",)


@dataclasses.dataclass(frozen=True)
class PriorityFloor(AdmissionPolicy):
    """Under load (queue at least ``when_queue_over`` deep), shed
    requests below ``min_priority`` — graceful degradation that keeps
    the high-priority tier inside its latency budget."""

    min_priority: int
    when_queue_over: int = 0
    name = "priority_floor"

    def __call__(self, ctx):
        if (ctx.queue_depth > self.when_queue_over
                and ctx.priority < self.min_priority):
            return Shed(Overloaded(
                f"priority {ctx.priority} below floor {self.min_priority} "
                f"with queue depth {ctx.queue_depth}"))
        return None

    def identity(self):
        return ("priority_floor", self.min_priority, self.when_queue_over)


@dataclasses.dataclass(frozen=True)
class PagePressure(AdmissionPolicy):
    """Shed when admitting the request would push KV token residency
    past ``max_util`` of pool capacity — the page-granular analogue of
    :class:`BoundedQueue`, fed by the cache backend's ``free_tokens`` /
    ``capacity_tokens`` signals.  Declines when the backend reports no
    capacity (dense engines constructed before the paged era, or unit
    tests with a partial context)."""

    max_util: float = 0.95
    name = "page_pressure"

    def __call__(self, ctx):
        if ctx.capacity_tokens <= 0 or ctx.free_tokens < 0:
            return None
        used = ctx.capacity_tokens - ctx.free_tokens
        if (used + ctx.prompt_len) / ctx.capacity_tokens > self.max_util:
            return Shed(Overloaded(
                f"KV pool at {ctx.kv_util:.0%} utilization; admitting a "
                f"{ctx.prompt_len}-token prompt would exceed the "
                f"{self.max_util:.0%} page-pressure ceiling"))
        return None

    def identity(self):
        return ("page_pressure", self.max_util)


class _AdmissionChain(AdmissionPolicy):
    name = "chain"

    def __init__(self, policies):
        self.policies = [p for p in policies if p is not None]

    def __call__(self, ctx):
        for p in self.policies:
            decision = p(ctx)
            if decision is not None:
                return decision
        return Admit()

    def identity(self):
        return ("chain", tuple(_identity_of(p) for p in self.policies))


def admission_chain(*policies) -> AdmissionPolicy:
    """Compose policies: the first non-``None`` decision wins; a chain
    that runs off the end admits.  Mirrors ``first_viable`` from
    ``core.policy``."""
    return _AdmissionChain(policies)


def resolve_admission(policy) -> AdmissionPolicy:
    """Normalize ``ServeConfig.admission``: ``None`` -> :class:`AdmitAll`,
    a single policy is wrapped so a declining predicate still admits."""
    if policy is None:
        return AdmitAll()
    if isinstance(policy, _AdmissionChain) or isinstance(policy, AdmitAll):
        return policy
    return _AdmissionChain([policy])
