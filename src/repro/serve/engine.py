"""Batched serving engine: bucketed prefill + continuous-batching decode.

The runtime dispatcher half of the paper's §3.3.2 story: incoming prompts
are rounded up to a shape bucket, the (plan, bucket) pair hits the
unified ``PlanStore`` (the CUDA-graph-capture analogue), and the
scheduler's plan for that bucket is replayed.  The first bucket pays the
full lowering; every further bucket shares it via fingerprint-v2
specialization.  Decode runs one static-shape step over the whole cache
pool every iteration; requests claim/release rows (continuous batching).

The engine is single-host/mesh-free here (tp=1); the launch layer wraps
the same step functions in shard_map for the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.plan_store import PlanStore
from ..core.scheduler import OpSchedulerBase, ScheduleContext
from ..models.base import build_forward
from .kv_cache import KVCacheManager


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stop early
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    row: int = -1
    submitted_s: float = 0.0
    first_token_s: float = 0.0
    done_s: float = 0.0


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    s_max: int = 256
    prefill_buckets: tuple = (32, 64, 128, 256)
    greedy: bool = True
    lowered: bool = True               # slot-based lowered plan replay
    # PlanStore budgets: bucketed serving churns through (shape, plan)
    # pairs, so both cache levels are bounded — plans by an LRU byte
    # budget, executables by entry count and an optional byte budget.
    plan_capacity: int = 256
    plan_budget_bytes: Optional[int] = 32 << 20
    exec_capacity: int = 64
    exec_budget_bytes: Optional[int] = None
    # Persistent PlanStore: when set, the engine warm-starts from this
    # file on construction (a restarted server serves every
    # previously-seen bucket without re-lowering) and checkpoints the
    # store back when the request queue drains and on ``shutdown()``.
    plan_store_path: Optional[str] = None


class ServeEngine:
    def __init__(self, model, params, scheduler: OpSchedulerBase,
                 cfg: ServeConfig):
        self.model = model
        self.params = params
        self.scheduler = scheduler
        self.cfg = cfg
        self.cache = KVCacheManager(model, cfg.max_batch, cfg.s_max)
        budgets = dict(plan_capacity=cfg.plan_capacity,
                       plan_budget_bytes=cfg.plan_budget_bytes,
                       exec_capacity=cfg.exec_capacity,
                       exec_budget_bytes=cfg.exec_budget_bytes)
        if cfg.plan_store_path:
            self.store = PlanStore.open(cfg.plan_store_path, **budgets)
        else:
            self.store = PlanStore(**budgets)
        self._op_config = model.op_closure_config()
        self.waiting: list[Request] = []
        self.active: dict[int, Request] = {}     # row -> request
        self.finished: list[Request] = []
        self._decode_fn = None
        self._stats = {"prefill_steps": 0, "decode_steps": 0,
                       "decode_tokens": 0}
        self._ck = self._cache_keys()

    # -- public -----------------------------------------------------------
    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        self.waiting.append(req)

    def run(self, max_iters: int = 10_000) -> list:
        it = 0
        while (self.waiting or self.active) and it < max_iters:
            self._admit()
            self._decode_step()
            it += 1
        # idle: the queue drained — checkpoint lowered plans so a restart
        # (or a sibling process) warm-starts instead of re-lowering
        self.checkpoint()
        return self.finished

    def checkpoint(self) -> int:
        """Persist the PlanStore when a path is configured; returns the
        number of outer entries written (0 when persistence is off or
        nothing changed since the last checkpoint — run() calls this on
        every queue drain, so a steady-state server must not rewrite an
        unchanged artifact per request)."""
        if not self.cfg.plan_store_path or not self.store.dirty:
            return 0
        return self.store.save()

    def shutdown(self) -> int:
        """Checkpoint and release; the engine stays usable afterwards but
        a well-behaved server calls this exactly once on the way out."""
        return self.checkpoint()

    @property
    def stats(self):
        out = dict(self._stats)
        out["plan_store"] = self.store.snapshot()
        return out

    # -- prefill ----------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.cfg.prefill_buckets:
            if n <= b:
                return b
        return self.cfg.prefill_buckets[-1]

    def _prefill_fn(self, bucket: int) -> Callable:
        def build():
            segs, _ = self.model.build_segments("prefill", 1, bucket,
                                                s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=1, seq_len=bucket,
                                   phase="prefill", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)

            def run(params, ids, positions):
                return fwd(params, {"ids": ids, "positions": positions})

            return jax.jit(run)

        return self.store.get_or_build(("prefill", bucket), build)

    def _admit(self):
        while self.waiting and self.cache.free_rows:
            req = self.waiting[0]
            row = self.cache.allocate(req.rid)
            if row is None:
                break
            self.waiting.pop(0)
            req.row = row
            n = len(req.prompt)
            bucket = self._bucket(n)
            ids = np.zeros((1, bucket), np.int32)
            ids[0, :n] = req.prompt[:n]
            pos = np.arange(bucket, dtype=np.int32)[None]
            out = self._prefill_fn(bucket)(
                self.params, jnp.asarray(ids), jnp.asarray(pos))
            self._stats["prefill_steps"] += 1
            stacks = {}
            for pk, pv, dk, dv in self._ck:
                stacks[dk] = out[pk][..., :n, :, :] if out[pk].ndim == 5 \
                    else out[pk][:, :n]
                stacks[dv] = out[pv][..., :n, :, :] if out[pv].ndim == 5 \
                    else out[pv][:, :n]
            tok = self._sample_from_prefill(out, n, bucket)
            # bucket-padded prompts (n < bucket): the head's last-position
            # logits are at padding, so the first decode step re-runs the
            # final prompt token at position n-1 (cache holds [0, n-1))
            # and produces the true first token — the -100 sentinel routes
            # the engine down that path.
            self.cache.write_prefill(row, stacks, n if tok >= 0 else n - 1)
            req.output.append(int(tok))
            req.first_token_s = time.perf_counter()
            self.active[row] = req

    def _sample_from_prefill(self, out, n, bucket):
        if n != bucket:
            return -100    # padded: first decode step recomputes position n-1
        return int(np.argmax(np.asarray(out["logits"][0, -1])))

    # -- decode -----------------------------------------------------------
    def _decode(self) -> Callable:
        if self._decode_fn is not None:
            return self._decode_fn

        def build():
            segs, _ = self.model.build_segments(
                "decode", self.cfg.max_batch, 1, s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=self.cfg.max_batch,
                                   seq_len=self.cfg.s_max, phase="decode",
                                   arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)

            def run(params, ids, positions, cache_len, caches):
                batch = {"ids": ids, "positions": positions,
                         "cache_len": cache_len, **caches}
                out = fwd(params, batch)
                new_caches = {k: out[k] for k in caches}
                return out["logits"], new_caches

            return jax.jit(run)

        self._decode_fn = self.store.get_or_build(("decode",), build)
        return self._decode_fn

    def _decode_step(self):
        if not self.active:
            return
        B = self.cfg.max_batch
        ids = np.zeros((B, 1), np.int32)
        for row, req in self.active.items():
            last = req.output[-1] if req.output and req.output[-1] >= 0 \
                else (req.prompt[-1] if len(req.prompt) else 0)
            ids[row, 0] = last
        clen = self.cache.cache_len_array()
        pos = np.asarray(clen).reshape(B, 1).astype(np.int32)
        logits, new_caches = self._decode()(
            self.params, jnp.asarray(ids), jnp.asarray(pos), clen,
            self.cache.caches)
        self.cache.caches = new_caches
        self._stats["decode_steps"] += 1
        toks = np.asarray(jnp.argmax(logits, axis=-1)).reshape(B)
        done_rows = []
        for row, req in list(self.active.items()):
            if req.output and req.output[0] == -100:
                req.output[0] = int(toks[row])     # first real token
            else:
                req.output.append(int(toks[row]))
            self.cache.lengths[row] += 1
            self._stats["decode_tokens"] += 1
            if (len(req.output) >= req.max_new_tokens
                    or req.output[-1] == req.eos_id
                    or self.cache.lengths[row] >= self.cfg.s_max - 1):
                done_rows.append(row)
        for row in done_rows:
            req = self.active.pop(row)
            req.done_s = time.perf_counter()
            self.finished.append(req)
            self.cache.release(row)

    # -- cache key mapping --------------------------------------------------
    def _cache_keys(self):
        """[(prefill_k, prefill_v, decode_k_cache, decode_v_cache)] pairs."""
        out = []
        pstacks = self.model.layer_stacks("prefill")
        dstacks = self.model.layer_stacks("decode")
        for ps, ds in zip(pstacks, dstacks):
            pname, _, pcount, _, psc_out = ps[:5]
            if "k" not in psc_out:
                continue
            popts = ps[5] if len(ps) > 5 else {}
            omap = popts.get("output_map", {})
            dopts = ds[5] if len(ds) > 5 else {}
            imap = dopts.get("input_map", {})
            pk = omap.get("k", f"{pname}.k" if pcount > 1 else "k")
            pv = omap.get("v", f"{pname}.v" if pcount > 1 else "v")
            out.append((pk, pv, imap.get("k_cache", "k_cache"),
                        imap.get("v_cache", "v_cache")))
        return out
