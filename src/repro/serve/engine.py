"""Tiered async serving engine: batch-tier decode captures, batched and
chunked prefill admission, a double-buffered host loop, and a hardened
request lifecycle (admission control, deadlines, preemption, fault
isolation).

The runtime dispatcher half of the paper's §3.3.2 story, grown into the
shape the backend thesis demands — a runtime that "manages complex
control/data-flow asynchronously" and "uses custom memory management to
eliminate copy overheads":

  * **Decode batch tiers.**  Decode captures are built at power-of-two
    batch tiers (1, 2, 4, …, ``max_batch``); each step runs the smallest
    tier covering the active rows instead of always paying ``max_batch``
    worth of compute.  Tiers 2..N never re-lower: the decode (graph,
    plan) pair is *structurally* identical across batch sizes, so the
    ``PlanStore`` derives every further tier from one canonical lowering
    via ``specialize()`` (the batch dimension is just another rewritten
    shape bucket; the inner store key carries the tier).  Active rows are
    compacted into the low slots on tier shrink so the tier prefix is
    always dense.

  * **Batched + chunked prefill.**  ``_admit`` packs several waiting
    requests into one bucketed prefill call (a real batch dimension with
    per-row lengths), and prompts longer than the largest bucket run as
    chunked prefill steps through the *decode* graph at chunk-sized
    query length — cached attention where chunk position ``j`` sees
    ``cache_len + j + 1`` keys — instead of crashing.  Chunk dispatch is
    **fair**: each engine iteration admits every waiting whole-prompt
    group first and then issues *one* chunk of the oldest in-progress
    chunked prefill (round-robin), so a long prompt never monopolizes
    dispatch for ``len/chunk`` consecutive iterations and short requests
    submitted behind it keep their TTFT.

  * **Async host loop.**  Sampling is on-device (argmax + eos/length
    masks inside the jitted decode step), prefill KV lands in the cache
    pool via ``dynamic_update_slice`` inside the jitted prefill step
    (donated buffers — no host-side numpy slicing on the copy path), and
    decode steps chain their sampled tokens on-device through a
    ``last_ids`` vector.  The host loop is double-buffered: step k+1 is
    dispatched before step k's small token/done vector is fetched with a
    single ``jax.device_get`` — one host sync per decode iteration
    instead of one per token-row.

  * **Request lifecycle.**  Robustness policy is decoupled from the
    dispatch machinery the same way execution policy is decoupled from
    the model (the paper's transparency claim, applied to survival):

      - *Admission control* — a pluggable ``AdmissionPolicy``
        (``serve/admission.py``) decides per request against a load
        snapshot; load shedding terminates a request as a typed
        ``Shed(reason)`` result instead of stranding it in the queue.
        Expired deadlines/TTFT budgets always shed (built-in gate).
      - *Preempt-and-requeue* — under memory pressure or when a
        higher-priority request is waiting on a full pool, the
        lowest-priority decoding row is evicted (KV row released, its
        generated tokens snapshotted host-side) and later re-admitted as
        a re-prefill over ``prompt + generated`` — through the existing
        batched or chunked prefill path, preserving the
        ≤1-sync-per-decode discipline.  Greedy decode makes the resumed
        token stream bitwise-identical to an uninterrupted run.
      - *Fault isolation* — dispatch and harvest are wrapped in
        per-request error boundaries: a targeted ``PoisonedRequest``
        terminates exactly that request as ``Failed(reason)`` and the
        dispatch retries with the survivors; an untargeted fault fails
        only the requests in that dispatch.  The engine itself never
        dies.
      - *Graceful drain* — ``drain(timeout)`` stops admitting, finishes
        in-flight rows, checkpoints the PlanStore, and reports (and
        releases) stranded work; ``shutdown()`` aborts in-flight work
        and still checkpoints.
      - *Chaos harness* — ``ServeConfig.faults`` threads a deterministic
        ``FaultInjector`` (``serve/faults.py``) through every injection
        site: allocation denial, poisoned/failed dispatches, slow
        iterations, and memory-pressure windows that shrink the KV
        pool's effective capacity.

    Every submitted request terminates in exactly one of ``Finished`` /
    ``Shed`` / ``Failed`` (``Request.result``), mirrored by the
    lifecycle counters in ``stats``.

Set ``ServeConfig(decode_tiers=(max_batch,), prefill_batch=1,
async_host=False)`` to recover the synchronous fixed-batch baseline
(benchmarked in ``benchmarks/serve_bench.py``).

The engine is single-host/mesh-free here (tp=1); the launch layer wraps
the same step functions in shard_map for the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.plan_store import PlanStore, resolve_plan_store
from ..core.scheduler import ScheduleContext
from ..models.base import build_forward
from .admission import (
    AdmissionContext,
    ChunkingDisabled,
    DeadlineExceeded,
    DeadlineGate,
    EmptyPrompt,
    EngineDraining,
    Failed,
    Finished,
    Overloaded,
    PromptOverflow,
    Shed,
    UnchunkablePrompt,
    admission_chain,
)
from .faults import PoisonedRequest
from .kv_cache import cache_backend_salt, resolve_cache_backend
from .sampling import resolve_sampling, sample_tokens, sampling_salt
from .speculative import DRAFT_K_CANDIDATES, SpecConfig, resolve_proposer


def pow2_tiers(n: int) -> tuple:
    """Power-of-two capture tiers up to and including ``n``."""
    ts, t = [], 1
    while t < n:
        ts.append(t)
        t *= 2
    ts.append(n)
    return tuple(sorted(set(ts)))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stop early
    priority: int = 0                  # higher preempts lower under load
    deadline_s: Optional[float] = None     # wall-clock budget from submit
    ttft_budget_s: Optional[float] = None  # budget to the first token
    seed: Optional[int] = None             # sampling seed (None: engine seed)
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    row: int = -1
    submitted_s: float = 0.0
    first_token_s: float = 0.0
    done_s: float = 0.0
    result: object = None              # Finished | Shed | Failed
    preemptions: int = 0
    _seq: int = dataclasses.field(default=-1, repr=False)
    _resume: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    @property
    def effective_prompt(self) -> np.ndarray:
        """The token stream a (re-)prefill must cover: the original
        prompt, or prompt + generated tokens after a preemption."""
        return self._resume if self._resume is not None else self.prompt

    @property
    def ok(self) -> bool:
        return isinstance(self.result, Finished)


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    s_max: int = 256
    prefill_buckets: tuple = (32, 64, 128, 256)
    greedy: bool = True
    lowered: bool = True               # slot-based lowered plan replay
    # On-device sampling policy (serve/sampling.py): a SamplingConfig or
    # None for greedy argmax (the historical behavior, bitwise-identical
    # compiled graph).  The policy — never any seed — salts the
    # executable-cache keys, so two engines with different seeds share
    # every capture.
    sampling: object = None
    # Engine-wide sampling seed; Request(seed=) overrides per request.
    # Seeds are runtime arguments of the captured steps and never enter
    # a PlanStore key.
    seed: int = 0
    # Speculative multi-token decode (serve/speculative.py): a
    # SpecConfig or None (plain one-token decode).  The verify step runs
    # the decode graph at query width k+1 — just another shape bucket of
    # the canonical decode lowering, so it specializes without any new
    # lower() after warm-up.
    spec: object = None
    # Tiered decode: captures at these batch sizes (ascending, last ==
    # max_batch).  None = power-of-two tiers.  A single-element tuple
    # (max_batch,) recovers the fixed-batch baseline.
    decode_tiers: Optional[tuple] = None
    # Batched prefill: pack up to this many waiting requests into one
    # prefill call (batch dim bucketed to power-of-two group tiers).
    prefill_batch: int = 4
    # Chunked prefill: prompts longer than the largest bucket run as
    # chunk-sized steps through the decode graph.  When off, oversized
    # prompts are rejected at submit() with a typed ChunkingDisabled
    # error (the pre-tiered engine raised an opaque numpy broadcast
    # error instead).
    chunked_prefill: bool = True
    # Double-buffered host loop: dispatch step k+1 before fetching step
    # k's token/done vector.  Off = harvest synchronously every step.
    async_host: bool = True
    # Admission policy (serve/admission.py).  None = admit everything
    # well-formed (the pre-hardening behavior); expired deadlines/TTFT
    # budgets shed regardless via a built-in DeadlineGate.
    admission: object = None
    # Preempt-and-requeue: evict the lowest-priority decoding row when a
    # higher-priority request waits on a full pool or a pressure window
    # shrinks effective capacity.  With uniform priorities and no
    # pressure this never triggers.
    preemption: bool = True
    # KV storage backend (serve/kv_cache.py): a CacheBackend instance,
    # the names "dense"/"paged", or None for DenseCache (today's dense
    # per-slot pool).  PagedCache allocates fixed-size pages on demand
    # from a shared pool, so KV memory scales with tokens resident and
    # admission is page-capacity, not row-count.  The backend identity
    # salts every PlanStore key, so dense and paged captures coexist in
    # one store and restore independently.
    cache: object = None
    # Chaos harness: a deterministic serve.faults.FaultInjector threaded
    # through allocation, dispatch, harvest, pacing, and capacity.
    faults: object = None
    # PlanStore budgets: bucketed serving churns through (shape, plan)
    # pairs, so both cache levels are bounded — plans by an LRU byte
    # budget, executables by entry count and an optional byte budget.
    plan_capacity: int = 256
    plan_budget_bytes: Optional[int] = 32 << 20
    exec_capacity: int = 64
    exec_budget_bytes: Optional[int] = None
    # Persistent PlanStore: when set, the engine warm-starts from this
    # file on construction (a restarted server serves every
    # previously-seen bucket without re-lowering) and checkpoints the
    # store back when the request queue drains and on ``shutdown()``.
    plan_store_path: Optional[str] = None


class ServeEngine:
    """``scheduler`` accepts an ``OpSchedulerBase`` *or* a
    ``StrategyPolicy`` (resolved per build context by ``build_forward``).
    ``plan_store`` injects an externally-owned store — the
    ``repro.api.Program`` facade passes its own warm-started store so
    every step the program builds shares one artifact; without it the
    engine opens/creates a store from ``cfg``."""

    def __init__(self, model, params, scheduler, cfg: ServeConfig,
                 plan_store: Optional[PlanStore] = None):
        self.model = model
        self.params = params
        self.scheduler = scheduler
        self.cfg = cfg
        if tuple(sorted(cfg.prefill_buckets)) != tuple(cfg.prefill_buckets):
            raise ValueError("prefill_buckets must be ascending")
        if max(cfg.prefill_buckets) > cfg.s_max:
            raise ValueError("largest prefill bucket exceeds s_max")
        self.tiers = tuple(cfg.decode_tiers or pow2_tiers(cfg.max_batch))
        if self.tiers != tuple(sorted(self.tiers)) \
                or self.tiers[-1] != cfg.max_batch:
            raise ValueError(
                f"decode_tiers must ascend to max_batch: {self.tiers}")
        self.prefill_tiers = pow2_tiers(
            max(1, min(cfg.prefill_batch, cfg.max_batch)))
        self.backend = resolve_cache_backend(cfg.cache)
        self.cache = self.backend.build(model, cfg)
        budgets = dict(plan_capacity=cfg.plan_capacity,
                       plan_budget_bytes=cfg.plan_budget_bytes,
                       exec_capacity=cfg.exec_capacity,
                       exec_budget_bytes=cfg.exec_budget_bytes)
        if plan_store is not None:
            if (cfg.plan_store_path and plan_store.path
                    and cfg.plan_store_path != plan_store.path):
                raise ValueError(
                    f"conflicting persistence targets: the injected "
                    f"PlanStore is bound to {plan_store.path!r} but "
                    f"ServeConfig.plan_store_path={cfg.plan_store_path!r}"
                    "; drop one of them")
            self.store = resolve_plan_store(plan_store,
                                            cfg.plan_store_path)
            # a shared store keeps its own budgets unless this config
            # explicitly overrides them (non-default values win — the
            # facade path must not silently drop a user's byte caps)
            defaults = ServeConfig()
            for field, val in budgets.items():
                if val != getattr(defaults, field):
                    setattr(self.store, field, val)
        elif cfg.plan_store_path:
            self.store = PlanStore.open(cfg.plan_store_path, **budgets)
        else:
            self.store = PlanStore(**budgets)
        # the cache backend changes what the jitted steps close over
        # (pool layout, gather/scatter paths), so its identity salts the
        # plan-level outer key — dense and paged captures coexist in one
        # persisted store and restore independently — and a short digest
        # of it tags the exec-level step-cache keys below
        self._op_config = model.op_closure_config() + (
            ("cache_backend", self.backend.identity()),)
        self._cache_tag = cache_backend_salt(self.backend)
        # store-aware policies (AutoPolicy, possibly wrapped in a
        # PolicyScheduler adapter) persist tuning verdicts in this
        # engine's store and take live step-timing feedback
        target = getattr(scheduler, "policy", scheduler)
        bind = getattr(target, "bind_store", None)
        if callable(bind):
            bind(self.store)
        self._observer = getattr(target, "observe", None)
        self._obs_prev = None      # (tier, perf_counter) of last dispatch
        # on-device sampling: the policy (static, baked into the capture)
        # salts exec keys; seeds/rids/positions are runtime args
        self.sampling = resolve_sampling(cfg.sampling)
        self._samp_salt = sampling_salt(self.sampling)
        # speculative decode state
        if cfg.spec is not None and not isinstance(cfg.spec, SpecConfig):
            raise ValueError(
                "ServeConfig.spec must be a serve.SpecConfig or None")
        self._spec = cfg.spec
        if self._spec is not None:
            self._proposer = resolve_proposer(self._spec.proposer)
            self._spec_sampling = resolve_sampling(
                self._spec.sampling if self._spec.sampling is not None
                else cfg.sampling)
            self._spec_salt = sampling_salt(self._spec_sampling)
            self._k_candidates = self._spec_k_candidates()
            self._k_picker = getattr(target, "spec_draft_k", None)
            kmax = (self._spec.k if isinstance(self._spec.k, int)
                    else max(self._k_candidates))
            # verify width k+1 must not exceed the smallest chunk length
            # (chunk-row garbage beyond the frontier is only overwritten
            # when the next chunk's slab covers it) nor s_max headroom
            if kmax + 1 > cfg.prefill_buckets[0]:
                raise ValueError(
                    f"speculative draft k={kmax} needs verify width "
                    f"{kmax + 1} <= the smallest prefill bucket "
                    f"{cfg.prefill_buckets[0]}")
            # rollback is length bookkeeping, which only works for
            # positional (attention) caches: recurrent SSM states
            # advance irreversibly, so a rejected draft would corrupt
            # them
            bad = [key for key in model.decode_cache_layout()
                   if not (key.endswith("k_cache")
                           or key.endswith("v_cache"))]
            if bad:
                raise ValueError(
                    "speculative decode needs positional decode caches "
                    f"(rollback = length decrement); {model.cfg.name} "
                    f"has non-positional state {bad}")
        else:
            self._proposer = None
            self._spec_sampling = self.sampling
            self._spec_salt = self._samp_salt
            self._k_candidates = DRAFT_K_CANDIDATES
            self._k_picker = None
        self._spec_t0 = 0.0        # perf_counter of the last spec dispatch
        # per-row sampling identity mirrors (compacted alongside _gen)
        self._row_seed = np.zeros((cfg.max_batch,), np.uint32)
        self._row_rid = np.zeros((cfg.max_batch,), np.int32)
        # the built-in deadline gate always runs first: a request whose
        # deadline/TTFT budget expired in the queue sheds even under the
        # default admit-everything policy
        self.admission = admission_chain(DeadlineGate(), cfg.admission)
        self._deadline_gate = admission_chain(DeadlineGate())
        self.faults = cfg.faults
        self.waiting: list[Request] = []
        self.active: dict[int, Request] = {}     # row -> request
        # in-progress chunked prefills: rows are allocated (KV filling
        # chunk by chunk) but not yet decoding; round-robin queue
        self._chunking: list[dict] = []
        self.finished: list[Request] = []
        # admission-order record: ("prefill", rids) / ("chunk", rid)
        # tuples in dispatch order — the fairness contract's test surface
        self.dispatch_log: list[tuple] = []
        # device-resident loop state: the sampled token of every row's
        # last decode step, chained into the next step without touching
        # the host (the async half of the double-buffered loop)
        self._last_ids = jnp.zeros((cfg.max_batch, 1), jnp.int32)
        self._gen = np.zeros((cfg.max_batch,), np.int32)   # tokens sampled
        self._pending = None               # in-flight decode step handle
        self._pending_prefill: list = []   # [(tok_dev, [(slot, req), ...])]
        self._seq = 0                      # submission order tiebreaker
        self._iter = 0                     # engine iteration counter
        self._cur_iter = 0                 # iteration the loop is inside
        self._draining = False
        self._stats = {"prefill_steps": 0, "prefill_reqs": 0,
                       "chunk_steps": 0, "decode_steps": 0,
                       "decode_tokens": 0, "host_syncs": 0, "row_moves": 0,
                       "submitted": 0, "admitted": 0, "finished": 0,
                       "shed": 0, "failed": 0, "preempted": 0,
                       "resumed": 0, "deadline_missed": 0,
                       "alloc_denied": 0, "page_denied": 0,
                       "peak_active": 0, "stranded": 0, "drains": 0,
                       "spec_steps": 0, "spec_drafted": 0,
                       "spec_accepted": 0, "spec_rollbacks": 0,
                       "spec_fallbacks": 0, "spec_builds": {},
                       "tier_steps": {t: 0 for t in self.tiers},
                       "tier_builds": {}}
        self._ck = self._cache_keys()

    # -- public -----------------------------------------------------------
    def submit(self, req: Request):
        """Validate and enqueue one request.

        Malformed requests raise a typed :class:`RejectedRequest`
        subclass (all are ``ValueError``s, with the historical
        messages).  A request the admission policy sheds at the door
        terminates immediately as ``Shed(Overloaded)`` — it appears in
        ``finished``/``run()`` like any other terminal request — and
        the ``Shed`` decision is returned; ``None`` means admitted."""
        if self._draining:
            raise EngineDraining()
        self._stats["submitted"] += 1
        n = len(req.prompt)
        if n < 1:
            raise EmptyPrompt("empty prompt")
        if n > self.cfg.s_max - 1:
            raise PromptOverflow(
                f"prompt length {n} cannot fit s_max={self.cfg.s_max} "
                "(need at least one decode slot)")
        if self.cache.paged and (self.cache.pages_needed(n + 1)
                                 > self.cache.num_pages):
            raise PromptOverflow(
                f"prompt length {n} needs "
                f"{self.cache.pages_needed(n + 1)} KV pages but the pool "
                f"holds only {self.cache.num_pages} in total")
        if n > self.cfg.prefill_buckets[-1]:
            if not self.cfg.chunked_prefill:
                raise ChunkingDisabled(
                    f"prompt length {n} exceeds the largest prefill bucket "
                    f"{self.cfg.prefill_buckets[-1]} and chunked prefill "
                    "is disabled")
            self._chunk_plan(n)            # raises if it cannot be chunked
        req.submitted_s = time.perf_counter()
        req._seq = self._seq
        self._seq += 1
        decision = self._decide(req, req.submitted_s)
        if isinstance(decision, Shed):
            self._shed_request(req, decision.reason)
            return decision
        self._stats["admitted"] += 1
        self.waiting.append(req)
        return None

    def step(self) -> bool:
        """One engine iteration: admit, dispatch, harvest.  Returns
        True while work remains (the unit ``run()`` loops over; exposed
        so drains and chaos tests can pace the loop themselves)."""
        it = self._iter
        self._iter += 1
        self._cur_iter = it
        if self.faults is not None:
            self.faults.on_iter(it)        # injected straggler
        self._admit()
        handle = self._dispatch_decode()
        if self._spec is not None:
            # speculative steps harvest synchronously: how far each row
            # advanced (the accepted count) is data-dependent, so the
            # host mirrors cannot move at dispatch time.  Still exactly
            # one device_get per decode iteration.
            self._harvest(handle)
        elif self.cfg.async_host:
            # double-buffered: step k+1 is now in flight; only then
            # pay the (single) host sync for step k's tokens
            prev, self._pending = self._pending, handle
            self._harvest(prev)
        else:
            self._harvest(handle)
        return self._busy()

    def run(self, max_iters: int = 10_000) -> list:
        """Drive the loop until every request terminates (or
        ``max_iters``).  Exhausting the iteration budget no longer
        strands in-flight work silently: survivors terminate as
        ``Failed``, their KV rows are released, and
        ``stats["stranded"]`` counts them."""
        it = 0
        while self._busy() and it < max_iters:
            self.step()
            it += 1
        if self._busy():
            self._strand(f"run() exhausted max_iters={max_iters}")
        # idle: the queue drained — checkpoint lowered plans so a restart
        # (or a sibling process) warm-starts instead of re-lowering
        self.checkpoint()
        return self.finished

    def drain(self, timeout: Optional[float] = None) -> dict:
        """Graceful drain: stop admitting (``submit`` raises
        :class:`EngineDraining`; already-queued requests shed), finish
        every in-flight row, checkpoint the PlanStore, and report.  On
        ``timeout`` (seconds of wall clock) the survivors are stranded:
        terminated as ``Failed``, rows released, rids reported."""
        self._draining = True
        try:
            for req in list(self.waiting):
                self._shed_request(req, EngineDraining(
                    "shed from the queue by drain()"))
            self.waiting = []
            t0 = time.perf_counter()
            stranded: list = []
            it = 0
            while self._inflight():
                if timeout is not None \
                        and time.perf_counter() - t0 > timeout:
                    stranded = self._strand(
                        f"stranded at drain(timeout={timeout})")
                    break
                self.step()
                it += 1
            n = self.checkpoint()
            self._stats["drains"] += 1
            return {"iters": it, "checkpointed": n,
                    "stranded": stranded,
                    "finished": self._stats["finished"],
                    "shed": self._stats["shed"],
                    "failed": self._stats["failed"],
                    "free_rows": len(self.cache.free_rows)}
        finally:
            self._draining = False

    def warmup(self, tiers: Optional[tuple] = None):
        """Build decode captures ahead of traffic (all tiers by default)
        so tier switches under load never hit a cold build."""
        for t in tiers or self.tiers:
            self._decode_fn(t)
            if self._spec is not None:
                ks = ([self._spec.k] if isinstance(self._spec.k, int)
                      else list(self._k_candidates))
                for k in ks:
                    # after _decode_fn(t): the canonical decode lowering
                    # exists, so verify buckets purely specialize
                    self._spec_verify_fn(t, k)
                    if self._proposer.device:
                        self._spec_draft_fn(t, k)

    def checkpoint(self) -> int:
        """Persist the PlanStore when it is path-bound (via
        ``cfg.plan_store_path`` or an injected store); returns the number
        of outer entries written (0 when persistence is off or nothing
        changed since the last checkpoint — run() calls this on every
        queue drain, so a steady-state server must not rewrite an
        unchanged artifact per request)."""
        if not self.store.path or not self.store.dirty:
            return 0
        return self.store.save()

    def shutdown(self) -> int:
        """Abort in-flight work and checkpoint.  Rows held by active,
        chunking, or pending requests are released (those requests
        terminate as ``Failed``/``Shed``) so the pool leaks nothing,
        and the PlanStore checkpoint still runs — a mid-chunked-prefill
        shutdown must not lose the lowered plans it already paid for.
        The engine stays usable afterwards but a well-behaved server
        calls this exactly once on the way out."""
        if self._busy():
            self._strand("engine shutdown")
        return self.checkpoint()

    @property
    def stats(self):
        out = dict(self._stats)
        out["tier_steps"] = dict(self._stats["tier_steps"])
        out["plan_store"] = self.store.snapshot()
        out["kv"] = self.cache.kv_stats()
        if self.faults is not None:
            out["faults"] = self.faults.counts
        return out

    # -- lifecycle --------------------------------------------------------
    def _busy(self) -> bool:
        return bool(self.waiting or self._inflight())

    def _inflight(self) -> bool:
        return bool(self.active or self._chunking
                    or self._pending is not None or self._pending_prefill)

    def _decide(self, req: Request, now: float, chain=None):
        """Run the admission chain against a load snapshot."""
        waited = max(0.0, now - req.submitted_s)
        deadline_left = (req.submitted_s + req.deadline_s - now
                         if req.deadline_s is not None else None)
        ttft_left = (req.submitted_s + req.ttft_budget_s - now
                     if req.ttft_budget_s is not None
                     and not req.first_token_s else None)
        ctx = AdmissionContext(
            queue_depth=len(self.waiting),
            active=len(self.active), chunking=len(self._chunking),
            free_rows=len(self._usable_free_rows()),
            max_batch=self.cfg.max_batch,
            prompt_len=len(req.effective_prompt), priority=req.priority,
            waited_s=waited, deadline_left_s=deadline_left,
            ttft_left_s=ttft_left,
            free_tokens=self.cache.free_tokens(),
            capacity_tokens=self.cache.token_capacity())
        return (chain or self.admission)(ctx)

    def _release_row_of(self, req: Request):
        row = req.row
        if row >= 0 and self.cache.row_owner.get(row) == req.rid:
            self.active.pop(row, None)
            self.cache.release(row)
            self._gen[row] = 0
        req.row = -1

    def _shed_request(self, req: Request, reason):
        """Terminate a request as ``Shed(reason)`` — a typed result,
        not a stranded queue entry."""
        if req.done_s:
            return
        req.done_s = time.perf_counter()
        req.result = Shed(reason)
        self._release_row_of(req)
        self._chunking = [st for st in self._chunking
                          if st["req"] is not req]
        self._stats["shed"] += 1
        if isinstance(reason, DeadlineExceeded):
            self._stats["deadline_missed"] += 1
        self.finished.append(req)

    def _fail_request(self, req: Request, reason):
        """Per-request error boundary sink: terminate as
        ``Failed(reason)``, release the KV row, keep the engine alive."""
        if req.done_s:
            return
        req.done_s = time.perf_counter()
        req.result = Failed(str(reason))
        self._release_row_of(req)
        self._chunking = [st for st in self._chunking
                          if st["req"] is not req]
        self._stats["failed"] += 1
        self.finished.append(req)

    def _finish(self, req: Request, now: float):
        self.active.pop(req.row, None)
        if req.row >= 0 and self.cache.row_owner.get(req.row) == req.rid:
            self.cache.release(req.row)
            self._gen[req.row] = 0
        req.row = -1
        req.done_s = now
        req.result = Finished()
        self._stats["finished"] += 1
        self.finished.append(req)

    def _deadline_blown(self, req: Request, now: float) -> bool:
        return (req.deadline_s is not None
                and now > req.submitted_s + req.deadline_s)

    def _fail_deadline(self, req: Request, now: float):
        self._stats["deadline_missed"] += 1
        self._fail_request(
            req, f"deadline {req.deadline_s}s exceeded after "
                 f"{len(req.output)} tokens")

    def _strand(self, reason: str) -> list:
        """Release every in-flight row and terminate its request
        (active/chunking -> ``Failed``, queued -> ``Shed``); returns the
        stranded rids.  Flushes the pending step first so tokens the
        device already produced are kept."""
        self._flush_pending()
        inflight = list(self.active.values()) \
            + [st["req"] for st in self._chunking]
        for req in inflight:
            self._stats["stranded"] += 1
            self._fail_request(req, reason)
        for req in list(self.waiting):
            self._shed_request(req, Overloaded(reason))
        self.waiting = []
        self._chunking = []
        self._pending = None
        return [r.rid for r in inflight]

    def _flush_pending(self):
        """Synchronize: harvest the in-flight decode step and any
        pending prefill first-token vectors so every request's host-side
        token list is current (preemption snapshots depend on this)."""
        if self._pending is not None or self._pending_prefill:
            self._harvest(self._pending)
            self._pending = None

    # -- admission --------------------------------------------------------
    def _bucket(self, n: int) -> int:
        for b in self.cfg.prefill_buckets:
            if n <= b:
                return b
        return self.cfg.prefill_buckets[-1]

    def _tier_for(self, n: int, tiers: tuple) -> int:
        for t in tiers:
            if t >= n:
                return t
        return tiers[-1]

    def _req_seed(self, req: Request) -> np.uint32:
        return np.uint32(req.seed if req.seed is not None
                         else self.cfg.seed)

    def _spec_k_candidates(self) -> tuple:
        """Draft-k candidates for ``SpecConfig(k="auto")``: the
        registered ``spec_decode`` param_space when present, else the
        built-in set."""
        try:
            from ..core.strategies import registry as _registry
            space = dict(_registry.get_entry("spec_decode").param_space)
            ks = tuple(int(v) for v in space.get("draft_k", ()))
            if ks:
                return ks
        except Exception:                           # noqa: BLE001
            pass
        return DRAFT_K_CANDIDATES

    def _pressure_rows(self) -> int:
        return (self.faults.pressure_rows(self._cur_iter)
                if self.faults is not None else 0)

    def _capacity(self) -> int:
        """Effective pool capacity: ``max_batch`` minus any rows
        embargoed by an injected memory-pressure window."""
        return max(0, self.cfg.max_batch - self._pressure_rows())

    def _usable_free_rows(self) -> list:
        """Free rows the engine may actually hand out right now —
        truncated so occupancy never exceeds the effective capacity."""
        occ = len(self.active) + len(self._chunking)
        room = max(0, self._capacity() - occ)
        return self.cache.free_rows[:room]

    def _try_allocate(self, req: Request) -> Optional[int]:
        """Allocate a KV row under admission control: denies under
        pressure-shrunk capacity and injected allocation faults (the
        request stays queued — exhaustion is an admission signal, not
        an exception)."""
        if not self._usable_free_rows():
            return None
        if self.faults is not None and self.faults.deny_alloc():
            self._stats["alloc_denied"] += 1
            return None
        row = self.cache.allocate(req.rid)
        if row is None:
            return None
        # paged backends reserve the whole (effective) prompt's pages up
        # front — chunked prefill then never exhausts mid-prompt, and a
        # shortfall is an admission signal (the request keeps waiting for
        # decodes to finish and free pages), not an exception.  The +1
        # covers the first decode write at position len(prompt).
        if not self.cache.reserve(row, len(req.effective_prompt) + 1):
            self.cache.release(row)
            self._stats["page_denied"] += 1
            return None
        return row

    def _shed_expired(self, now: float):
        """Re-check *deadlines* over the queue: a request that was
        admissible at submit may have blown its deadline/TTFT budget
        while waiting for a row.  Load policies (bounded queue,
        priority floors) do NOT re-run here — admission is a one-time
        gate, and re-applying a depth bound to already-admitted work
        would shed the very queue it admitted."""
        keep = []
        for req in self.waiting:
            decision = self._decide(req, now, chain=self._deadline_gate)
            if isinstance(decision, Shed):
                self._shed_request(req, decision.reason)
            else:
                keep.append(req)
        self.waiting = keep

    def _admit(self):
        """Fair admission under lifecycle control: shed expired work,
        preempt if pressure/priority demands it, then admit waiting
        whole-prompt groups (highest priority first, submission order
        within a priority) and exactly one chunk of the oldest
        in-progress chunked prefill per iteration (round-robin).  An
        oversized prompt at the queue head only *stages* its chunk
        state — its chunks interleave with later iterations' admits
        instead of monopolizing dispatch for ``len/chunk`` consecutive
        steps."""
        now = time.perf_counter()
        self._shed_expired(now)
        self._maybe_preempt()
        big = self.cfg.prefill_buckets[-1]
        self.waiting.sort(key=lambda r: (-r.priority, r._seq))
        while self.waiting:
            if not self._usable_free_rows():
                break
            head = self.waiting[0]
            if len(head.effective_prompt) > big:
                row = self._try_allocate(head)
                if row is None:
                    break
                self._start_chunked(self.waiting.pop(0), row)
                continue
            group, denied = [], False
            while (self.waiting and len(group) < self.cfg.prefill_batch
                   and len(self.waiting[0].effective_prompt) <= big):
                row = self._try_allocate(self.waiting[0])
                if row is None:
                    denied = True
                    break
                req = self.waiting.pop(0)
                req.row = row
                group.append(req)
            if group:
                self._dispatch_prefill(group)
            if denied or not group:
                break
        self._step_chunked()

    # -- preemption -------------------------------------------------------
    def _maybe_preempt(self):
        """Evict decoding rows when the pool must shrink (pressure
        window pushed occupancy over capacity) or a waiting request
        outranks the lowest-priority decoding row on a full pool.  The
        victim's generated tokens are snapshotted host-side, its KV row
        released, and it re-enters the queue as a re-prefill over
        ``prompt + generated`` (chunked when the combined length
        exceeds the largest bucket)."""
        if not self.cfg.preemption:
            return
        # capacity eviction: occupancy must fit the pressured pool
        while (len(self.active) + len(self._chunking) > self._capacity()
               and self._preempt_one()):
            pass
        # priority eviction: one per iteration is enough — admission
        # takes the freed row immediately after
        if self.waiting and not self._usable_free_rows() and self.active:
            best = max(r.priority for r in self.waiting)
            live = [r for r in self.active.values() if not r.done_s]
            if live and best > min(r.priority for r in live):
                self._preempt_one(max_priority=best - 1)

    def _preempt_one(self, max_priority: Optional[int] = None) -> bool:
        self._flush_pending()
        victims = [r for r in self.active.values()
                   if not r.done_s and r.output
                   and r.output[-1] != -100
                   and (max_priority is None
                        or r.priority <= max_priority)]
        if not victims:
            return False
        # lowest priority first; youngest within a priority (the oldest
        # request has waited longest for its tokens)
        victim = min(victims, key=lambda r: (r.priority, -r._seq))
        self.active.pop(victim.row, None)
        self.cache.release(victim.row)
        self._gen[victim.row] = 0
        victim.row = -1
        victim.preemptions += 1
        victim._resume = np.concatenate(
            [np.asarray(victim.prompt, np.int32),
             np.asarray(victim.output, np.int32)])
        self.waiting.append(victim)
        self._stats["preempted"] += 1
        return True

    # -- prefill ----------------------------------------------------------
    def _dispatch_prefill(self, group: list):
        """One bucketed prefill call over a real batch of requests.

        The jitted step writes each row's KV straight into the donated
        cache pool (``dynamic_update_slice`` at the row index) and
        samples the first token on-device; the host fetches the tiny
        token vector together with the next decode harvest.  Group slots
        are padded up to a power-of-two tier; padded slots alias a real
        row and are unrolled *first* so the real row's write wins.

        Error boundary: a ``PoisonedRequest`` excises exactly the named
        request (it terminates as ``Failed``) and the dispatch retries
        with the survivors; any other dispatch exception fails the
        whole group — never the engine.
        """
        while group:
            bp = self._tier_for(len(group), self.prefill_tiers)
            prompts = [r.effective_prompt for r in group]
            bucket = self._bucket(max(len(p) for p in prompts))
            ids = np.zeros((bp, bucket), np.int32)
            rows = np.full((bp,), group[0].row, np.int32)
            full = np.zeros((bp,), bool)
            sent_last = np.zeros((bp,), np.int32)
            seeds = np.zeros((bp,), np.uint32)
            rids = np.zeros((bp,), np.int32)
            pos_emit = np.zeros((bp,), np.int32)
            for j, (req, pr) in enumerate(zip(group, prompts)):
                n = len(pr)
                ids[j, :n] = pr[:n]
                rows[j] = req.row
                full[j] = n == bucket
                sent_last[j] = int(pr[n - 1])
                seeds[j] = self._req_seed(req)
                rids[j] = req.rid
                pos_emit[j] = n       # a full bucket emits position n
                self._row_seed[req.row] = seeds[j]
                self._row_rid[req.row] = req.rid
            try:
                if self.faults is not None:
                    self.faults.check_dispatch(
                        "prefill", [r.rid for r in group])
                fn = self._prefill_fn(bp, bucket)
                args = [self.params, jnp.asarray(ids), jnp.asarray(rows),
                        jnp.asarray(full), jnp.asarray(sent_last),
                        jnp.asarray(seeds), jnp.asarray(rids),
                        jnp.asarray(pos_emit),
                        self.cache.caches, self._last_ids]
                if self.cache.paged:
                    args.append(self.cache.page_table_array())
                tok, self.cache.caches, self._last_ids = fn(*args)
            except PoisonedRequest as e:
                bad = next(r for r in group if r.rid == e.rid)
                self._fail_request(bad, e)
                group = [r for r in group if r is not bad]
                continue
            except Exception as e:                  # noqa: BLE001
                for req in group:
                    self._fail_request(req, f"prefill dispatch failed: {e}")
                return
            slots = []
            for j, (req, pr) in enumerate(zip(group, prompts)):
                n = len(pr)
                # tokens already generated pre-preemption count toward
                # max_new_tokens; a fresh request starts at 0
                base = len(req.output)
                if req._resume is not None:
                    self._stats["resumed"] += 1
                self._gen[req.row] = base + (1 if full[j] else 0)
                self.cache.lengths[req.row] = n if full[j] else n - 1
                self.active[req.row] = req
                if full[j]:
                    slots.append((j, req))
                else:
                    # bucket-padded: the cache holds [0, n-1); the first
                    # decode step re-runs the last token at position n-1
                    # and yields the true next token (the -100 sentinel
                    # routes the harvest down the replace path).
                    req.output.append(-100)
            self._stats["prefill_steps"] += 1
            self._stats["prefill_reqs"] += len(group)
            self.dispatch_log.append(("prefill",
                                      tuple(r.rid for r in group)))
            if slots:
                self._pending_prefill.append((tok, slots))
            return

    def _prefill_fn(self, bp: int, bucket: int) -> Callable:
        def build():
            segs, _ = self.model.build_segments("prefill", bp, bucket,
                                                s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=bp, seq_len=bucket,
                                   phase="prefill", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)
            ck = self._ck
            cache = self.cache
            bds = cache.batch_dims
            samp = self.sampling

            if cache.paged:
                nb = bucket // cache.page_size

                def run(params, ids, rows, full, sent_last, seeds, rids,
                        pos_emit, caches, last_ids, page_tab):
                    pos = jnp.broadcast_to(
                        jnp.arange(bucket, dtype=jnp.int32), (bp, bucket))
                    out = fwd(params, {"ids": ids, "positions": pos})
                    tok = sample_tokens(out["logits"][:, -1, :], samp,
                                        seeds=seeds, rids=rids,
                                        positions=pos_emit)
                    caches = dict(caches)
                    li = last_ids[:, 0]
                    # reversed: padded slots alias rows[0]'s page-table
                    # row, so slot 0's real write lands last and wins;
                    # bucket tail beyond a row's reserved pages scatters
                    # into the trash page
                    for j in reversed(range(bp)):
                        r = rows[j]
                        pt_row = jnp.take(page_tab, r, axis=0)
                        for pk, pv, dk, dv in ck:
                            for src, dst in ((pk, dk), (pv, dv)):
                                axis = 1 if bds[dst] else 0
                                slab = lax.slice_in_dim(out[src], j, j + 1,
                                                        axis=axis)
                                caches.update(cache.scatter_row_pages(
                                    {dst: caches[dst]}, {dst: slab},
                                    pt_row, 0, nb, 0, bucket))
                        li = li.at[r].set(
                            jnp.where(full[j], tok[j], sent_last[j]))
                    return tok, caches, li[:, None]

                return _jit(run, donate=(8, 9))

            def run(params, ids, rows, full, sent_last, seeds, rids,
                    pos_emit, caches, last_ids):
                pos = jnp.broadcast_to(jnp.arange(bucket, dtype=jnp.int32),
                                       (bp, bucket))
                out = fwd(params, {"ids": ids, "positions": pos})
                tok = sample_tokens(out["logits"][:, -1, :], samp,
                                    seeds=seeds, rids=rids,
                                    positions=pos_emit)
                caches = dict(caches)
                li = last_ids[:, 0]
                # reversed: padded slots (which alias rows[0]) run first,
                # so slot 0's real write lands last and wins
                for j in reversed(range(bp)):
                    r = rows[j]
                    for pk, pv, dk, dv in ck:
                        for src, dst in ((pk, dk), (pv, dv)):
                            val = out[src]
                            c = caches[dst]
                            if bds[dst]:            # stacked (L,B,S,...)
                                slab = lax.slice_in_dim(val, j, j + 1,
                                                        axis=1)
                                start = (0, r) + (0,) * (c.ndim - 2)
                            else:                   # per-layer (B,S,...)
                                slab = lax.slice_in_dim(val, j, j + 1,
                                                        axis=0)
                                start = (r,) + (0,) * (c.ndim - 1)
                            caches[dst] = lax.dynamic_update_slice(
                                c, slab.astype(c.dtype), start)
                    li = li.at[r].set(
                        jnp.where(full[j], tok[j], sent_last[j]))
                return tok, caches, li[:, None]

            return _jit(run, donate=(8, 9))

        return self.store.get_or_build(
            ("prefill", self._cache_tag, self._samp_salt, bp, bucket),
            build)

    # -- chunked prefill --------------------------------------------------
    def _chunk_plan(self, n: int) -> list:
        """Chunk schedule [(offset, chunk_len)] filling the cache up to
        position ``n - 1`` (the sentinel decode step recomputes the final
        prompt position and yields the first token).  Chunk lengths are
        prefill buckets so their decode-graph captures are shared; the
        final chunk may overhang ``n - 1`` (padding is masked by
        ``cache_len``) but must never overhang ``s_max``, where the
        clamped cache write would corrupt earlier positions."""
        buckets = self.cfg.prefill_buckets
        big = buckets[-1]
        chunks, off, target = [], 0, n - 1
        while off < target:
            rem = target - off
            c = big if rem >= big else next(b for b in buckets if b >= rem)
            if off + c > self.cfg.s_max:
                fits = [b for b in buckets
                        if b >= rem and off + b <= self.cfg.s_max]
                if not fits:
                    raise UnchunkablePrompt(
                        f"prompt length {n} cannot be chunk-prefilled "
                        f"within s_max={self.cfg.s_max} with buckets "
                        f"{buckets}")
                c = fits[0]
            chunks.append((off, c))
            off += c
        return chunks

    def _start_chunked(self, req: Request, row: int):
        """Stage a prompt longer than the largest bucket for chunked
        prefill through the decode graph: bind its (pre-allocated) row
        and queue the chunk schedule; ``_step_chunked`` dispatches one
        chunk per engine iteration."""
        req.row = row
        prompt = np.asarray(req.effective_prompt, np.int32)
        n = len(prompt)
        try:
            chunks = self._chunk_plan(n)
        except UnchunkablePrompt as e:
            # resumed prompts grew past submit-time validation
            self._fail_request(req, e)
            return
        if req._resume is not None:
            self._stats["resumed"] += 1
        self._row_seed[row] = self._req_seed(req)
        self._row_rid[row] = req.rid
        # chunks cover [0, n-1) and may fall exactly one token short of
        # the prompt (position n-1 travels via the sentinel decode), so
        # size the staging buffer for whichever is longer
        padded = np.zeros(max(n, chunks[-1][0] + chunks[-1][1]), np.int32)
        padded[:n] = prompt
        self._chunking.append({"req": req, "prompt": prompt,
                               "padded": padded, "chunks": chunks,
                               "next": 0})

    def _step_chunked(self):
        """Dispatch the pending chunk of the round-robin head — packed
        with every other in-progress chunked prefill whose next chunk
        has the *same* length (one bucketed call over a real batch
        dimension, batch padded to a power-of-two slab tier), writing
        their KV in-place; when a request's final chunk is in flight it
        joins ``active`` and its first token arrives via the sentinel
        decode step like any bucket-padded prefill.  No host sync here.
        A dispatch fault fails exactly the packed requests."""
        if not self._chunking:
            return
        head = self._chunking.pop(0)
        c = head["chunks"][head["next"]][1]
        batch = [head]
        keep = []
        for st in self._chunking:
            if (len(batch) < self.cfg.prefill_batch
                    and st["chunks"][st["next"]][1] == c):
                batch.append(st)
            else:
                keep.append(st)
        self._chunking = keep
        bc = self._tier_for(len(batch), self.prefill_tiers)
        ids = np.zeros((bc, c), np.int32)
        offs = np.zeros((bc,), np.int32)
        rows = np.full((bc,), batch[0]["req"].row, np.int32)
        for j, st in enumerate(batch):
            off = st["chunks"][st["next"]][0]
            ids[j] = st["padded"][off:off + c]
            offs[j] = off
            rows[j] = st["req"].row
        # padded slots duplicate slot 0: identical writes are order-safe
        for j in range(len(batch), bc):
            ids[j], offs[j] = ids[0], offs[0]
        try:
            if self.faults is not None:
                self.faults.check_dispatch(
                    "chunk", [st["req"].rid for st in batch])
            fn = self._chunk_fn(bc, c)
            args = [self.params, jnp.asarray(ids), jnp.asarray(offs),
                    jnp.asarray(rows), self.cache.caches]
            if self.cache.paged:
                args.append(self.cache.page_table_array())
            self.cache.caches = fn(*args)
        except Exception as e:                      # noqa: BLE001
            for st in batch:
                self._fail_request(st["req"], f"chunk dispatch failed: {e}")
            return
        self._stats["chunk_steps"] += 1
        self.dispatch_log.append(
            ("chunk", tuple(st["req"].rid for st in batch)))
        for j, st in enumerate(batch):
            req, row = st["req"], st["req"].row
            off = int(offs[j])
            st["next"] += 1
            if st["next"] < len(st["chunks"]):
                # keep the host length mirror at the chunk frontier: a
                # decode step interleaved before the next chunk writes
                # one garbage k/v at this position for the (inactive)
                # row, and the next chunk's full-slab write overwrites it
                self.cache.lengths[row] = off + c
                self._chunking.append(st)      # round-robin: to the back
                continue
            prompt = st["prompt"]
            n = len(prompt)
            self._last_ids = self._last_ids.at[row, 0].set(
                int(prompt[n - 1]))
            self.cache.lengths[row] = n - 1
            self._gen[row] = len(req.output)
            req.output.append(-100)
            self.active[row] = req

    def _chunk_fn(self, bc: int, chunk: int) -> Callable:
        def build():
            segs, _ = self.model.build_segments("decode", bc, chunk,
                                                s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=bc, seq_len=self.cfg.s_max,
                                   phase="decode", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)
            cache = self.cache
            bds = cache.batch_dims

            if cache.paged:
                nbc = chunk // cache.page_size

                def run(params, ids, offs, rows, caches, page_tab):
                    pos = offs[:, None] \
                        + jnp.arange(chunk, dtype=jnp.int32)[None]
                    pt_rows = jnp.take(page_tab, rows, axis=0)
                    rcaches = cache.gather_row_batch(caches, pt_rows)
                    out = fwd(params, {"ids": ids, "positions": pos,
                                       "cache_len": offs, **rcaches})
                    # chunk offsets are bucket sums and buckets are page
                    # multiples (validated at backend build), so each
                    # slot's slab is exactly nbc whole blocks.  Reversed
                    # unroll: padded slots duplicate slot 0, so slot 0's
                    # (identical) write lands last
                    new = dict(caches)
                    for j in reversed(range(bc)):
                        out_j = {k: lax.slice_in_dim(
                                     out[k], j, j + 1,
                                     axis=1 if bds[k] else 0)
                                 for k in caches}
                        new.update(cache.scatter_row_pages(
                            new, out_j, pt_rows[j],
                            offs[j] // cache.page_size, nbc, offs[j],
                            chunk))
                    return new

                return _jit(run, donate=(4,))

            def run(params, ids, offs, rows, caches):
                pos = offs[:, None] \
                    + jnp.arange(chunk, dtype=jnp.int32)[None]
                rcaches = {k: jnp.take(v, rows, axis=bds[k])
                           for k, v in caches.items()}
                out = fwd(params, {"ids": ids, "positions": pos,
                                   "cache_len": offs, **rcaches})
                new = dict(caches)
                for j in reversed(range(bc)):
                    for k in caches:
                        slab = lax.slice_in_dim(out[k], j, j + 1,
                                                axis=bds[k])
                        new[k] = lax.dynamic_update_slice_in_dim(
                            new[k], slab.astype(new[k].dtype), rows[j],
                            axis=bds[k])
                return new

            return _jit(run, donate=(4,))

        return self.store.get_or_build(
            ("chunk", self._cache_tag, bc, chunk), build)

    # -- decode -----------------------------------------------------------
    def _decode_fn(self, tier: int) -> Callable:
        def build():
            before = dict(self.store.stats)
            segs, _ = self.model.build_segments(
                "decode", tier, 1, s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=tier, seq_len=self.cfg.s_max,
                                   phase="decode", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)
            st = self.store.stats
            self._stats["tier_builds"][tier] = {
                k: st[k] - before[k]
                for k in ("misses", "shares", "restore_hits")}
            cache = self.cache
            bds = cache.batch_dims
            samp = self.sampling

            if cache.paged:

                def run(params, last_ids, cache_len, active, eos,
                        will_end, seeds, rids, caches, page_tab):
                    ids = lax.slice_in_dim(last_ids, 0, tier, axis=0)
                    clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                    # gather the tier's pages into the contiguous
                    # (tier, s_max, ...) view — the model forward (and
                    # its captured plan) is identical to the dense path
                    tcaches = cache.gather_rows(caches, page_tab, tier)
                    out = fwd(params, {"ids": ids,
                                       "positions": clen[:, None],
                                       "cache_len": clen, **tcaches})
                    # only the frontier block per row was written;
                    # unmapped frontiers (mid-chunk rows, freed rows in
                    # the tier prefix) scatter into the trash page
                    new_caches = cache.scatter_frontier(
                        caches, out, page_tab, cache_len, tier)
                    tok_t = sample_tokens(
                        out["logits"][:, -1, :], samp,
                        seeds=lax.slice_in_dim(seeds, 0, tier, axis=0),
                        rids=lax.slice_in_dim(rids, 0, tier, axis=0),
                        positions=clen + 1)
                    tok = lax.dynamic_update_slice(last_ids[:, 0], tok_t,
                                                   (0,))
                    tok = jnp.where(active, tok, last_ids[:, 0])
                    done = active & (will_end | (tok == eos))
                    return tok, done, tok[:, None], new_caches

                return _jit(run, donate=(1, 8))

            def run(params, last_ids, cache_len, active, eos, will_end,
                    seeds, rids, caches):
                ids = lax.slice_in_dim(last_ids, 0, tier, axis=0)
                clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                tcaches = {k: lax.slice_in_dim(v, 0, tier, axis=bds[k])
                           for k, v in caches.items()}
                out = fwd(params, {"ids": ids, "positions": clen[:, None],
                                   "cache_len": clen, **tcaches})
                new_caches = {
                    k: lax.dynamic_update_slice_in_dim(
                        caches[k], out[k].astype(caches[k].dtype), 0,
                        axis=bds[k])
                    for k in caches}
                tok_t = sample_tokens(
                    out["logits"][:, -1, :], samp,
                    seeds=lax.slice_in_dim(seeds, 0, tier, axis=0),
                    rids=lax.slice_in_dim(rids, 0, tier, axis=0),
                    positions=clen + 1)
                tok = lax.dynamic_update_slice(last_ids[:, 0], tok_t, (0,))
                tok = jnp.where(active, tok, last_ids[:, 0])
                done = active & (will_end | (tok == eos))
                return tok, done, tok[:, None], new_caches

            return _jit(run, donate=(1, 8))

        return self.store.get_or_build(
            ("decode", self._cache_tag, self._samp_salt, tier), build)

    def _compact(self, tier: int):
        """Restore the prefix invariant: every allocated row < tier —
        active requests *and* in-progress chunked prefills, whose
        partially-filled cache rows relocate the same way (cache rows
        move on-device; the in-flight step, if any, ordered ahead by
        data dependencies)."""
        chunk_rows = {st["req"].row: st for st in self._chunking}
        occupied = sorted((r for r in (*self.active, *chunk_rows)
                           if r >= tier), reverse=True)
        for src in occupied:
            dst = next(r for r in self.cache.free_rows if r < tier)
            self.cache.move_row(src, dst)
            self._last_ids = self._last_ids.at[dst].set(self._last_ids[src])
            self._gen[dst] = self._gen[src]
            self._row_seed[dst] = self._row_seed[src]
            self._row_rid[dst] = self._row_rid[src]
            if src in self.active:
                req = self.active.pop(src)
                req.row = dst
                self.active[dst] = req
            else:
                chunk_rows[src]["req"].row = dst
            self._stats["row_moves"] += 1

    def _ensure_decode_pages(self):
        """Paged backends only: every active row writes position
        ``lengths[row]`` this step, which needs a fresh page whenever the
        length crosses a page boundary (including the boundary cases a
        prefill or final chunk leaves the length exactly page-aligned).
        On pool exhaustion, preempt the lowest-priority decoding row
        (its release frees pages — the victim may itself be one of the
        short rows) and retry; rows that still cannot get a page
        terminate as ``Failed`` so the survivors keep decoding."""
        if not self.cache.paged:
            return
        while True:
            short = [row for row in sorted(self.active)
                     if not self.cache.reserve(
                         row, int(self.cache.lengths[row]) + 1)]
            if not short:
                return
            self._stats["page_denied"] += len(short)
            if self.cfg.preemption and self._preempt_one():
                continue
            for row in short:
                req = self.active.get(row)
                if req is not None:
                    self._fail_request(req, (
                        "KV page pool exhausted: no page free for the "
                        f"decode write at position {self.cache.lengths[row]}"
                        " and no preemptible victim"))
            return

    def _dispatch_decode(self):
        """Dispatch one decode step at the smallest covering tier.
        Returns an opaque handle ``(tok_dev, done_dev, snapshot)`` the
        harvest consumes — in async mode one loop iteration later.

        Error boundary: a ``PoisonedRequest`` fails exactly that row
        and the dispatch retries with the survivors; any other dispatch
        exception fails the rows in this dispatch (blast radius is the
        batch, never the engine)."""
        while self.active:
            self._ensure_decode_pages()
            if not self.active:
                return None
            B = self.cfg.max_batch
            occ = len(self.active) + len(self._chunking)
            self._stats["peak_active"] = max(self._stats["peak_active"],
                                             occ)
            # the tier must cover every allocated row: chunking rows ride
            # in the prefix (their frontier-position garbage writes are
            # overwritten by the next chunk — see _step_chunked)
            tier = self._tier_for(occ, self.tiers)
            self._compact(tier)
            if self._spec is not None:
                k = self._spec_k_for_dispatch()
                if k:
                    result = self._dispatch_spec(tier, k)
                    if result == "retry":
                        continue
                    return result
                self._stats["spec_fallbacks"] += 1
            active = np.zeros((B,), bool)
            will_end = np.zeros((B,), bool)
            eos = np.full((B,), -1, np.int32)
            snapshot = []
            for row, req in self.active.items():
                active[row] = True
                eos[row] = req.eos_id
                will_end[row] = (self._gen[row] + 1 >= req.max_new_tokens
                                 or self.cache.lengths[row] + 1
                                 >= self.cfg.s_max - 1)
                snapshot.append((row, req))
            try:
                if self.faults is not None:
                    self.faults.check_dispatch(
                        "decode", [r.rid for _, r in snapshot])
                fn = self._decode_fn(tier)
                # .copy(): on CPU jnp.asarray may alias the host buffer,
                # and these mirrors mutate between dispatch and execute
                args = [self.params, self._last_ids,
                        self.cache.cache_len_array(),
                        jnp.asarray(active), jnp.asarray(eos),
                        jnp.asarray(will_end),
                        jnp.asarray(self._row_seed.copy()),
                        jnp.asarray(self._row_rid.copy()),
                        self.cache.caches]
                if self.cache.paged:
                    args.append(self.cache.page_table_array())
                tok, done, self._last_ids, self.cache.caches = fn(*args)
            except PoisonedRequest as e:
                bad = next(r for _, r in snapshot if r.rid == e.rid)
                self._fail_request(bad, e)
                continue
            except Exception as e:                  # noqa: BLE001
                for _, req in snapshot:
                    self._fail_request(req, f"decode dispatch failed: {e}")
                return None
            # host mirrors advance at dispatch, not harvest: the device's
            # view of every row is derivable without a sync
            for row, _ in snapshot:
                self.cache.lengths[row] += 1
                self._gen[row] += 1
            self._stats["decode_steps"] += 1
            self._stats["tier_steps"][tier] += 1
            if self._observer is not None:
                self._feed_observer(tier)
            return (tok, done, snapshot)
        return None

    def _feed_observer(self, tier: int):
        """Feed the policy live step timings: the wall clock between two
        successive same-tier decode dispatches bounds one device step
        (the loop is double-buffered — dispatch N+1 waits on step N), so
        it is the cheapest honest signal that needs no extra sync."""
        t_now = time.perf_counter()
        prev = self._obs_prev
        self._obs_prev = (tier, t_now)
        if prev is None or prev[0] != tier:
            return
        try:
            self._observer(
                phase="decode", arch=self.model.cfg.name,
                local_batch=tier, seq_len=self.cfg.s_max,
                seconds=t_now - prev[1],
                stats={"decode_steps": self._stats["decode_steps"],
                       "active": len(self.active),
                       "shed": self._stats["shed"]})
        except Exception:                           # noqa: BLE001
            self._observer = None   # a broken observer never kills serving

    # -- speculative decode -----------------------------------------------
    def _pick_k(self) -> int:
        """Draft length for this iteration: the static ``SpecConfig.k``,
        or — under ``k="auto"`` — the policy's pick from measured
        acceptance (``AutoPolicy.spec_draft_k``), defaulting to 4."""
        if isinstance(self._spec.k, int):
            return self._spec.k
        if self._k_picker is not None:
            try:
                k = int(self._k_picker(arch=self.model.cfg.name,
                                       candidates=self._k_candidates))
                if k >= 1:
                    return k
            except Exception:                       # noqa: BLE001
                self._k_picker = None   # broken picker: fall back, once
        return 4 if 4 in self._k_candidates else self._k_candidates[0]

    def _spec_k_for_dispatch(self) -> int:
        """Decide whether this iteration can run speculatively and at
        what k; 0 means fall back to plain one-token decode.  A verify
        step writes ``W = k + 1`` cache positions per allocated row
        (active rows at their frontier; chunk rows write garbage the
        next chunk slab overwrites), so every row needs W positions of
        headroom and — paged — W positions of reserved pages.  Any page
        shortfall or injected allocation denial falls back rather than
        failing rows: plain decode only needs the +1 the caller already
        reserved."""
        k = self._pick_k()
        W = k + 1
        for row in self.active:
            if int(self.cache.lengths[row]) + W > self.cfg.s_max:
                return 0
        for st in self._chunking:
            off, c = st["chunks"][st["next"]]
            if c < W or int(self.cache.lengths[st["req"].row]) + W \
                    > self.cfg.s_max:
                return 0
        if self.cache.paged:
            for row in sorted(self.active):
                need = self.cache.pages_needed(
                    int(self.cache.lengths[row]) + W)
                if need > int(self.cache.blocks_used[row]):
                    if self.faults is not None \
                            and self.faults.deny_alloc():
                        self._stats["alloc_denied"] += 1
                        return 0
                if not self.cache.reserve(
                        row, int(self.cache.lengths[row]) + W):
                    self._stats["page_denied"] += 1
                    return 0
        return k

    def _dispatch_spec(self, tier: int, k: int):
        """Dispatch one speculative verify step: draft k tokens per
        active row, run the decode graph once at query width k + 1, and
        return the handle the (synchronous) harvest consumes.  Host
        mirrors do NOT advance here — how far each row moved is the
        data-dependent accepted count, applied at harvest.  Returns
        ``"retry"`` after excising a poisoned request."""
        B = self.cfg.max_batch
        active = np.zeros((B,), bool)
        eos = np.full((B,), -1, np.int32)
        gen_left = np.ones((B,), np.int32)
        snapshot = []
        for row, req in self.active.items():
            active[row] = True
            eos[row] = req.eos_id
            gen_left[row] = max(1, req.max_new_tokens - self._gen[row])
            snapshot.append((row, req))
        try:
            if self.faults is not None:
                self.faults.check_dispatch(
                    "decode", [r.rid for _, r in snapshot])
            drafts = self._make_drafts(tier, k, snapshot)
            fn = self._spec_verify_fn(tier, k)
            args = [self.params, self._last_ids,
                    self.cache.cache_len_array(),
                    jnp.asarray(active), jnp.asarray(eos),
                    jnp.asarray(gen_left),
                    jnp.asarray(self._row_seed.copy()),
                    jnp.asarray(self._row_rid.copy()),
                    drafts, self.cache.caches]
            if self.cache.paged:
                args.append(self.cache.page_table_array())
            u, n_emit, done, self._last_ids, self.cache.caches = fn(*args)
        except PoisonedRequest as e:
            bad = next(r for _, r in snapshot if r.rid == e.rid)
            self._fail_request(bad, e)
            return "retry"
        except Exception as e:                      # noqa: BLE001
            for _, req in snapshot:
                self._fail_request(req, f"decode dispatch failed: {e}")
            return None
        self._stats["decode_steps"] += 1
        self._stats["spec_steps"] += 1
        self._stats["spec_drafted"] += k * len(snapshot)
        self._stats["tier_steps"][tier] += 1
        self._spec_t0 = time.perf_counter()
        return ("spec", u, n_emit, done, snapshot, k, tier)

    def _make_drafts(self, tier: int, k: int, snapshot: list):
        """(tier, k) int32 draft tokens: device proposers run their
        captured draft step; host proposers see each row's current token
        stream (the trailing ``-100`` sentinel is a placeholder, not a
        token — popped before drafting)."""
        if self._proposer.device:
            fn = self._spec_draft_fn(tier, k)
            args = [self.params, self._last_ids,
                    self.cache.cache_len_array(),
                    jnp.asarray(self._row_seed.copy()),
                    jnp.asarray(self._row_rid.copy()),
                    self.cache.caches]
            if self.cache.paged:
                args.append(self.cache.page_table_array())
            return fn(*args)
        drafts = np.zeros((tier, k), np.int32)
        streams, rows = [], []
        for row, req in snapshot:
            s = list(req.prompt) + list(req.output)
            if s and s[-1] == -100:
                s.pop()
            streams.append(s)
            rows.append(row)
        if streams:
            got = np.asarray(self._proposer.draft(streams, k), np.int32)
            for i, row in enumerate(rows):
                drafts[row] = got[i]
        return jnp.asarray(drafts)

    def _spec_verify_fn(self, tier: int, k: int) -> Callable:
        """The verify step: the canonical decode graph at query width
        ``W = k + 1`` — just another shape bucket, so after ``warmup``
        (or any plain decode build) it *specializes* off the canonical
        decode lowering with zero new ``lower()`` calls (asserted via
        ``stats["spec_builds"]``).  Accepts the longest draft prefix
        matching what the target itself emits, plus one corrected
        token; eos / token-budget / s_max cuts mirror the plain decode
        ``will_end``/``done`` semantics position by position, which is
        what makes greedy speculative decode bitwise-identical to plain
        greedy decode."""
        W = k + 1

        def build():
            before = dict(self.store.stats)
            segs, _ = self.model.build_segments(
                "decode", tier, W, s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=tier, seq_len=self.cfg.s_max,
                                   phase="decode", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)
            st = self.store.stats
            self._stats["spec_builds"][(tier, k)] = {
                key: st[key] - before[key]
                for key in ("misses", "shares", "restore_hits")}
            cache = self.cache
            bds = cache.batch_dims
            samp = self._spec_sampling
            s_max = self.cfg.s_max

            def body(params, last_ids, clen, act, eo, gl, sd, rd,
                     drafts, tcaches):
                ids = jnp.concatenate(
                    [lax.slice_in_dim(last_ids, 0, tier, axis=0), drafts],
                    axis=1)                                   # (tier, W)
                pos = clen[:, None] \
                    + jnp.arange(W, dtype=jnp.int32)[None]    # (tier, W)
                out = fwd(params, {"ids": ids, "positions": pos,
                                   "cache_len": clen, **tcaches})
                # u[:, j]: the token the target emits at stream position
                # clen + 1 + j given the draft prefix — drawn with the
                # exact (seed, rid, position) key plain decode would use
                u = sample_tokens(out["logits"], samp,
                                  seeds=sd[:, None], rids=rd[:, None],
                                  positions=pos + 1)          # (tier, W)
                match = (drafts == u[:, :k]).astype(jnp.int32)
                m = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                n_base = m + 1            # accepted prefix + correction
                steps = jnp.arange(W, dtype=jnp.int32)[None]
                hit = (u == eo[:, None]) & (eo[:, None] >= 0) \
                    & (steps < n_base[:, None])
                any_eos = hit.any(axis=1)
                first_eos = jnp.argmax(hit, axis=1).astype(jnp.int32)
                n_emit = jnp.where(any_eos, first_eos + 1, n_base)
                n_emit = jnp.minimum(n_emit, gl)
                n_emit = jnp.minimum(n_emit, s_max - 1 - clen)
                n_emit = jnp.where(act, jnp.maximum(n_emit, 1), 0)
                new_last = jnp.take_along_axis(
                    u, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
                done = act & ((any_eos & (first_eos < n_emit))
                              | (n_emit >= gl)
                              | (clen + n_emit >= s_max - 1))
                li = last_ids[:, 0]
                li = lax.dynamic_update_slice(
                    li, jnp.where(act, new_last,
                                  lax.slice_in_dim(li, 0, tier, axis=0)),
                    (0,))
                return out, u, n_emit, done, li

            if cache.paged:

                def run(params, last_ids, cache_len, active, eos,
                        gen_left, seeds, rids, drafts, caches, page_tab):
                    clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                    sl = lambda a: lax.slice_in_dim(a, 0, tier, axis=0)  # noqa: E731
                    tcaches = cache.gather_rows(caches, page_tab, tier)
                    out, u, n_emit, done, li = body(
                        params, last_ids, clen, sl(active), sl(eos),
                        sl(gen_left), sl(seeds), sl(rids), drafts,
                        tcaches)
                    new_caches = cache.scatter_span(
                        caches, out, page_tab, cache_len, tier, W)
                    return u, n_emit, done, li[:, None], new_caches

                return _jit(run, donate=(1, 9))

            def run(params, last_ids, cache_len, active, eos, gen_left,
                    seeds, rids, drafts, caches):
                clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                sl = lambda a: lax.slice_in_dim(a, 0, tier, axis=0)  # noqa: E731
                tcaches = {ck: lax.slice_in_dim(v, 0, tier, axis=bds[ck])
                           for ck, v in caches.items()}
                out, u, n_emit, done, li = body(
                    params, last_ids, clen, sl(active), sl(eos),
                    sl(gen_left), sl(seeds), sl(rids), drafts, tcaches)
                new_caches = {
                    ck: lax.dynamic_update_slice_in_dim(
                        caches[ck], out[ck].astype(caches[ck].dtype), 0,
                        axis=bds[ck])
                    for ck in caches}
                return u, n_emit, done, li[:, None], new_caches

            return _jit(run, donate=(1, 9))

        return self.store.get_or_build(
            ("spec_verify", self._cache_tag, self._spec_salt, tier, k),
            build)

    def _spec_draft_fn(self, tier: int, k: int) -> Callable:
        """Self-speculative draft step: k width-1 decode passes through
        the first ``n`` layers of the *same* model.  The layer-stack
        ``lax.scan`` infers its length from the xs leading dim, so
        slicing the stacked params and caches to ``n`` layers replays
        the already-lowered per-layer decode plans — zero new lowers.
        Draft-step cache updates are discarded (read-only drafting);
        the verify step rewrites every touched position."""
        def build():
            stacks = self.model.layer_stacks("decode")
            scanned = [s for s in stacks if s[2] > 1]
            if len(stacks) != 1 or not scanned:
                raise ValueError(
                    "SelfSpecProposer needs a model whose decode phase "
                    "is a single scanned layer stack; "
                    f"{self.model.cfg.name} has "
                    f"{[s[0] for s in stacks]} — use the 'ngram' "
                    "proposer instead")
            stack_name, total = stacks[0][0], stacks[0][2]
            n = self._proposer.n_layers or max(1, total // 2)
            n = min(n, total)
            segs, _ = self.model.build_segments(
                "decode", tier, 1, s_max=self.cfg.s_max)
            info = ScheduleContext(local_batch=tier, seq_len=self.cfg.s_max,
                                   phase="decode", arch=self.model.cfg.name)
            fwd = build_forward(segs, self.scheduler, info,
                                lowered=self.cfg.lowered,
                                plan_cache=self.store if self.cfg.lowered
                                else None,
                                op_config=self._op_config)
            cache = self.cache
            bds = cache.batch_dims
            if any(not bds[ck] for ck in bds):
                raise ValueError(
                    "SelfSpecProposer needs stacked decode caches")
            samp = self._spec_sampling

            def body(params, last_ids, clen, sd, rd, tcaches):
                sub = dict(params)
                sub[stack_name] = jax.tree_util.tree_map(
                    lambda x: x[:n], params[stack_name])
                dc = {ck: lax.slice_in_dim(v, 0, n, axis=0)
                      for ck, v in tcaches.items()}
                cur = lax.slice_in_dim(last_ids, 0, tier, axis=0)
                cl = clen
                toks = []
                for _ in range(k):
                    out = fwd(sub, {"ids": cur, "positions": cl[:, None],
                                    "cache_len": cl, **dc})
                    tok = sample_tokens(out["logits"][:, -1, :], samp,
                                        seeds=sd, rids=rd,
                                        positions=cl + 1)
                    dc = {ck: out[ck].astype(dc[ck].dtype) for ck in dc}
                    cur = tok[:, None]
                    cl = cl + 1
                    toks.append(tok)
                return jnp.stack(toks, axis=1)                # (tier, k)

            if cache.paged:

                def run(params, last_ids, cache_len, seeds, rids, caches,
                        page_tab):
                    clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                    tcaches = cache.gather_rows(caches, page_tab, tier)
                    return body(params, last_ids, clen,
                                lax.slice_in_dim(seeds, 0, tier, axis=0),
                                lax.slice_in_dim(rids, 0, tier, axis=0),
                                tcaches)

                return _jit(run)

            def run(params, last_ids, cache_len, seeds, rids, caches):
                clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
                tcaches = {ck: lax.slice_in_dim(v, 0, tier, axis=bds[ck])
                           for ck, v in caches.items()}
                return body(params, last_ids, clen,
                            lax.slice_in_dim(seeds, 0, tier, axis=0),
                            lax.slice_in_dim(rids, 0, tier, axis=0),
                            tcaches)

            return _jit(run)

        return self.store.get_or_build(
            ("spec_draft", self._cache_tag, self._spec_salt,
             self._proposer.identity(), tier, k), build)

    # -- harvest ----------------------------------------------------------
    def _harvest(self, pending):
        """The loop's single host sync: fetch the pending decode step's
        token/done vectors (plus any prefill first-token vectors) in one
        ``device_get`` and run the host bookkeeping.  Each request's
        bookkeeping runs inside its own error boundary — a poisoned
        request terminates as ``Failed`` without touching its
        batchmates."""
        prefills, self._pending_prefill = self._pending_prefill, []
        if pending is None and not prefills:
            return
        spec = pending is not None and isinstance(pending[0], str)
        if spec:
            fetch = list(pending[1:4])     # u, n_emit, done
        elif pending is not None:
            fetch = list(pending[:2])
        else:
            fetch = []
        i = len(fetch)
        fetch.extend(t for t, _ in prefills)
        vals = jax.device_get(fetch)
        self._stats["host_syncs"] += 1
        now = time.perf_counter()
        # prefill first: in sync mode the same harvest also carries the
        # first decode step of the just-admitted rows
        for (_, slots), toks in zip(prefills, vals[i:]):
            for j, req in slots:
                if req.done_s:
                    continue
                try:
                    if self.faults is not None:
                        self.faults.check_harvest(req.rid)
                    req.output.append(int(toks[j]))
                    if not req.first_token_s:
                        req.first_token_s = now
                    if (len(req.output) >= req.max_new_tokens
                            or req.output[-1] == req.eos_id):
                        self._finish(req, now)
                    elif self._deadline_blown(req, now):
                        self._fail_deadline(req, now)
                except Exception as e:              # noqa: BLE001
                    self._fail_request(req, f"harvest failed: {e}")
        if pending is None:
            return
        if spec:
            self._harvest_spec(vals, pending, now)
            return
        tok, done, snapshot = np.asarray(vals[0]), np.asarray(vals[1]), \
            pending[2]
        for row, req in snapshot:
            if req.done_s:       # finished by an earlier harvest: the
                continue         # in-flight step decoded a stale row
            try:
                if self.faults is not None:
                    self.faults.check_harvest(req.rid)
                t = int(tok[row])
                if req.output and req.output[-1] == -100:
                    req.output[-1] = t     # sentinel: first real token
                    if not req.first_token_s:
                        req.first_token_s = now
                else:
                    req.output.append(t)
                self._stats["decode_tokens"] += 1
                if done[row]:
                    self._finish(req, now)
                elif self._deadline_blown(req, now):
                    self._fail_deadline(req, now)
            except Exception as e:                  # noqa: BLE001
                self._fail_request(req, f"harvest failed: {e}")

    def _harvest_spec(self, vals, pending, now: float):
        """Apply one verify step's results: append each row's accepted
        tokens (+ the correction), advance the host mirrors by the
        data-dependent amount, and roll the cache length — and, paged,
        the page reservation — back over the rejected tail.  Rollback
        is pure length bookkeeping: rejected-position KV is garbage the
        attention mask already hides and later writes overwrite."""
        u, n_emit, done = (np.asarray(vals[0]), np.asarray(vals[1]),
                           np.asarray(vals[2]))
        snapshot, k, tier = pending[4], pending[5], pending[6]
        accepted = 0
        for row, req in snapshot:
            if req.done_s:
                continue
            try:
                if self.faults is not None:
                    self.faults.check_harvest(req.rid)
                n = int(n_emit[row])
                toks = [int(t) for t in u[row, :n]]
                if toks and req.output and req.output[-1] == -100:
                    req.output[-1] = toks[0]       # sentinel: first token
                    req.output.extend(toks[1:])
                else:
                    req.output.extend(toks)
                if toks and not req.first_token_s:
                    req.first_token_s = now
                self._gen[row] += n
                self.cache.lengths[row] += n
                if n < k + 1:
                    self._stats["spec_rollbacks"] += 1
                    self.cache.rollback(row, int(self.cache.lengths[row]))
                self._stats["decode_tokens"] += n
                accepted += max(0, n - 1)
                if done[row]:
                    self._finish(req, now)
                elif self._deadline_blown(req, now):
                    self._fail_deadline(req, now)
            except Exception as e:                  # noqa: BLE001
                self._fail_request(req, f"harvest failed: {e}")
        self._stats["spec_accepted"] += accepted
        if self._observer is not None and snapshot:
            try:
                self._observer(
                    phase="spec_decode", arch=self.model.cfg.name,
                    local_batch=tier, seq_len=k,
                    seconds=now - self._spec_t0,
                    stats={"draft_k": k, "accepted": accepted,
                           "acceptance_rate":
                               accepted / max(1, k * len(snapshot))})
            except Exception:                       # noqa: BLE001
                self._observer = None

    # -- cache key mapping --------------------------------------------------
    def _cache_keys(self):
        """[(prefill_k, prefill_v, decode_k_cache, decode_v_cache)] pairs."""
        out = []
        pstacks = self.model.layer_stacks("prefill")
        dstacks = self.model.layer_stacks("decode")
        for ps, ds in zip(pstacks, dstacks):
            pname, _, pcount, _, psc_out = ps[:5]
            if "k" not in psc_out:
                continue
            popts = ps[5] if len(ps) > 5 else {}
            omap = popts.get("output_map", {})
            dopts = ds[5] if len(ds) > 5 else {}
            imap = dopts.get("input_map", {})
            pk = omap.get("k", f"{pname}.k" if pcount > 1 else "k")
            pv = omap.get("v", f"{pname}.v" if pcount > 1 else "v")
            out.append((pk, pv, imap.get("k_cache", "k_cache"),
                        imap.get("v_cache", "v_cache")))
        return out


def _jit(fn, donate: tuple = ()):
    """jit with buffer donation where the backend supports it (donation
    is a no-op warning on CPU, so skip it there to keep test logs clean)."""
    if donate and jax.default_backend() != "cpu":
        return jax.jit(fn, donate_argnums=donate)
    return jax.jit(fn)
