"""KV-cache slot manager for the batched serving engine.

A fixed pool of ``max_batch`` rows per cache tensor (the model's
``decode_cache_env`` layout).  Requests are assigned rows on admission and
release them on completion — continuous batching over a static-shape
decode step (the compiled executable never changes shape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class KVCacheManager:
    def __init__(self, model, max_batch: int, s_max: int):
        self.max_batch = max_batch
        self.s_max = s_max
        self.caches = {k: jnp.zeros(v.shape, v.dtype)
                       for k, v in model.decode_cache_env(
                           max_batch, s_max).items()}
        self.lengths = np.zeros((max_batch,), np.int32)
        self.free_rows = list(range(max_batch))
        self.row_owner: dict[int, int] = {}    # row -> request id

    # -- slots ------------------------------------------------------------
    def allocate(self, request_id: int) -> Optional[int]:
        if not self.free_rows:
            return None
        row = self.free_rows.pop(0)
        self.row_owner[row] = request_id
        self.lengths[row] = 0
        return row

    def release(self, row: int):
        self.row_owner.pop(row, None)
        self.lengths[row] = 0
        self.free_rows.append(row)
        self.free_rows.sort()

    @property
    def active_rows(self) -> list:
        return sorted(self.row_owner)

    # -- data -------------------------------------------------------------
    def write_prefill(self, row: int, stacks: dict, length: int):
        """Write prefilled K/V ([L,]1,S,kv,hd) into the row's cache slots."""
        for key, val in stacks.items():
            cache = self.caches[key]
            stacked = cache.ndim == val.ndim        # (L,B,S,...) vs (L,1,S,..)
            if stacked:
                cache = jax.lax.dynamic_update_slice(
                    cache, val.astype(cache.dtype),
                    (0, row, 0, 0, 0))
            else:
                cache = jax.lax.dynamic_update_slice(
                    cache, val[0].astype(cache.dtype), (row, 0, 0, 0))
            self.caches[key] = cache
        self.lengths[row] = length

    def cache_len_array(self) -> jnp.ndarray:
        return jnp.asarray(self.lengths)
