"""KV-cache storage backends for the tiered batched serving engine.

The memory-layout decision is a **pluggable policy**, mirroring
``core.policy.StrategyPolicy``: a :class:`CacheBackend` is a frozen
dataclass with a stable ``identity()`` whose ``build()`` constructs the
engine's cache manager.  Two backends ship:

  * :class:`DenseCache` (default) — a fixed pool of ``max_batch`` rows
    per cache tensor (the model's ``decode_cache_env`` layout); every
    admitted request reserves a full ``s_max`` row whether used or not.
  * :class:`PagedCache` — a shared pool of fixed-size pages per cache
    tensor plus a per-request page table (the vLLM idea, expressed
    through the engine's tier/specialize machinery so paged decode
    graphs are just more shape buckets).  KV memory scales with tokens
    actually resident; admission is page-capacity, not row-count, and
    tier-shrink compaction is a host-side page-table handoff instead of
    device row copies.

Both managers keep the engine's **prefix invariant**: active rows are
compacted into the lowest-numbered slots so a decode step at batch tier
``t`` only touches rows ``[0, t)``.  ``lengths`` is the host-side mirror
of per-row cache occupancy, advanced deterministically at dispatch time.

The backend's ``identity()`` salts every PlanStore key the engine forms
(plan-level via the op-closure config, exec-level via the step-cache
keys), so dense and paged captures coexist in one store and restore
independently across processes.

Paged layout.  Physical page 0 is reserved as a **trash page**: page-
table entries of unallocated block slots point at it, so the static-
shape jitted steps may write through them unconditionally (bucket
padding beyond a short prompt, the frontier-position garbage token of a
row mid-chunked-prefill) without corrupting a later owner.  Real pages
are ``1..num_pages``.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import heapq
from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax import lax


class CacheRowError(RuntimeError):
    """Row bookkeeping violation: double release, releasing a row that
    was never allocated, or an invalid ``move_row``.  These are engine
    bugs (or deliberate chaos probes), never load conditions — tolerate
    them silently and a leaked or doubly-freed row corrupts a *later*
    request's cache, far from the cause."""


class UnpageableCache(ValueError):
    """The model's decode state has no sequence axis to page over (SSM
    conv/state tensors); serve it with :class:`DenseCache`."""


# -- backend protocol --------------------------------------------------------


class CacheBackend:
    """Protocol base, mirroring ``core.policy.StrategyPolicy``: frozen
    dataclasses with a stable ``identity()`` (a tuple of primitives,
    reproducible across processes — it salts PlanStore keys) and a
    ``build(model, cfg)`` constructing the engine's cache manager."""

    name = "cache"

    def identity(self) -> tuple:
        raise NotImplementedError

    def build(self, model, cfg):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class DenseCache(CacheBackend):
    """Today's behavior (the default): one ``s_max`` row per admitted
    request, reserved up front."""

    name = "dense"

    def identity(self) -> tuple:
        return ("dense",)

    def build(self, model, cfg) -> "KVCacheManager":
        return KVCacheManager(model, cfg.max_batch, cfg.s_max,
                              backend=self)


@dataclasses.dataclass(frozen=True)
class PagedCache(CacheBackend):
    """Paged KV: a shared pool of ``num_pages`` pages of ``page_size``
    tokens per cache tensor, allocated to requests on demand.

    ``num_pages=None`` sizes the pool to the dense equivalent
    (``max_batch * s_max / page_size`` pages — same bytes, but memory
    now scales with tokens resident, so the same pool admits more
    concurrent requests whenever actual lengths run short of ``s_max``).
    ``page_size`` must divide ``s_max`` and every prefill bucket (chunk
    offsets are bucket sums, so page-aligned writes come for free)."""

    page_size: int = 16
    num_pages: Optional[int] = None
    name = "paged"

    def identity(self) -> tuple:
        return ("paged", self.page_size, self.num_pages)

    def build(self, model, cfg) -> "PagedKVCacheManager":
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1: {self.page_size}")
        if cfg.s_max % self.page_size:
            raise ValueError(
                f"page_size {self.page_size} must divide s_max "
                f"{cfg.s_max}")
        bad = [b for b in cfg.prefill_buckets if b % self.page_size]
        if bad:
            raise ValueError(
                f"page_size {self.page_size} must divide every prefill "
                f"bucket (chunk offsets are bucket sums and cache writes "
                f"are page-granular); offending buckets: {bad}")
        return PagedKVCacheManager(model, cfg.max_batch, cfg.s_max,
                                   backend=self)


def resolve_cache_backend(cache) -> CacheBackend:
    """Normalize ``ServeConfig.cache``: ``None`` -> :class:`DenseCache`,
    the strings ``"dense"``/``"paged"`` -> default instances, a backend
    instance passes through."""
    if cache is None:
        return DenseCache()
    if isinstance(cache, str):
        if cache == "dense":
            return DenseCache()
        if cache == "paged":
            return PagedCache()
        raise ValueError(f"unknown cache backend {cache!r} "
                         "(expected 'dense', 'paged', or a CacheBackend)")
    if isinstance(cache, CacheBackend):
        return cache
    raise TypeError(f"cache must be a CacheBackend, a name, or None; "
                    f"got {type(cache).__name__}")


def backend_from_identity(ident) -> CacheBackend:
    """Rebuild a backend from its stable ``identity()`` tuple — the
    inverse the ``Program.save``/``load`` bundle needs (identities are
    primitives, so they JSON-roundtrip)."""
    ident = tuple(ident)
    if ident[:1] == ("dense",):
        return DenseCache()
    if ident[:1] == ("paged",) and len(ident) == 3:
        return PagedCache(
            page_size=int(ident[1]),
            num_pages=None if ident[2] is None else int(ident[2]))
    raise ValueError(f"unknown cache backend identity {ident!r}")


def cache_backend_salt(backend: CacheBackend) -> str:
    """Backend identity as a short printable salt (the
    ``core.plan.strategy_salt`` idiom) for exec-level step-cache keys."""
    digest = hashlib.sha256(
        repr(backend.identity()).encode()).hexdigest()[:12]
    return f"{backend.name}:{digest}"


# -- dense -------------------------------------------------------------------


class KVCacheManager:
    """Dense per-slot pool: requests own whole rows."""

    paged = False

    def __init__(self, model, max_batch: int, s_max: int,
                 backend: Optional[CacheBackend] = None):
        self.backend = backend or DenseCache()
        self.max_batch = max_batch
        self.s_max = s_max
        self.caches = {k: jnp.zeros(v.shape, v.dtype)
                       for k, v in model.decode_cache_env(
                           max_batch, s_max).items()}
        layout = model.decode_cache_layout()
        # which dim of each cache tensor is the request-batch dim (0 for
        # per-layer tensors, 1 for (L, B, ...) stacked scan caches)
        self.batch_dims = {k: layout[k][0] for k in self.caches}
        self.lengths = np.zeros((max_batch,), np.int32)
        self.free_rows = list(range(max_batch))
        self.row_owner: dict[int, int] = {}    # row -> request id

    # -- slots ------------------------------------------------------------
    def allocate(self, request_id: int) -> Optional[int]:
        if not self.free_rows:
            return None
        row = self.free_rows.pop(0)
        self.row_owner[row] = request_id
        self.lengths[row] = 0
        return row

    def release(self, row: int):
        if row not in self.row_owner:
            raise CacheRowError(
                f"release of row {row} which is not allocated "
                f"(double release or unknown row; active rows: "
                f"{sorted(self.row_owner)})")
        self.row_owner.pop(row)
        self.lengths[row] = 0
        # sorted insertion: releases are per-request-completion hot path,
        # so O(log n) search + memmove, not an O(n log n) sort
        bisect.insort(self.free_rows, row)

    def move_row(self, src: int, dst: int):
        """Relocate a request's cache rows ``src -> dst`` (tier-shrink
        compaction).  Device-side: one slice + one dynamic_update_slice
        per cache tensor, dispatched asynchronously — the copies order
        behind any in-flight step through data dependencies."""
        self._check_move(src, dst)
        for k, c in self.caches.items():
            bd = self.batch_dims[k]
            row = lax.slice_in_dim(c, src, src + 1, axis=bd)
            self.caches[k] = lax.dynamic_update_slice_in_dim(
                c, row, dst, axis=bd)
        self._move_bookkeeping(src, dst)

    def _check_move(self, src: int, dst: int):
        if src == dst:
            raise CacheRowError(f"move_row src == dst == {src}")
        if src not in self.row_owner:
            raise CacheRowError(
                f"move_row src {src} is not an active row "
                f"(active: {sorted(self.row_owner)})")
        if dst not in self.free_rows:
            raise CacheRowError(f"move_row dst {dst} is not free "
                                f"(free: {self.free_rows})")

    def _move_bookkeeping(self, src: int, dst: int):
        self.lengths[dst] = self.lengths[src]
        self.lengths[src] = 0
        self.row_owner[dst] = self.row_owner.pop(src)
        self.free_rows.remove(dst)
        bisect.insort(self.free_rows, src)

    @property
    def active_rows(self) -> list:
        return sorted(self.row_owner)

    # -- capacity (backend-generic admission signals) ---------------------
    def reserve(self, row: int, new_len: int) -> bool:
        """Ensure the row can hold ``new_len`` tokens.  Dense rows own
        a full ``s_max`` slice up front, so this never fails."""
        return True

    def rollback(self, row: int, new_len: int) -> int:
        """Release storage beyond ``new_len`` tokens (speculative-decode
        rejection).  The engine's length mirror is the source of truth
        for *logical* occupancy — attention masks positions >= cache_len
        — so on the dense backend rollback is purely that host-side
        length decrement and this is a no-op.  Returns pages freed (0
        here; the paged backend returns real counts)."""
        return 0

    def token_capacity(self) -> int:
        return self.max_batch * self.s_max

    def free_tokens(self) -> int:
        """Token capacity still allocatable (admission pressure signal)."""
        return len(self.free_rows) * self.s_max

    def resident_tokens(self) -> int:
        return int(self.lengths.sum())

    def kv_stats(self) -> dict:
        return {"backend": self.backend.name,
                "capacity_tokens": self.token_capacity(),
                "free_tokens": self.free_tokens(),
                "resident_tokens": self.resident_tokens()}

    # -- data -------------------------------------------------------------
    def cache_len_array(self) -> jnp.ndarray:
        # snapshot, never alias: on CPU jnp.asarray can zero-copy the
        # numpy buffer, and the async engine mutates ``lengths`` while
        # the dispatched step is still consuming it
        return jnp.asarray(self.lengths.copy())


# -- paged -------------------------------------------------------------------


class PagedKVCacheManager(KVCacheManager):
    """Paged pool: requests own page-table rows mapping logical blocks
    to physical pages, allocated on demand as the sequence grows.

    Pool tensors replace the dense batch dim with a physical-page dim
    and shrink the sequence dim to one page (``(P, page, kv, hd)``
    per-layer, ``(L, P, page, kv, hd)`` stacked — from the model's
    ``decode_cache_page_env``).  The jitted steps gather a tier's pages
    into the contiguous ``(t, s_max, ...)`` view the model forward
    expects, so the forward graph — and therefore the PlanStore
    lowering story — is unchanged, and scatter back only the pages a
    step wrote (the frontier block per decode row, a chunk's blocks per
    chunk step)."""

    paged = True

    def __init__(self, model, max_batch: int, s_max: int,
                 backend: PagedCache):
        self.backend = backend
        self.max_batch = max_batch
        self.s_max = s_max
        self.page_size = backend.page_size
        self.blocks_per_row = s_max // self.page_size
        self.num_pages = (backend.num_pages
                          if backend.num_pages is not None
                          else max_batch * self.blocks_per_row)
        if self.num_pages < 1:
            raise ValueError(f"num_pages must be >= 1: {self.num_pages}")
        # +1: physical page 0 is the trash page (never allocated)
        env = model.decode_cache_page_env(self.num_pages + 1,
                                          self.page_size)
        self.caches = {k: jnp.zeros(v.shape, v.dtype)
                       for k, v in env.items()}
        layout = model.decode_cache_layout()
        self.batch_dims = {k: layout[k][0] for k in self.caches}
        self.lengths = np.zeros((max_batch,), np.int32)
        self.free_rows = list(range(max_batch))
        self.row_owner: dict[int, int] = {}
        # logical block -> physical page; 0 = trash (unmapped)
        self.page_table = np.zeros((max_batch, self.blocks_per_row),
                                   np.int32)
        self.blocks_used = np.zeros((max_batch,), np.int32)
        self.free_pages = list(range(1, self.num_pages + 1))
        heapq.heapify(self.free_pages)
        self.peak_pages_used = 0

    # -- pages ------------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(0, n_tokens) // self.page_size)

    def pages_used(self) -> int:
        return self.num_pages - len(self.free_pages)

    def reserve(self, row: int, new_len: int) -> bool:
        """Ensure the row's page table covers ``new_len`` tokens,
        allocating pages from the shared pool on demand.  Returns False
        when the pool is exhausted — an admission/preemption signal,
        never an exception."""
        if row not in self.row_owner:
            raise CacheRowError(
                f"reserve on row {row} which is not allocated")
        if new_len > self.s_max:
            return False
        need = self.pages_needed(new_len)
        cur = int(self.blocks_used[row])
        if need <= cur:
            return True
        if need - cur > len(self.free_pages):
            return False
        for blk in range(cur, need):
            self.page_table[row, blk] = heapq.heappop(self.free_pages)
        self.blocks_used[row] = need
        self.peak_pages_used = max(self.peak_pages_used, self.pages_used())
        return True

    def rollback(self, row: int, new_len: int) -> int:
        """Free the pages reserved past ``new_len`` tokens — the
        regrowth a verify step reserved for draft positions the target
        model rejected.  Freed pages held only rejected-draft garbage,
        so returning them to the pool is safe regardless of what a
        later owner writes.  Returns the number of pages freed."""
        if row not in self.row_owner:
            raise CacheRowError(
                f"rollback on row {row} which is not allocated")
        need = self.pages_needed(new_len)
        cur = int(self.blocks_used[row])
        for blk in range(need, cur):
            heapq.heappush(self.free_pages, int(self.page_table[row, blk]))
            self.page_table[row, blk] = 0
        if need < cur:
            self.blocks_used[row] = need
        return max(0, cur - need)

    def release(self, row: int):
        if row not in self.row_owner:
            raise CacheRowError(
                f"release of row {row} which is not allocated "
                f"(double release or unknown row; active rows: "
                f"{sorted(self.row_owner)})")
        self.row_owner.pop(row)
        self.lengths[row] = 0
        for blk in range(int(self.blocks_used[row])):
            heapq.heappush(self.free_pages, int(self.page_table[row, blk]))
        self.page_table[row, :] = 0
        self.blocks_used[row] = 0
        bisect.insort(self.free_rows, row)

    def move_row(self, src: int, dst: int):
        """Tier-shrink compaction by **page-table handoff**: the
        physical pages stay put; only the host-side row bookkeeping
        moves.  Zero device copies (the dense manager pays one
        slice + dynamic_update_slice per cache tensor here)."""
        self._check_move(src, dst)
        self.page_table[dst, :] = self.page_table[src, :]
        self.page_table[src, :] = 0
        self.blocks_used[dst] = self.blocks_used[src]
        self.blocks_used[src] = 0
        self._move_bookkeeping(src, dst)

    # -- capacity ---------------------------------------------------------
    def token_capacity(self) -> int:
        return self.num_pages * self.page_size

    def free_tokens(self) -> int:
        return len(self.free_pages) * self.page_size

    def kv_stats(self) -> dict:
        out = super().kv_stats()
        out.update(page_size=self.page_size, num_pages=self.num_pages,
                   pages_used=self.pages_used(),
                   peak_pages_used=self.peak_pages_used,
                   kv_util=(self.peak_pages_used * self.page_size
                            / max(1, self.token_capacity())))
        return out

    # -- data -------------------------------------------------------------
    def page_table_array(self) -> jnp.ndarray:
        # snapshot per dispatch, same aliasing caveat as cache_len_array
        return jnp.asarray(self.page_table.copy())

    # -- device-side gather/scatter helpers (used inside jitted steps) ----
    def gather_rows(self, caches: dict, page_tab, tier: int) -> dict:
        """Gather ``tier`` rows' pages into the contiguous
        ``(tier, s_max, ...)`` view the model forward expects (the
        dense tier slice's shape, so decode graphs are shared across
        backends' shape buckets)."""
        pt = lax.slice_in_dim(page_tab, 0, tier, axis=0)
        flat = pt.reshape(-1)
        out = {}
        for k, pool in caches.items():
            if self.batch_dims[k]:              # stacked (L, P, page, ...)
                g = jnp.take(pool, flat, axis=1)
                out[k] = g.reshape(pool.shape[0], tier, self.s_max,
                                   *pool.shape[3:])
            else:                               # per-layer (P, page, ...)
                g = jnp.take(pool, flat, axis=0)
                out[k] = g.reshape(tier, self.s_max, *pool.shape[2:])
        return out

    def scatter_frontier(self, caches: dict, out: dict, page_tab,
                         cache_len, tier: int) -> dict:
        """Write back only the frontier page of each row — the single
        block a decode step touched (position ``cache_len``).  Rows
        whose frontier block is unmapped (inactive / mid-chunk rows)
        target the trash page; duplicate trash indices are harmless
        because everything landing there is garbage by construction."""
        pt = lax.slice_in_dim(page_tab, 0, tier, axis=0)
        clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
        blk = clen // self.page_size                        # (t,)
        phys = jnp.take_along_axis(pt, blk[:, None], axis=1)[:, 0]
        idx = blk[:, None] * self.page_size \
            + jnp.arange(self.page_size, dtype=blk.dtype)[None]  # (t, page)
        new = {}
        for k, pool in caches.items():
            o = out[k].astype(pool.dtype)
            if self.batch_dims[k]:              # o: (L, t, s_max, ...)
                ix = idx.reshape((1,) + idx.shape + (1,) * (o.ndim - 3))
                slab = jnp.take_along_axis(
                    o, jnp.broadcast_to(
                        ix, o.shape[:2] + (self.page_size,) + o.shape[3:]),
                    axis=2)
                new[k] = pool.at[:, phys].set(slab)
            else:                               # o: (t, s_max, ...)
                ix = idx.reshape(idx.shape + (1,) * (o.ndim - 2))
                slab = jnp.take_along_axis(
                    o, jnp.broadcast_to(
                        ix, o.shape[:1] + (self.page_size,) + o.shape[2:]),
                    axis=1)
                new[k] = pool.at[phys].set(slab)
        return new

    def scatter_span(self, caches: dict, out: dict, page_tab,
                     cache_len, tier: int, width: int) -> dict:
        """Write back every block a width-``width`` verify step may
        have touched: positions ``[cache_len, cache_len + width)`` per
        row — the multi-block generalization of
        :meth:`scatter_frontier` (which is the ``width == 1`` case).
        Whole blocks are written; positions of a block outside the
        step's window carry the values the gather read, so rewriting
        them is a no-op.  Blocks past the row's mapped range (or past
        ``blocks_per_row``) land in the trash page."""
        ps = self.page_size
        nb = min(self.blocks_per_row, (width + ps - 2) // ps + 1)
        pt = lax.slice_in_dim(page_tab, 0, tier, axis=0)
        clen = lax.slice_in_dim(cache_len, 0, tier, axis=0)
        blk = clen[:, None] // ps \
            + jnp.arange(nb, dtype=clen.dtype)[None]           # (t, nb)
        in_range = blk < self.blocks_per_row
        safe_blk = jnp.minimum(blk, self.blocks_per_row - 1)
        phys = jnp.take_along_axis(pt, safe_blk, axis=1)
        phys = jnp.where(in_range, phys, 0).reshape(-1)        # (t*nb,)
        idx = (safe_blk[..., None] * ps
               + jnp.arange(ps, dtype=blk.dtype)).reshape(
                   tier, nb * ps)                              # (t, nb*ps)
        new = {}
        for k, pool in caches.items():
            o = out[k].astype(pool.dtype)
            if self.batch_dims[k]:              # o: (L, t, s_max, ...)
                ix = idx.reshape((1,) + idx.shape + (1,) * (o.ndim - 3))
                slab = jnp.take_along_axis(
                    o, jnp.broadcast_to(
                        ix, o.shape[:2] + (nb * ps,) + o.shape[3:]),
                    axis=2)
                slab = slab.reshape(o.shape[0], tier * nb, ps,
                                    *o.shape[3:])
                new[k] = pool.at[:, phys].set(slab)
            else:                               # o: (t, s_max, ...)
                ix = idx.reshape(idx.shape + (1,) * (o.ndim - 2))
                slab = jnp.take_along_axis(
                    o, jnp.broadcast_to(
                        ix, o.shape[:1] + (nb * ps,) + o.shape[2:]),
                    axis=1)
                slab = slab.reshape(tier * nb, ps, *o.shape[2:])
                new[k] = pool.at[phys].set(slab)
        return new

    def scatter_row_pages(self, caches: dict, out: dict, page_row,
                          first_block, n_blocks: int, seq_off,
                          seq_len: int) -> dict:
        """Write one row's ``[seq_off, seq_off + seq_len)`` slab into
        its mapped pages (``n_blocks`` consecutive blocks starting at
        ``first_block``).  ``out[k]`` is the row view ``(1, s_max, ...)``
        (stacked: ``(L, 1, s_max, ...)``); unmapped blocks land in
        trash."""
        phys = lax.dynamic_slice(page_row, (first_block,), (n_blocks,))
        new = {}
        for k, pool in caches.items():
            o = out[k].astype(pool.dtype)
            if self.batch_dims[k]:              # o: (L, 1, s_max, ...)
                slab = lax.dynamic_slice_in_dim(o, seq_off, seq_len,
                                                axis=2)
                slab = slab.reshape(o.shape[0], n_blocks, self.page_size,
                                    *o.shape[3:])
                new[k] = pool.at[:, phys].set(slab)
            else:                               # o: (1, s_max, ...)
                slab = lax.dynamic_slice_in_dim(o, seq_off, seq_len,
                                                axis=1)
                slab = slab.reshape(n_blocks, self.page_size, *o.shape[2:])
                new[k] = pool.at[phys].set(slab)
        return new

    def gather_row(self, caches: dict, page_row) -> dict:
        """Gather one (dynamically indexed) row into its contiguous
        ``(1, s_max, ...)`` view, for the chunked-prefill step."""
        return self.gather_row_batch(caches, page_row.reshape(1, -1))

    def gather_row_batch(self, caches: dict, page_rows) -> dict:
        """Gather ``bc`` (dynamically indexed) rows into their
        contiguous ``(bc, s_max, ...)`` views — the batched
        chunked-prefill step's gather.  ``page_rows`` is the slots'
        page-table rows, ``(bc, blocks_per_row)``."""
        bc = page_rows.shape[0]
        flat = page_rows.reshape(-1)
        out = {}
        for k, pool in caches.items():
            if self.batch_dims[k]:
                g = jnp.take(pool, flat, axis=1)
                out[k] = g.reshape(pool.shape[0], bc, self.s_max,
                                   *pool.shape[3:])
            else:
                g = jnp.take(pool, flat, axis=0)
                out[k] = g.reshape(bc, self.s_max, *pool.shape[2:])
        return out
