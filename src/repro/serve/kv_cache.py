"""KV-cache slot manager for the tiered batched serving engine.

A fixed pool of ``max_batch`` rows per cache tensor (the model's
``decode_cache_env`` layout).  Requests are assigned rows on admission and
release them on completion — continuous batching over static-shape decode
steps.  The tiered engine keeps the **prefix invariant**: active rows are
compacted into the lowest-numbered slots so a decode step at batch tier
``t`` only touches rows ``[0, t)`` of the pool (sliced and written back
*inside* the jitted step; the manager itself never copies cache data
host-side).

``lengths`` is the host-side mirror of per-row cache occupancy.  The
engine advances it deterministically at dispatch time (prefill sets it,
every decode step increments the active rows), so the device never has to
be synced to know where a row's history ends.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np
from jax import lax


class CacheRowError(RuntimeError):
    """Row bookkeeping violation: double release, releasing a row that
    was never allocated, or an invalid ``move_row``.  These are engine
    bugs (or deliberate chaos probes), never load conditions — tolerate
    them silently and a leaked or doubly-freed row corrupts a *later*
    request's cache, far from the cause."""


class KVCacheManager:
    def __init__(self, model, max_batch: int, s_max: int):
        self.max_batch = max_batch
        self.s_max = s_max
        self.caches = {k: jnp.zeros(v.shape, v.dtype)
                       for k, v in model.decode_cache_env(
                           max_batch, s_max).items()}
        layout = model.decode_cache_layout()
        # which dim of each cache tensor is the request-batch dim (0 for
        # per-layer tensors, 1 for (L, B, ...) stacked scan caches)
        self.batch_dims = {k: layout[k][0] for k in self.caches}
        self.lengths = np.zeros((max_batch,), np.int32)
        self.free_rows = list(range(max_batch))
        self.row_owner: dict[int, int] = {}    # row -> request id

    # -- slots ------------------------------------------------------------
    def allocate(self, request_id: int) -> Optional[int]:
        if not self.free_rows:
            return None
        row = self.free_rows.pop(0)
        self.row_owner[row] = request_id
        self.lengths[row] = 0
        return row

    def release(self, row: int):
        if row not in self.row_owner:
            raise CacheRowError(
                f"release of row {row} which is not allocated "
                f"(double release or unknown row; active rows: "
                f"{sorted(self.row_owner)})")
        self.row_owner.pop(row)
        self.lengths[row] = 0
        self.free_rows.append(row)
        self.free_rows.sort()

    def move_row(self, src: int, dst: int):
        """Relocate a request's cache rows ``src -> dst`` (tier-shrink
        compaction).  Device-side: one slice + one dynamic_update_slice
        per cache tensor, dispatched asynchronously — the copies order
        behind any in-flight step through data dependencies."""
        if src == dst:
            raise CacheRowError(f"move_row src == dst == {src}")
        if src not in self.row_owner:
            raise CacheRowError(
                f"move_row src {src} is not an active row "
                f"(active: {sorted(self.row_owner)})")
        if dst not in self.free_rows:
            raise CacheRowError(f"move_row dst {dst} is not free "
                                f"(free: {self.free_rows})")
        for k, c in self.caches.items():
            bd = self.batch_dims[k]
            row = lax.slice_in_dim(c, src, src + 1, axis=bd)
            self.caches[k] = lax.dynamic_update_slice_in_dim(
                c, row, dst, axis=bd)
        self.lengths[dst] = self.lengths[src]
        self.lengths[src] = 0
        self.row_owner[dst] = self.row_owner.pop(src)
        self.free_rows.remove(dst)
        self.free_rows.append(src)
        self.free_rows.sort()

    @property
    def active_rows(self) -> list:
        return sorted(self.row_owner)

    # -- data -------------------------------------------------------------
    def cache_len_array(self) -> jnp.ndarray:
        # snapshot, never alias: on CPU jnp.asarray can zero-copy the
        # numpy buffer, and the async engine mutates ``lengths`` while
        # the dispatched step is still consuming it
        return jnp.asarray(self.lengths.copy())
