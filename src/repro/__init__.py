"""DynaFlow reproduction — programmable operator scheduling on JAX."""
from ._compat import install_jax_shims

install_jax_shims()
