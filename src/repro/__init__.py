"""DynaFlow reproduction — programmable operator scheduling on JAX.

``repro.api.compile`` is the frontend: one call from a model (or arch
name, or raw traced Module) to a ``Program`` whose step builders route
through the plan IR, the persistent PlanStore and the tiered serve
runtime.  ``repro.core`` holds the substrate those builders compose.
"""
from ._compat import install_jax_shims

install_jax_shims()

from . import api  # noqa: E402,F401  (the facade is the public frontend)
