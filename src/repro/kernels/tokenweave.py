"""TokenWeave-style fused AllReduce + residual-add + RMSNorm for TPU.

GPU TokenWeave fuses a multimem AllReduce with RMSNorm inside one kernel,
reserving a few CTAs for communication.  The TPU-native adaptation splits
the AllReduce into its ring halves and fuses the *memory-bound* middle:

    all_reduce(y); s = x + y; h = rmsnorm(s)          (sequential: 3 full
                                                       HBM passes over B·S·d)
    ==>
    y_s = reduce_scatter(y)         # network, 1/tp payload per hop
    s_s, h_s = pallas fused add+norm on the (B·S/tp, d) shard   # 1 pass,
                                                                # 1/tp tokens
    s, h = all_gather([s_s, h_s])   # network

The elementwise work drops by tp× and fuses into one VMEM pass (the Pallas
kernel in rmsnorm.py); RS+AG moves the same bytes as the AllReduce it
replaces.  The residual stream ``s`` and the normed ``h`` are both
returned because both are consumed downstream (s by the next residual
add, h by the next projection).

The CTA-count runtime knob from the paper maps to ``block_rows`` of the
Pallas kernel — selected per batch bucket by the TokenWeave strategy
(§5.3.4's 12% adaptive win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist import collectives as col


def fused_ar_add_rmsnorm(y_partial, x, g, *, axis: str = "model",
                         eps: float = 1e-5, block_rows: int = 256,
                         interpret: bool = True):
    """Fused psum(y) + (x + .) + rmsnorm over mesh axis ``axis``.

    y_partial, x: (B, S, d) with S divisible by the axis size.
    Returns (s, h) both (B, S, d), s = x + psum(y), h = rmsnorm(s) * g.
    Outside shard_map (tests, tp=1) the collective halves are identity.
    """
    from . import ops as kops
    B, S, d = x.shape
    tp = col.axis_size(axis)
    y_s = col.reduce_scatter(y_partial, axis, dim=1)      # (B, S/tp, d)
    idx = col.axis_index(axis)
    x_s = jax.lax.dynamic_slice_in_dim(x, idx * (S // tp), S // tp, axis=1)
    # differentiable Pallas core (ops.py carries the custom VJP)
    s_s, h_s = kops.fused_add_rmsnorm(x_s, y_s, g, block_rows=block_rows)
    sh = jnp.stack([s_s, h_s])                            # (2, B, S/tp, d)
    sh = col.all_gather(sh, axis, dim=2)                  # (2, B, S, d)
    return sh[0], sh[1]
