"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def rmsnorm(x, g, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(x.dtype) * g


def fused_add_rmsnorm(x, y, g, *, eps: float = 1e-5):
    s = x.astype(jnp.float32) + y.astype(jnp.float32)
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    h = (s * lax.rsqrt(var + eps))
    return s.astype(x.dtype), h.astype(x.dtype) * g


def flash_attention(q, k, v, *, causal: bool = True, sm_scale=None):
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, sm_scale=None):
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * sm_scale
    ki = jnp.arange(S)[None, None, None, :]
    vl = jnp.asarray(cache_len)
    if vl.ndim:
        vl = vl.reshape(-1, 1, 1, 1)
    s = jnp.where(ki < vl, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def grouped_ffn(x, w1, w3, w2):
    h1 = jnp.einsum("end,edf->enf", x.astype(jnp.float32),
                    w1.astype(jnp.float32))
    h3 = jnp.einsum("end,edf->enf", x.astype(jnp.float32),
                    w3.astype(jnp.float32))
    h = jax.nn.silu(h1) * h3
    return jnp.einsum("enf,efd->end", h,
                      w2.astype(jnp.float32)).astype(x.dtype)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128):
    """Sequential-recurrence oracle (exact, O(L) state updates)."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # (b, L, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    dtf = dt.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                  # (b,H,P), (b,H), (b,H,N) x2
        a = jnp.exp(dtt * A[None, :])          # (b,H)
        state = state * a[..., None, None] + \
            jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt)
        y = jnp.einsum("bhn,bhnp->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    s0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, ys = lax.scan(step, s0, xs)             # (L, b, H, P)
    y = jnp.moveaxis(ys, 0, 1) + xf * D[None, None, :, None]
    return y.astype(x.dtype)
