"""Pallas TPU kernel: single-token decode attention (flash-decode).

Decode attention is memory-bound: the entire KV cache is streamed once per
step.  The kernel tiles the KV sequence into VMEM blocks and keeps the
online-softmax state in registers; invalid cache positions (>= cache_len)
are masked.  Grid: (B*H, Sk_blocks) with the KV-block axis innermost so
the running (acc, m, l) scratch carries across blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k, sm_scale):
    kb = pl.program_id(1)
    n_kb = pl.num_programs(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale       # (1, hd)
    k = k_ref[0].astype(jnp.float32)                  # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    valid_len = len_ref[0]

    s = (q @ k.T)[0]                                  # (block_k,)
    pos = kb * block_k + lax.iota(jnp.int32, block_k)
    s = jnp.where(pos < valid_len, s, NEG_INF)

    m_prev, l_prev = m_ref[0], l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                            # (block_k,)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p[None, :] @ v)
    m_ref[0], l_ref[0] = m_new, l_new

    @pl.when(kb == n_kb - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-20)
                    ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     sm_scale=None, interpret: bool = True):
    """q (B, 1, H, hd); k/v_cache (B, S, H, hd); cache_len () or (B,) int32.

    Attends to positions [0, cache_len[b]); returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    bk = min(block_k, S)
    while S % bk:
        bk //= 2
    bk = max(bk, 1)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, 1, hd)
    kt = k_cache.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    clen = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_decode_kernel, block_k=bk, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i, H=H: (b // H,)),
            pl.BlockSpec((1, 1, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(clen, qt, kt, vt)
    return out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3)
