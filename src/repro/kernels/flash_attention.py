"""Pallas TPU kernel: blockwise flash attention (online softmax).

Grid: (batch*heads, q_blocks); the kernel body loops over K/V blocks with
``lax.fori_loop``, keeping the running max / sum / accumulator in VMEM
scratch.  Block shapes are MXU-aligned (q/k blocks multiples of 128 when
the sequence allows; head_dim padded to 128 by the wrapper in ops.py when
needed).  Causal masking skips fully-masked K blocks by bounding the loop
trip count per q block — the standard TPU flash schedule.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, sm_scale,
                  block_k, seq_k):
    # q_ref: (1, block_q, hd); k_ref/v_ref: (1, seq_k, hd)
    _, block_q, hd = q_ref.shape
    qi = pl.program_id(1)
    # full-block loads + array indexing (older pallas interpret mode does
    # not discharge raw-int ref indices)
    q = q_ref[...][0].astype(jnp.float32) * sm_scale

    n_kb = seq_k // block_k
    if causal:
        # last K block that intersects [0, (qi+1)*block_q)
        hi = lax.min(((qi + 1) * block_q + block_k - 1) // block_k, n_kb)
    else:
        hi = n_kb

    def body(kb, carry):
        acc, m, lsum = carry
        k = pl.load(k_ref, (pl.ds(0, 1),
                            pl.ds(kb * block_k, block_k), slice(None)))[0]
        v = pl.load(v_ref, (pl.ds(0, 1),
                            pl.ds(kb * block_k, block_k), slice(None)))[0]
        s = q @ k.astype(jnp.float32).T                     # (bq, bk)
        if causal:
            qpos = qi * block_q + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = kb * block_k + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        lsum_new = lsum * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc, m_new, lsum_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    lsum0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, lsum = lax.fori_loop(0, hi, body, (acc0, m0, lsum0))
    o_ref[...] = (acc / jnp.maximum(lsum, 1e-20)[:, None]).astype(
        o_ref.dtype)[None]


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    sm_scale: float | None = None, interpret: bool = True):
    """q (B, Sq, H, hd), k/v (B, Sk, H, hd) -> (B, Sq, H, hd).

    H is the per-q-head layout (GQA already expanded by the caller).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bq = max(bq, 1)
    bk = min(block_k, Sk)
    while Sk % bk:
        bk //= 2
    bk = max(bk, 1)

    # (B, S, H, hd) -> (B*H, S, hd)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, hd)

    kernel = functools.partial(_flash_kernel, causal=causal,
                               sm_scale=sm_scale, block_k=bk, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
