"""Pallas TPU kernel: grouped expert FFN (Comet's compute hot-spot).

Computes, per expert e:  y_e = (silu(x_e @ w1_e) * (x_e @ w3_e)) @ w2_e
with x (E, N, D), w1/w3 (E, D, F), w2 (E, F, D).

Grid: (E, N/block_n, F/block_f).  Each program computes a
(block_n, block_f) tile of the hidden activation for one expert, applies
the gate, and accumulates its contribution to the (block_n, D) output tile
— accumulation over the F grid axis happens in-place in the output block
(revisited across the innermost grid dim, the standard Pallas reduction
pattern).  Block shapes are MXU-aligned multiples of 128 where shapes
allow.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grouped_ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    # x (1, bn, D), w1/w3 (1, D, bf), w2 (1, bf, D), o (1, bn, D)
    fi = pl.program_id(2)
    x = x_ref[0].astype(jnp.float32)
    h1 = x @ w1_ref[0].astype(jnp.float32)
    h3 = x @ w3_ref[0].astype(jnp.float32)
    h = jax.nn.silu(h1) * h3
    part = h @ w2_ref[0].astype(jnp.float32)

    @pl.when(fi == 0)
    def _init():
        o_ref[0] = part.astype(o_ref.dtype)

    @pl.when(fi != 0)
    def _acc():
        o_ref[0] = (o_ref[0].astype(jnp.float32) + part).astype(o_ref.dtype)


def grouped_ffn(x, w1, w3, w2, *, block_n: int = 128, block_f: int = 512,
                interpret: bool = True):
    """x (E, N, D) -> (E, N, D); SwiGLU expert FFN, grouped over E."""
    E, N, D = x.shape
    F = w1.shape[-1]
    bn = min(block_n, N)
    while N % bn:
        bn //= 2
    bn = max(bn, 1)
    bf = min(block_f, F)
    while F % bf:
        bf //= 2
    bf = max(bf, 1)

    kernel = _grouped_ffn_kernel
    return pl.pallas_call(
        kernel,
        grid=(E, N // bn, F // bf),
        in_specs=[
            pl.BlockSpec((1, bn, D), lambda e, n, f: (e, n, 0)),
            pl.BlockSpec((1, D, bf), lambda e, n, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, n, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, n, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, D), lambda e, n, f: (e, n, 0)),
        out_shape=jax.ShapeDtypeStruct((E, N, D), x.dtype),
        interpret=interpret,
    )(x, w1, w3, w2)
