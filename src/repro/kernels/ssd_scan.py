"""Pallas TPU kernel: Mamba2 chunked SSD scan (state-space duality).

Layout: the wrapper flattens (batch, head) into the first grid axis; the
second grid axis walks chunks *sequentially* (TPU grid iterations run in
order on a core), carrying the running SSM state in a VMEM scratch buffer
— the inter-chunk recurrence needs no HBM round-trip.

Per program (one head, one chunk of Q timesteps):
  intra-chunk:  M[i,j] = (C_i · B_j) * exp(cum_i - cum_j) * dt_j   (j <= i)
                y_intra = M @ x
  inter-chunk:  y_inter = (C * exp(cum)) @ state
  state update: state' = state * exp(cum_Q) + B^T diag(w) x,
                w_j = exp(cum_Q - cum_j) * dt_j

VMEM per program (Q=256, N=128, P=64, f32): x 64 KiB, B/C 128 KiB each,
M 256 KiB, state 32 KiB — comfortably inside the ~128 MiB v5e VMEM budget
with double buffering.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, state_ref):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    B = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)       # (Q, N)
    A = a_ref[0].astype(jnp.float32)          # ()
    D = d_ref[0].astype(jnp.float32)          # ()
    Q = x.shape[0]

    dA = dt * A
    cum = jnp.cumsum(dA)                      # (Q,) inclusive
    # intra-chunk
    CB = C @ B.T                              # (Q, Q)
    i = lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    j = lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    expo = jnp.where(j <= i, cum[:, None] - cum[None, :], -jnp.inf)
    M = CB * jnp.exp(expo) * dt[None, :]
    y = M @ x
    # inter-chunk
    state = state_ref[...].astype(jnp.float32)          # (N, P)
    y = y + (C * jnp.exp(cum)[:, None]) @ state
    # state update
    last = cum[Q - 1]
    w = jnp.exp(last - cum) * dt                        # (Q,)
    state_new = state * jnp.exp(last) + (B * w[:, None]).T @ x
    state_ref[...] = state_new
    y_ref[0, 0] = (y + D * x).astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool = True):
    """Chunked SSD.  x (b, L, H, P); dt (b, L, H); A/D (H,);
    B/C (b, L, G, N) with H % G == 0.  Returns y (b, L, H, P)."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    Q = min(chunk, L)
    while L % Q:
        Q //= 2
    Q = max(Q, 1)
    nc = L // Q
    rep = H // G

    BH = b * H
    xt = x.transpose(0, 2, 1, 3).reshape(BH, nc, Q, P)
    dtt = dt.transpose(0, 2, 1).reshape(BH, nc, Q)
    Bt = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(BH, nc, Q, N)
    Ct = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(BH, nc, Q, N)
    At = jnp.tile(A.astype(jnp.float32), b)
    Dt = jnp.tile(D.astype(jnp.float32), b)

    y = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda h, c: (h, c, 0, 0)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
            pl.BlockSpec((1,), lambda h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda h, c: (h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, Q, P), x.dtype),
        scratch_shapes=[_vmem_scratch((N, P))],
        interpret=interpret,
    )(xt, dtt, Bt, Ct, At, Dt)
    return y.reshape(b, H, L, P).transpose(0, 2, 1, 3)


def _vmem_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
