"""Jit'd public wrappers for the Pallas kernels.

``INTERPRET`` is True on CPU (kernel bodies execute in Python for
validation) and False on real TPUs.  Model code calls these; strategy
``replace_func``s call the fused variants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import grouped_matmul as _gm
from . import rmsnorm as _rn
from . import ssd_scan as _ssd

INTERPRET = jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q, k, v, *, causal: bool = True):
    return _fa.flash_attention(q, k, v, causal=causal, interpret=INTERPRET)


@jax.jit
def decode_attention(q, k_cache, v_cache, cache_len):
    return _dec.decode_attention(q, k_cache, v_cache, cache_len,
                                 interpret=INTERPRET)


@jax.jit
def rmsnorm(x, g):
    shape = x.shape
    out = _rn.rmsnorm(x.reshape(-1, shape[-1]), g, interpret=INTERPRET)
    return out.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused_add_rmsnorm(x, y, g, block_rows):
    """Differentiable fused add+RMSNorm: Pallas forward, analytic VJP
    (the backward is memory-bound elementwise math XLA fuses well; a
    Pallas backward kernel is a further perf iteration)."""
    shape = x.shape
    s, h = _rn.fused_add_rmsnorm(x.reshape(-1, shape[-1]),
                                 y.reshape(-1, shape[-1]), g,
                                 block_rows=block_rows,
                                 interpret=INTERPRET)
    return s.reshape(shape), h.reshape(shape)


def fused_add_rmsnorm(x, y, g, block_rows: int = 256):
    return _fused_add_rmsnorm(x, y, g, block_rows)


def _farn_fwd(x, y, g, block_rows):
    s, h = _fused_add_rmsnorm(x, y, g, block_rows)
    return (s, h), (s, g)


def _farn_bwd(block_rows, res, cts):
    s, g = res
    ds_out, dh = cts
    eps = 1e-5
    sf = s.astype(jnp.float32)
    dhf = dh.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    n = s.shape[-1]
    var = jnp.mean(sf * sf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    dg = jnp.sum((dhf * sf * r).reshape(-1, n), axis=0).astype(g.dtype)
    dhg = dhf * gf
    ds_h = r * dhg - (r ** 3 / n) * sf * jnp.sum(dhg * sf, -1, keepdims=True)
    ds = (ds_out.astype(jnp.float32) + ds_h).astype(s.dtype)
    return ds, ds, dg


_fused_add_rmsnorm.defvjp(_farn_fwd, _farn_bwd)


@jax.jit
def grouped_ffn(x, w1, w3, w2):
    return _gm.grouped_ffn(x, w1, w3, w2, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128):
    return _ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=INTERPRET)


def fused_ar_add_rmsnorm(y_partial, x, g, *, axis="model", block_rows=256):
    """TokenWeave fused collective+norm — must run inside shard_map (or
    unsharded, where the collective halves degrade to identity)."""
    from . import tokenweave as _tw
    return _tw.fused_ar_add_rmsnorm(y_partial, x, g, axis=axis,
                                    block_rows=block_rows,
                                    interpret=INTERPRET)
