"""Pallas TPU kernel: fused (residual-add +) RMSNorm.

The TokenWeave-style fusion target: after a reduce-scatter, each chip
holds a (tokens/tp, d) shard; the residual add + RMSNorm run on that shard
in one VMEM pass (one HBM read of x and y, one write of s and h) instead
of three separate memory-bound ops over the full token set.

Tiling: grid over row blocks; each program loads a (block_rows, d) tile of
x and y into VMEM, computes s = x + y, h = s * rsqrt(mean(s^2) + eps) * g,
and writes both.  d is the model dim (<= 8192 here): a full row fits VMEM
comfortably (block_rows * d * 2B * 4 tensors << 128 MiB for block_rows=256,
d=8192: 16 MiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_add_rmsnorm_kernel(x_ref, y_ref, g_ref, s_ref, h_ref, *, eps):
    x = x_ref[...]
    y = y_ref[...]
    s = (x.astype(jnp.float32) + y.astype(jnp.float32))
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    h = s * jax.lax.rsqrt(var + eps)
    s_ref[...] = s.astype(s_ref.dtype)
    h_ref[...] = (h.astype(h_ref.dtype)
                  * g_ref[...].astype(h_ref.dtype)[None, :])


def fused_add_rmsnorm(x, y, g, *, eps: float = 1e-5, block_rows: int = 256,
                      interpret: bool = True):
    """(x + y, rmsnorm(x + y) * g) over rows; x,y (n, d), g (d,).

    Returns (s, h).  ``interpret=True`` executes on CPU for validation;
    on TPU pass interpret=False.
    """
    n, d = x.shape
    br = min(block_rows, n)
    while n % br:
        br //= 2
    br = max(br, 1)
    grid = (n // br,)
    kernel = functools.partial(_fused_add_rmsnorm_kernel, eps=eps)
    s, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((n, d), x.dtype),
        ],
        interpret=interpret,
    )(x, y, g)
    return s, h


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype)
                  * g_ref[...].astype(o_ref.dtype)[None, :])


def rmsnorm(x, g, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """Plain RMSNorm over rows; x (n, d), g (d,)."""
    n, d = x.shape
    br = min(block_rows, n)
    while n % br:
        br //= 2
    br = max(br, 1)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, g)
