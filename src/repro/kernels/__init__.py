"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel lives in <name>.py (pl.pallas_call + BlockSpec), with the
jit'd public wrappers in ops.py and pure-jnp oracles in ref.py:

  rmsnorm.py          fused residual-add + RMSNorm (TokenWeave local half)
  tokenweave.py       fused reduce-scatter + add/norm + all-gather
  flash_attention.py  blockwise online-softmax attention
  decode_attention.py flash-decode against a KV cache
  grouped_matmul.py   grouped expert FFN (Comet compute half)
  ssd_scan.py         Mamba2 chunked SSD with VMEM-carried state
"""
