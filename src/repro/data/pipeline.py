"""Tokenized data pipeline: host-sharded, seekable, double-buffered.

Design for 1000+ nodes:
  * every host reads only its own shard of the sample space, derived from
    (step, host_index) — no coordination traffic;
  * ``state_dict()/load_state_dict()`` capture the exact cursor so a
    checkpoint restart resumes on the *next* sample (exactly-once);
  * a background prefetch thread hides storage latency behind the step.

Backends: SyntheticBackend (deterministic per-step PRNG tokens — used by
the examples/benchmarks) and MemmapBackend (flat token file, the
production path).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_index: int = 0
    seed: int = 0
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0, \
            (self.global_batch, self.n_hosts)
        return self.global_batch // self.n_hosts


class SyntheticBackend:
    """Deterministic synthetic tokens: batch(step, host) is a pure function
    — trivially seekable and identical across restarts."""

    def __init__(self, vocab: int):
        self.vocab = vocab

    def batch(self, cfg: DataConfig, step: int) -> dict:
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4093 + cfg.host_index)
        B, S = cfg.host_batch, cfg.seq_len
        ids = rng.integers(0, self.vocab, (B, S + 1), dtype=np.int32)
        return {"ids": ids[:, :-1], "labels": ids[:, 1:]}


class MemmapBackend:
    """Flat int32 token file; sample i = tokens[i*(S+1):(i+1)*(S+1)].
    Host h reads samples (step*GB + h*HB + [0, HB)) mod n_samples."""

    def __init__(self, path: str, seq_len: int):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.stride = seq_len + 1
        self.n_samples = len(self.tokens) // self.stride

    def batch(self, cfg: DataConfig, step: int) -> dict:
        B = cfg.host_batch
        base = step * cfg.global_batch + cfg.host_index * B
        rows = [(base + i) % self.n_samples for i in range(B)]
        buf = np.stack([
            self.tokens[r * self.stride:(r + 1) * self.stride]
            for r in rows])
        return {"ids": buf[:, :-1].astype(np.int32),
                "labels": buf[:, 1:].astype(np.int32)}


class TokenPipeline:
    """Seekable iterator with background prefetch."""

    def __init__(self, backend, cfg: DataConfig, start_step: int = 0):
        self.backend = backend
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- checkpointable cursor ------------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, st: dict):
        self.seek(int(st["step"]))

    def seek(self, step: int):
        self._shutdown()
        self.step = step

    # -- iteration -------------------------------------------------------------
    def _producer(self, from_step: int):
        s = from_step
        while not self._stop.is_set():
            try:
                self._q.put((s, self.backend.batch(self.cfg, s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._producer, args=(self.step,), daemon=True)
            self._thread.start()
        s, batch = self._q.get()
        assert s == self.step, (s, self.step)
        self.step += 1
        return batch

    def _shutdown(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
            while not self._q.empty():
                self._q.get_nowait()

    def __del__(self):
        try:
            self._shutdown()
        except Exception:
            pass
