from .pipeline import (DataConfig, MemmapBackend, SyntheticBackend,
                       TokenPipeline)
