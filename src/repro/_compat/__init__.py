"""Environment shims — run the codebase on the baked-in toolchain.

The source tree targets the current JAX API surface; the container pins
jax 0.4.x.  ``install_jax_shims`` backfills the few moved/renamed entry
points we use (``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.make_mesh(axis_types=...)``) from their older locations.  All shims
are no-ops on a new-enough JAX.
"""
from __future__ import annotations

import enum
import inspect


def install_jax_shims():
    import jax
    import jax.sharding as sharding

    if not hasattr(sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map
        _params = inspect.signature(_shard_map).parameters

        def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=None,
                      **kw):
            # new API spells replication checking `check_vma`; old `check_rep`
            if check_vma is not None and "check_rep" in _params:
                kw.setdefault("check_rep", check_vma)

            def bind(g):
                return _shard_map(g, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, **kw)

            return bind if f is None else bind(f)

        jax.shard_map = shard_map
