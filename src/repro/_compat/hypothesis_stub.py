"""Minimal drop-in for the ``hypothesis`` API surface the tests use.

Installed by ``tests/conftest.py`` ONLY when the real package is absent
(the container doesn't ship it).  Examples are drawn from a deterministic
per-test RNG, so runs are reproducible; this trades hypothesis' shrinking
and adaptive search for zero dependencies — acceptable for CI smoke.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_for(self, rng):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples=10, deadline=None, **_):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*arg_strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # positional strategies fill the trailing non-keyword params
        pos_names = [n for n in names if n not in kw_strats]
        pos_names = pos_names[len(pos_names) - len(arg_strats):]
        drawn = set(kw_strats) | set(pos_names)
        fixture_params = [sig.parameters[n] for n in names if n not in drawn]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 10)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                dkw = {k: s.example_for(rng) for k, s in kw_strats.items()}
                dkw.update({k: s.example_for(rng)
                            for k, s in zip(pos_names, arg_strats)})
                fn(*args, **kwargs, **dkw)

        # hide drawn params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        del wrapper.__wrapped__
        return wrapper

    return deco
