from .adamw import (AdamWConfig, adamw_init, adamw_update, dequantize_state,
                    quantize_state)
from .schedules import cosine_schedule, linear_warmup
