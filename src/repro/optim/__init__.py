from .adamw import (AdamWConfig, adamw_init, adamw_update, quantize_state,
                    dequantize_state)
from .schedules import cosine_schedule, linear_warmup
