"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))


def cosine_schedule(step, warmup: int, total: int, peak: float,
                    floor: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, (s + 1.0) / max(warmup, 1))
    frac = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, peak * cos)
