"""AdamW from scratch + int8-quantized second moment (distributed-
optimization trick: 4x less optimizer-state HBM, block-wise scales)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized: bool = False     # int8 second moment
    block: int = 256            # quantization block size


def adamw_init(params, cfg: AdamWConfig):
    def init_leaf(p):
        m = jnp.zeros(p.shape, jnp.float32)
        if cfg.quantized:
            v = quantize_state(jnp.zeros(p.shape, jnp.float32), cfg.block)
        else:
            v = jnp.zeros(p.shape, jnp.float32)
        return {"m": m, "v": v}

    return {"state": jax.tree_util.tree_map(
                init_leaf, params,
                is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def quantize_state(v, block: int):
    """Block-wise int8 quantization of the (non-negative) second moment
    with a sqrt code map: q = round(127·sqrt(v/absmax)).  The nonlinear
    map keeps resolution near zero — a linear map rounds small-v entries
    to exactly 0, and any gradient noise (e.g. from int8-compressed
    all-reduces) then explodes m/sqrt(v) (observed divergence; see
    tests).  Shape stays implicit (derived from the param at dequantize
    time) so the state dict holds only array leaves."""
    flat = v.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(blocks, axis=1, keepdims=True), 1e-20)
    q = jnp.clip(jnp.round(127.0 * jnp.sqrt(blocks / scale)), 0, 127)
    q = jnp.where(blocks > 0, jnp.maximum(q, 1.0), 0.0)   # never zero v>0
    return {"q": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def dequantize_state(qs, shape) -> jax.Array:
    code = qs["q"].astype(jnp.float32) / 127.0
    flat = (code * code * qs["scale"]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def _is_quant(x):
    return isinstance(x, dict) and "q" in x and "scale" in x


def adamw_update(params, grads, opt_state, cfg: AdamWConfig,
                 lr: Optional[jax.Array] = None,
                 gnorm: Optional[jax.Array] = None):
    """One AdamW step.  Returns (new_params, new_opt_state, grad_norm).
    Pass a globally-reduced ``gnorm`` under SPMD so clipping is identical
    on every chip (see train/step.py:global_grad_norm)."""
    lr = cfg.lr if lr is None else lr
    if gnorm is None:
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in leaves))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    count = opt_state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, st):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * st["m"] + (1 - cfg.b1) * g
        v_prev = (dequantize_state(st["v"], p.shape)
                  if _is_quant(st["v"]) else st["v"])
        v = cfg.b2 * v_prev + (1 - cfg.b2) * g * g
        mhat, vhat = m / c1, v / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - lr * (step + cfg.weight_decay * p.astype(jnp.float32)))
        v_out = quantize_state(v, cfg.block) if _is_quant(st["v"]) else v
        return newp.astype(p.dtype), {"m": m, "v": v_out}

    def is_state_leaf(x):
        return isinstance(x, dict) and "m" in x

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_s = jax.tree_util.tree_leaves(
        opt_state["state"], is_leaf=is_state_leaf)
    outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_s = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_p, {"state": new_s, "count": count}, gnorm
