"""OpGraph IR — the DynaFlow operator graph.

The graph is the unit DynaFlow schedules over.  Nodes are *logical,
coarse-grained operators* (an RMSNorm, an attention, a TP all-reduce), per
the paper's §3.2.1 granularity argument: scheduling individual tensor
arithmetic ops costs more in dispatch/planning than it buys in overlap.

Tensors are symbolic (`TensorRef`): shape/dtype plus an optional batch
dimension.  The batch dimension is what `split()` micro-batches along.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

FULL = -1    # sentinel "part" index: the whole (unsplit) batch
VBATCH = -2  # sentinel batch_dim: value *scales with* the micro-batch but has
             # no sliceable batch axis (e.g. MoE dispatch buffers whose
             # capacity is proportional to token count).  Such tensors can be
             # produced/consumed per-micro-batch but never sliced or merged.


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """Symbolic tensor flowing between OpNodes."""

    tid: int
    shape: tuple[int, ...]
    dtype: Any
    batch_dim: Optional[int] = 0  # None => not micro-batch-splittable (weights etc.)
    name: str = ""

    @property
    def nbytes(self) -> int:
        import numpy as np

        size = 1
        for d in self.shape:
            size *= d
        return size * np.dtype(self.dtype).itemsize

    def part_shape(self, sizes: Sequence[int], mb: int) -> tuple[int, ...]:
        """Shape of micro-batch `mb` under split `sizes`."""
        if self.batch_dim is None or mb == FULL:
            return self.shape
        s = list(self.shape)
        s[self.batch_dim] = sizes[mb]
        return tuple(s)


@dataclasses.dataclass
class OpNode:
    """One schedulable operator.

    ``fn(params, *inputs) -> output | tuple[outputs]`` where ``params`` is
    this op's own parameter subtree (possibly ``None``).
    """

    oid: int
    name: str                      # fully scoped, e.g. "layer/attn/qkv"
    fn: Callable
    inputs: tuple[int, ...]        # tensor ids
    outputs: tuple[int, ...]
    param_paths: tuple[tuple[str, ...], ...] = ()
    resource: str = "compute"      # compute | memory | network
    scope: tuple[str, ...] = ()
    tags: frozenset = frozenset()
    flops: float = 0.0             # rough estimate, for scheduler heuristics
    bytes_moved: float = 0.0
    param_bytes: float = 0.0       # weight bytes this op reads (split penalty)
    members: tuple = ()            # for composite (coalesced) nodes: member OpNodes

    def __repr__(self):  # compact for debugging/plan dumps
        return f"OpNode({self.oid}:{self.name}:{self.resource})"


class OpGraph:
    """A DAG of OpNodes over TensorRefs."""

    def __init__(self):
        self.nodes: dict[int, OpNode] = {}
        self.tensors: dict[int, TensorRef] = {}
        self.producer: dict[int, int] = {}       # tid -> oid
        self.consumers: dict[int, list[int]] = {}  # tid -> [oid]
        self.inputs: dict[str, int] = {}         # graph input name -> tid
        self.outputs: dict[str, int] = {}        # graph output name -> tid
        self._next_tid = 0
        self._next_oid = 0

    # -- construction -----------------------------------------------------
    def new_tensor(self, shape, dtype, batch_dim=0, name="") -> TensorRef:
        t = TensorRef(self._next_tid, tuple(int(d) for d in shape), dtype,
                      batch_dim, name)
        self.tensors[t.tid] = t
        self.consumers.setdefault(t.tid, [])
        self._next_tid += 1
        return t

    def add_input(self, name, shape, dtype, batch_dim=0) -> TensorRef:
        t = self.new_tensor(shape, dtype, batch_dim, name=name)
        self.inputs[name] = t.tid
        return t

    def mark_output(self, name: str, ref: TensorRef):
        self.outputs[name] = ref.tid

    def add_node(self, name, fn, inputs: Sequence[TensorRef],
                 out_refs: Sequence[TensorRef], *, param_paths=(),
                 resource="compute", scope=(), tags=(), flops=0.0,
                 bytes_moved=0.0, param_bytes=0.0, members=()) -> OpNode:
        node = OpNode(
            oid=self._next_oid, name=name, fn=fn,
            inputs=tuple(r.tid for r in inputs),
            outputs=tuple(r.tid for r in out_refs),
            param_paths=tuple(param_paths), resource=resource,
            scope=tuple(scope), tags=frozenset(tags), flops=flops,
            bytes_moved=bytes_moved, param_bytes=param_bytes,
            members=tuple(members))
        self.nodes[node.oid] = node
        self._next_oid += 1
        for r in inputs:
            self.consumers[r.tid].append(node.oid)
        for r in out_refs:
            self.producer[r.tid] = node.oid
        return node

    # -- queries ----------------------------------------------------------
    def topo_order(self) -> list[int]:
        """Topological order of node oids (stable: by insertion order)."""
        return sorted(self.nodes.keys())

    def node_deps(self, oid: int) -> set[int]:
        """Producer nodes this node depends on."""
        return {self.producer[t] for t in self.nodes[oid].inputs
                if t in self.producer}

    def splittable(self, oid: int) -> bool:
        """An op is micro-batch-splittable if any input carries a batch dim."""
        n = self.nodes[oid]
        return any(self.tensors[t].batch_dim is not None for t in n.inputs)

    def validate(self):
        """DAG sanity: every non-input tensor has a producer; no forward refs."""
        input_tids = set(self.inputs.values())
        for oid in self.topo_order():
            n = self.nodes[oid]
            for t in n.inputs:
                if t not in input_tids and t not in self.producer:
                    raise ValueError(f"tensor {t} consumed by {n} has no producer")
                if t in self.producer and self.producer[t] >= oid:
                    raise ValueError(f"graph not topologically ordered at {n}")
        for name, t in self.outputs.items():
            if t not in self.producer and t not in input_tids:
                raise ValueError(f"output {name} never produced")

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    def pretty(self) -> str:
        lines = []
        for oid in self.topo_order():
            n = self.nodes[oid]
            ins = ",".join(str(t) for t in n.inputs)
            outs = ",".join(str(t) for t in n.outputs)
            lines.append(f"[{oid:3d}] {n.resource:8s} {n.name}  ({ins})->({outs})")
        return "\n".join(lines)
