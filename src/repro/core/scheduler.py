"""Programmable operator scheduling — paper Fig. 6.

``OpSchedulerBase.schedule(ctx)`` is user Python that builds the execution
plan through three primitives:

  * ``ctx.split([bs_1..bs_n])``   — create n micro-batches (local sizes)
  * ``ctx.get_ready_ops(i)``      — control-flow-ready ops of micro-batch i
  * ``ctx.execute(ops, replace_func=...)`` — dispatch; a tuple of the same
    op across all micro-batches merges them; ``replace_func`` substitutes a
    fused kernel; different ops without a kernel fall back to sequential.

The scheduler runs in *record mode* per (graph, context-bucket): decisions
may depend on static context (batch size, seq len, phase, mesh) — exactly
the information the paper's CUDA-graph-compatible mode can condition on.
The recorded plan is validated (every op executed exactly once per
micro-batch, dependencies honoured) and handed to the backend.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence, Union

from .graph import FULL, OpGraph
from .plan import ExecutionPlan, OpHandle, PlanStep, graph_fingerprint


class ScheduleError(RuntimeError):
    """A schedule violated the recording contract; ``diagnostic`` (when
    set) carries the typed finding behind the message."""

    def __init__(self, message: str, diagnostic=None):
        super().__init__(message)
        self.diagnostic = diagnostic


@dataclasses.dataclass
class ScheduleContext:
    """Static context a schedule may condition on (the paper's 'execution
    context': workload, model architecture, hardware)."""

    local_batch: int = 0
    global_batch: int = 0
    seq_len: int = 0
    phase: str = "train"          # train | prefill | decode
    arch: str = ""
    mesh_shape: dict = dataclasses.field(default_factory=dict)
    extra: dict = dataclasses.field(default_factory=dict)


class SchedCtx:
    """The object handed to ``schedule()`` — records the plan."""

    def __init__(self, graph: OpGraph, info: ScheduleContext):
        self.graph = graph
        self.info = info
        self.split_sizes: tuple[int, ...] = ()
        self.steps: list[PlanStep] = []
        # availability: tid -> set of parts available (FULL or mb index)
        self._avail: dict[int, set] = {}
        self._done: dict[int, set] = {}   # oid -> parts executed
        input_tids = set(graph.inputs.values())
        for t in input_tids:
            self._avail[t] = {FULL}
        self._input_tids = input_tids

    # -- paper primitives ---------------------------------------------------
    def split(self, sizes: Sequence[int]):
        if self.steps:
            raise RuntimeError("split() must be called before any execute()")
        if self.split_sizes:
            raise RuntimeError("split() may be called once")
        sizes = tuple(int(s) for s in sizes)
        if self.info.local_batch and sum(sizes) != self.info.local_batch:
            raise ValueError(
                f"split sizes {sizes} must sum to local batch "
                f"{self.info.local_batch}")
        if any(s <= 0 for s in sizes):
            raise ValueError(f"split sizes must be positive: {sizes}")
        self.split_sizes = sizes

    def get_ready_ops(self, i: int = None) -> list[OpHandle]:
        """Ready ops for micro-batch ``i`` (or FULL when unsplit)."""
        part = FULL if not self.split_sizes else i
        if part is None:
            part = FULL
        out = []
        for oid in self.graph.topo_order():
            n = self.graph.nodes[oid]
            if part in self._done.get(oid, set()):
                continue
            if not self.graph.splittable(oid) and part != self._first_part():
                continue  # unsplittable ops belong to the first micro-batch
            if all(self._input_ok(t, part) for t in n.inputs):
                out.append(OpHandle(oid, part, n.name))
        return out

    def execute(self, ops: Union[OpHandle, Sequence[OpHandle]],
                replace_func: Optional[Callable] = None,
                replace_name: str = ""):
        if isinstance(ops, OpHandle):
            ops = (ops,)
        ops = tuple(ops)
        if not ops:
            return
        if replace_func is not None:
            self._record(PlanStep("fused", ops,
                                  replace_name or getattr(replace_func, "__name__", "k"),
                                  replace_func))
            return
        same_op = len({h.oid for h in ops}) == 1
        if len(ops) > 1 and same_op:
            mbs = sorted(h.mb for h in ops)
            if mbs != list(range(len(self.split_sizes))):
                raise ValueError(
                    f"merged execution must cover all micro-batches; got {mbs}")
            self._record(PlanStep("merged", ops))
            return
        # different ops, no kernel: sequential fallback (paper §3.2.2)
        for h in ops:
            self._record(PlanStep("exec", (h,)))

    # -- conveniences ---------------------------------------------------------
    def handles(self, i: int = None) -> list[OpHandle]:
        """All handles of micro-batch i in topo order (ignores readiness)."""
        part = FULL if not self.split_sizes else (0 if i is None else i)
        return [OpHandle(oid, part if self.graph.splittable(oid)
                         else self._first_part(),
                         self.graph.nodes[oid].name)
                for oid in self.graph.topo_order()]

    def find(self, pattern: str, i: int = None) -> list[OpHandle]:
        return [h for h in self.handles(i)
                if re.search(pattern, self.graph.nodes[h.oid].name)]

    def resource_of(self, h: OpHandle) -> str:
        return self.graph.nodes[h.oid].resource

    def run_rest_sequential(self):
        """Finish everything not yet executed, in topo order."""
        progress = True
        while progress:
            progress = False
            for part in self._parts():
                for h in self.get_ready_ops(part):
                    self.execute(h)
                    progress = True

    # -- internals -------------------------------------------------------------
    def _parts(self):
        return list(range(len(self.split_sizes))) if self.split_sizes else [FULL]

    def _first_part(self):
        return 0 if self.split_sizes else FULL

    def _input_ok(self, tid: int, part) -> bool:
        from .graph import VBATCH
        avail = self._avail.get(tid, set())
        if FULL in avail:
            return True
        ref = self.graph.tensors[tid]
        if part == FULL:
            # consuming merged: need every part present (prealloc merge)
            return (bool(self.split_sizes)
                    and ref.batch_dim not in (None, VBATCH)
                    and avail >= set(range(len(self.split_sizes))))
        if part in avail:
            return True
        return False

    def _record(self, step: PlanStep):
        # tensors produced inside a fused group are satisfied by the kernel
        group_internal = {t for h in step.handles
                          for t in self.graph.nodes[h.oid].outputs} \
            if step.kind == "fused" else set()
        handles = step.handles if step.kind != "merged" else step.handles[:1]
        for h in handles:
            n = self.graph.nodes.get(h.oid)
            if n is None:
                raise ValueError(f"unknown op {h}")
            done = self._done.setdefault(h.oid, set())
            parts = set(self._parts()) if step.kind == "merged" else {h.mb}
            if done & parts:
                raise ScheduleError(f"{h} already executed")
            check_part = FULL if step.kind == "merged" else h.mb
            for t in n.inputs:
                if t in group_internal:
                    continue
                if not self._input_ok(t, check_part):
                    raise ScheduleError(
                        f"dependency violation: {h} needs tensor {t} "
                        f"part {check_part} before it is produced")
            done |= parts
            for t in n.outputs:
                ref = self.graph.tensors[t]
                if step.kind == "merged" or ref.batch_dim is None:
                    p = FULL
                else:
                    p = h.mb
                self._avail.setdefault(t, set()).add(p)
        self.steps.append(step)

    # -- finalize ---------------------------------------------------------------
    def finalize(self) -> ExecutionPlan:
        missing = []
        for oid in self.graph.topo_order():
            need = set(self._parts()) if self.graph.splittable(oid) \
                else {self._first_part()}
            done = self._done.get(oid, set())
            if not (need <= done or FULL in done):
                missing.append((self.graph.nodes[oid].name, need - done))
        if missing:
            from .verify import format_missing
            raise ScheduleError(
                f"schedule incomplete; {format_missing(missing)}")
        return ExecutionPlan(list(self.steps), self.split_sizes,
                             graph_fingerprint(self.graph))


class OpSchedulerBase:
    """Base class for user schedulers (paper Fig. 6)."""

    name = "base"

    def partition_rules(self) -> list:
        """Graph-partition annotations this strategy wants (paper Fig. 5)."""
        return []

    def schedule(self, ctx: SchedCtx):
        """Default: sequential execution (the paper's fallback mode)."""
        ctx.run_rest_sequential()


def record_plan(graph: OpGraph, scheduler: OpSchedulerBase,
                info: ScheduleContext,
                verify: str = "off") -> ExecutionPlan:
    """Record a plan; ``verify`` runs the static verifier on the result:
    ``"off"`` (default) skips it, ``"warn"`` emits a Python warning on
    error-severity diagnostics, ``"strict"`` raises
    :class:`~repro.core.verify.PlanVerificationError`."""
    ctx = SchedCtx(graph, info)
    scheduler.schedule(ctx)
    plan = ctx.finalize()
    if verify != "off":
        from .verify import enforce, verify as run_verify
        report = run_verify(graph, plan, lint=False)
        enforce(report, verify, what=f"plan from {scheduler.name!r}")
    return plan
