"""Unified PlanStore — the plan/capture cache behind cheap re-dispatch.

DynaFlow's backend wins by amortizing scheduling work across many
invocation shapes (the paper's CUDA-graph capture/replay, §3.3.2).  PR 1
left that amortization split across two caches keyed per (model, mesh,
bucket): a ``CompileCache`` of jitted executables and a
``LoweredPlanCache`` of lowered plans, both keyed by the *shape-covering*
v1 plan fingerprint — so every prefill bucket re-ran static analysis and
lowering for what is structurally the same layer program.

``PlanStore`` collapses the pair into one subsystem with a two-level
plan cache:

  * **outer key — fingerprint v2** (``outer_key``; printable digest via
    ``fingerprint_v2``): the shape-free structural identity of the
    (graph, plan) pair, combined with the strategy identity (the
    caller's ``salt``) and the op-closure config (attention impl, shard
    layout, dtype policy — everything the op callables close over that
    the graph cannot see).
  * **inner key — the shape bucket** (``bucket_key``): graph input
    shapes/dtypes, concrete split sizes, capture flag.

The first bucket of an outer entry pays the full ``lower`` (static
analysis + slot allocation) and becomes the **canonical** lowering;
every later bucket is derived from it via ``specialize`` — a single
pass that rewrites slice offsets and merge-buffer pads — and is counted
as a *share*, not a miss.

Entries are LRU-bounded both by count and by an estimated byte budget;
evictions, hits, misses and shares are all counted in ``stats``.  The
executable level (``get_or_build``) keeps the old CompileCache contract
under ``exec_*`` counters.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, Optional

import jax

from .lowering import LoweredPlan, LoweringError, lower, specialize
from .plan import structural_key


def outer_key(graph, plan, salt: str = "", op_config=(),
              struct_key_: Optional[tuple] = None) -> tuple:
    """Fingerprint-v2 outer key: structure + strategy identity + op
    closures, as a raw hashable tuple (the store's dict key — tuple
    hashing is ~3x cheaper than a digest on the warm-up path).

    ``op_config`` is a canonical tuple of (name, value) pairs describing
    what the op callables close over — see ``LMBase.op_closure_config``.
    ``struct_key_`` short-circuits the structural walk when the caller
    already holds ``structural_key(graph, plan)``.
    """
    return (struct_key_ if struct_key_ is not None
            else structural_key(graph, plan),
            salt, tuple(sorted(tuple(op_config))))


def fingerprint_v2(graph, plan, salt: str = "", op_config=()) -> str:
    """Printable digest of the fingerprint-v2 outer key (logs, docs)."""
    import hashlib
    h = hashlib.sha256(repr(outer_key(graph, plan, salt, op_config))
                       .encode())
    return h.hexdigest()[:16]


def bucket_key(graph, plan, capture: bool = True) -> tuple:
    """Inner PlanStore key: the shape bucket of a (graph, plan) pair."""
    shapes = tuple(
        (name, graph.tensors[t].shape, str(graph.tensors[t].dtype))
        for name, t in sorted(graph.inputs.items()))
    return (shapes, tuple(plan.split_sizes), bool(capture))


def plan_nbytes(lowered: LoweredPlan) -> int:
    """Deterministic host-memory estimate of one lowered plan.

    Not a profiler — a monotone proxy (instructions, slots, interned
    paths) so the byte budget evicts big plans before small ones.
    """
    n = 512
    for ins in lowered.instrs:
        n += 256 + 48 * (len(ins.reads) + len(ins.writes) + len(ins.frees)
                         + len(ins.fused_pairs)
                         + len(ins.member_pairs or ()))
    n += 64 * (lowered.n_slots + len(lowered.param_paths)
               + len(lowered.input_slots) + len(lowered.output_slots))
    return n


class PlanStore:
    """Two-level lowered-plan cache + executable cache, unified.

    Plan level  — ``get_or_lower``: (fingerprint v2) -> (bucket) ->
    ``LoweredPlan``; cross-bucket requests specialize the canonical
    lowering instead of re-running analysis + lowering.

    Exec level  — ``get_or_build``: arbitrary key -> jitted executable
    (the runtime dispatcher's CUDA-graph-replay analogue).
    """

    def __init__(self, plan_capacity: int = 256,
                 plan_budget_bytes: Optional[int] = None,
                 exec_capacity: int = 128,
                 capacity: Optional[int] = None):
        # ``capacity`` kept for LoweredPlanCache call-site compatibility
        self.plan_capacity = capacity if capacity is not None \
            else plan_capacity
        self.plan_budget_bytes = plan_budget_bytes
        self.exec_capacity = exec_capacity
        self._plans: OrderedDict = OrderedDict()   # (outer, inner) -> entry
        self._canonical: dict = {}                 # outer -> (outer, inner)
        self._execs: OrderedDict = OrderedDict()
        self.stats = {
            "hits": 0, "misses": 0, "shares": 0, "evictions": 0,
            "lower_s": 0.0, "specialize_s": 0.0, "plan_bytes": 0,
            "exec_hits": 0, "exec_misses": 0, "exec_evictions": 0,
            "compile_s": 0.0, "trace_s": 0.0,
        }

    # -- plan level --------------------------------------------------------
    def get_or_lower(self, graph, plan, analysis=None, salt: str = "",
                     capture: bool = True, op_config=()) -> LoweredPlan:
        skey = structural_key(graph, plan)
        outer = outer_key(graph, plan, salt=salt, op_config=op_config,
                          struct_key_=skey)
        key = (outer, bucket_key(graph, plan, capture))
        hit = self._plans.get(key)
        if hit is not None:
            self.stats["hits"] += 1
            self._plans.move_to_end(key)
            return hit[0]
        canonical = self._canonical_plan(outer)
        if canonical is not None:
            t0 = time.perf_counter()
            try:
                lowered = specialize(canonical, graph, plan, capture=capture,
                                     struct_key=skey)
            except LoweringError:
                lowered = None          # structure drifted: full lower below
            if lowered is not None:
                self.stats["specialize_s"] += time.perf_counter() - t0
                self.stats["shares"] += 1
                # a specialized plan has the canonical's instr structure,
                # so its byte estimate is the canonical's — skip the walk
                nbytes = self._plans[self._canonical[outer]][1]
                self._insert(outer, key, lowered, nbytes)
                return lowered
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        lowered = lower(graph, plan, analysis, capture=capture)
        self.stats["lower_s"] += time.perf_counter() - t0
        self._insert(outer, key, lowered)
        return lowered

    @property
    def share_rate(self) -> float:
        """Fraction of cold (non-hit) lookups served by specialization."""
        cold = self.stats["shares"] + self.stats["misses"]
        return self.stats["shares"] / cold if cold else 0.0

    def _canonical_plan(self, outer) -> Optional[LoweredPlan]:
        key = self._canonical.get(outer)
        entry = self._plans.get(key) if key is not None else None
        return entry[0] if entry is not None else None

    def _insert(self, outer, key, lowered: LoweredPlan,
                nbytes: Optional[int] = None):
        if nbytes is None:
            nbytes = plan_nbytes(lowered)
        self._plans[key] = (lowered, nbytes)
        self.stats["plan_bytes"] += nbytes
        self._canonical.setdefault(outer, key)
        self._evict_plans()

    def _evict_plans(self):
        while len(self._plans) > self.plan_capacity or (
                self.plan_budget_bytes is not None
                and self.stats["plan_bytes"] > self.plan_budget_bytes
                and len(self._plans) > 1):
            key, (_, nbytes) = self._plans.popitem(last=False)
            self.stats["plan_bytes"] -= nbytes
            self.stats["evictions"] += 1
            outer = key[0]
            if self._canonical.get(outer) == key:
                # promote the most-recently-used surviving bucket of this
                # outer entry (scan from the MRU end — the LRU end is next
                # in line for eviction, which would re-trigger promotion
                # on every pop under sustained pressure)
                repl = next((k for k in reversed(self._plans)
                             if k[0] == outer), None)
                if repl is None:
                    del self._canonical[outer]
                else:
                    self._canonical[outer] = repl

    # -- executable level --------------------------------------------------
    def key_for(self, plan_fp: str, inputs: dict) -> tuple:
        shapes = tuple(sorted(
            (k, tuple(v.shape), str(getattr(v, "dtype", type(v))))
            for k, v in inputs.items()))
        return (plan_fp, shapes)

    def get_or_build(self, key, build: Callable[[], Callable],
                     example_args: Optional[tuple] = None):
        if key in self._execs:
            self.stats["exec_hits"] += 1
            self._execs.move_to_end(key)
            return self._execs[key]
        self.stats["exec_misses"] += 1
        t0 = time.perf_counter()
        fn = build()
        self.stats["trace_s"] += time.perf_counter() - t0
        if example_args is not None:
            t0 = time.perf_counter()
            fn = jax.jit(fn).lower(*example_args).compile()
            self.stats["compile_s"] += time.perf_counter() - t0
        self._execs[key] = fn
        while len(self._execs) > self.exec_capacity:
            self._execs.popitem(last=False)
            self.stats["exec_evictions"] += 1
        return fn

    # -- introspection -----------------------------------------------------
    @property
    def n_plans(self) -> int:
        return len(self._plans)

    @property
    def n_execs(self) -> int:
        return len(self._execs)

    def __len__(self):
        return len(self._plans) + len(self._execs)

    def snapshot(self) -> dict:
        out = dict(self.stats)
        out["n_plans"] = self.n_plans
        out["n_execs"] = self.n_execs
        out["share_rate"] = round(self.share_rate, 4)
        return out


GLOBAL_STORE = PlanStore()
