"""Unified PlanStore — the plan/capture cache behind cheap re-dispatch.

DynaFlow's backend wins by amortizing scheduling work across many
invocation shapes (the paper's CUDA-graph capture/replay, §3.3.2).  PR 1
left that amortization split across two caches keyed per (model, mesh,
bucket): a ``CompileCache`` of jitted executables and a
``LoweredPlanCache`` of lowered plans, both keyed by the *shape-covering*
v1 plan fingerprint — so every prefill bucket re-ran static analysis and
lowering for what is structurally the same layer program.

``PlanStore`` collapses the pair into one subsystem with a two-level
plan cache:

  * **outer key — fingerprint v2** (``outer_key``; printable digest via
    ``fingerprint_v2``): the shape-free structural identity of the
    (graph, plan) pair, combined with the strategy identity (the
    caller's ``salt``) and the op-closure config (attention impl, shard
    layout, dtype policy — everything the op callables close over that
    the graph cannot see).
  * **inner key — the shape bucket** (``bucket_key``): graph input
    shapes/dtypes, concrete split sizes, capture flag.

The first bucket of an outer entry pays the full ``lower`` (static
analysis + slot allocation) and becomes the **canonical** lowering;
every later bucket is derived from it via ``specialize`` — a single
pass that rewrites slice offsets and merge-buffer pads — and is counted
as a *share*, not a miss.

**Persistence.**  Because fingerprint v2 is shape-free and closure-aware,
a lowering is a reusable artifact *across processes*: ``save()``
serializes every persistable entry (``core.plan_serde`` — instruction
tuples, slots, liveness, interned param paths, merge-pad metadata;
callables and jaxpr captures excluded), and ``load()`` /
``PlanStore.open()`` restore them lazily.  A restored bucket is
*redeemed* on first request — callables rebound from the caller's live
(graph, plan), counted as a ``restore_hit`` — and an unseen bucket of a
restored entry specializes a rehydrated canonical skeleton instead of
re-lowering.  A warm-started process therefore serves every
previously-seen bucket without a single ``lower`` call.  Corrupt or
version-mismatched files degrade to cold lowering, counted under the
``restore_*`` stats family.

**Admission policy.**  Eviction stats feed persistence: a bucket evicted
before a second touch is recorded as *one-shot* and never re-admitted to
the on-disk artifact (the record itself is persisted in the file
header), keeping the store bounded under bucket churn.

Entries are LRU-bounded both by count and by an estimated byte budget;
evictions, hits, misses and shares are all counted in ``stats``.  The
executable level (``get_or_build``) keeps the old CompileCache contract
under ``exec_*`` counters, with its own entry-count and byte budgets.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, Optional

import jax

from .._deprecation import warn_once
from .lowering import LoweredPlan, LoweringError, lower, specialize
from .plan import FINGERPRINT_VERSION, structural_key
from .plan_serde import (FORMAT_VERSION, RestoreError, encode_analysis,
                         encode_lowered, entry_line, key_digest,
                         parse_payload, persistable_key, read_store,
                         rehydrate, split_entry_line, split_verdict_line,
                         verdict_line, write_store)

_ONE_SHOT_CAP = 4096          # bounded one-shot eviction record
_PASSTHROUGH_CAP = 1024       # max never-redeemed entries kept per save
_EXEC_DEFAULT_NBYTES = 1 << 12  # floor estimate for un-analyzable execs


def outer_key(graph, plan, salt: str = "", op_config=(),
              struct_key_: Optional[tuple] = None) -> tuple:
    """Fingerprint-v2 outer key: structure + strategy identity + op
    closures, as a raw hashable tuple (the store's dict key — tuple
    hashing is ~3x cheaper than a digest on the warm-up path).

    ``op_config`` is a canonical tuple of (name, value) pairs describing
    what the op callables close over — see ``LMBase.op_closure_config``.
    ``struct_key_`` short-circuits the structural walk when the caller
    already holds ``structural_key(graph, plan)``.
    """
    return (struct_key_ if struct_key_ is not None
            else structural_key(graph, plan),
            salt, tuple(sorted(tuple(op_config))))


def fingerprint_v2(graph, plan, salt: str = "", op_config=()) -> str:
    """Printable digest of the fingerprint-v2 outer key (logs, docs,
    and the per-entry header of the persisted store)."""
    return key_digest(outer_key(graph, plan, salt, op_config))


def bucket_key(graph, plan, capture: bool = True) -> tuple:
    """Inner PlanStore key: the shape bucket of a (graph, plan) pair."""
    shapes = tuple(
        (name, graph.tensors[t].shape, str(graph.tensors[t].dtype))
        for name, t in sorted(graph.inputs.items()))
    return (shapes, tuple(plan.split_sizes), bool(capture))


def plan_nbytes(lowered: LoweredPlan) -> int:
    """Deterministic host-memory estimate of one lowered plan.

    Not a profiler — a monotone proxy (instructions, slots, interned
    paths) so the byte budget evicts big plans before small ones.
    """
    n = 512
    for ins in lowered.instrs:
        n += 256 + 48 * (len(ins.reads) + len(ins.writes) + len(ins.frees)
                         + len(ins.fused_pairs)
                         + len(ins.member_pairs or ()))
    n += 64 * (lowered.n_slots + len(lowered.param_paths)
               + len(lowered.input_slots) + len(lowered.output_slots))
    return n


class PlanStore:
    """Two-level lowered-plan cache + executable cache, unified.

    Plan level  — ``get_or_lower``: (fingerprint v2) -> (bucket) ->
    ``LoweredPlan``; cross-bucket requests specialize the canonical
    lowering instead of re-running analysis + lowering; cross-process
    requests redeem entries restored from a persisted store file.

    Exec level  — ``get_or_build``: arbitrary key -> jitted executable
    (the runtime dispatcher's CUDA-graph-replay analogue).
    """

    def __init__(self, plan_capacity: int = 256,
                 plan_budget_bytes: Optional[int] = None,
                 exec_capacity: int = 128,
                 exec_budget_bytes: Optional[int] = None,
                 capacity: Optional[int] = None,
                 path: Optional[str] = None,
                 verify_restored: bool = True):
        # ``capacity`` kept for LoweredPlanCache call-site compatibility
        self.plan_capacity = capacity if capacity is not None \
            else plan_capacity
        # semantic verification of rehydrated instruction streams — the
        # check *behind* the checksum: a stale or tampered artifact whose
        # digest and fingerprint both pass can still alias live slots
        self.verify_restored = verify_restored
        self.plan_budget_bytes = plan_budget_bytes
        self.exec_capacity = exec_capacity
        self.exec_budget_bytes = exec_budget_bytes
        self.path = path
        self._plans: OrderedDict = OrderedDict()   # (outer, inner) -> entry
        self._canonical: dict = {}                 # outer -> (outer, inner)
        self._execs: OrderedDict = OrderedDict()   # key -> (fn, nbytes)
        self._touches: dict = {}                   # plan key -> reuse count
        self._one_shot: OrderedDict = OrderedDict()  # (odig, bdig) -> None
        # restored-but-unredeemed state: verbatim entry lines by fp2
        # digest (checksum-verified at load, JSON parse deferred to
        # first use) and parsed entries by outer key
        self._restored_raw: dict = {}
        self._restored_parsed: dict = {}
        self._verdicts: dict = {}                  # context_fp -> payload
        self._dirty = False                        # plan-level state vs disk
        self.stats = {
            "hits": 0, "misses": 0, "shares": 0, "evictions": 0,
            "specialize_rejects": 0,
            "lower_s": 0.0, "specialize_s": 0.0, "plan_bytes": 0,
            "one_shot_evictions": 0,
            "restore_hits": 0, "restore_canonicals": 0,
            "restore_entries": 0, "restore_rejected": 0,
            "restore_verify_rejected": 0,
            "restore_errors": 0, "restore_saved": 0, "restore_skipped": 0,
            "restore_s": 0.0,
            "exec_hits": 0, "exec_misses": 0, "exec_evictions": 0,
            "exec_bytes": 0, "compile_s": 0.0, "trace_s": 0.0,
            "verdicts_put": 0, "verdict_hits": 0, "verdict_misses": 0,
            "verdict_rejected": 0,
        }

    # -- plan level --------------------------------------------------------
    def get_or_lower(self, graph, plan, analysis=None, salt: str = "",
                     capture: bool = True, op_config=()) -> LoweredPlan:
        skey = structural_key(graph, plan)
        outer = outer_key(graph, plan, salt=salt, op_config=op_config,
                          struct_key_=skey)
        key = (outer, bucket_key(graph, plan, capture))
        hit = self._plans.get(key)
        if hit is not None:
            self.stats["hits"] += 1
            self._touches[key] = self._touches.get(key, 0) + 1
            self._plans.move_to_end(key)
            return hit[0]
        restored = self._restored_entry(outer) \
            if (self._restored_raw or self._restored_parsed) else None
        if restored is not None:
            # the record is kept after a successful redeem: it serves
            # again if LRU churn evicts the live entry, and save()'s
            # pass-through re-persists it (a short-lived or
            # budget-squeezed process must never shrink the artifact)
            rec = restored["buckets"].get(key[1])
            if rec is not None:
                lowered = self._redeem(rec, restored, graph, plan, skey,
                                       outer, key)
                if lowered is not None:
                    return lowered
                restored["buckets"].pop(key[1], None)   # rejected: no retry
        canonical = self._canonical_plan(outer)
        if canonical is None and restored is not None:
            canonical = self._skeleton_canonical(restored, outer, graph,
                                                 plan, skey)
        if canonical is not None:
            t0 = time.perf_counter()
            try:
                lowered = specialize(canonical, graph, plan, capture=capture,
                                     struct_key=skey)
            except LoweringError:
                # structure drifted (e.g. a batch tier whose scheduler
                # changed the micro-batch count): full lower below,
                # observable so tier configs that never share are loud
                lowered = None
                self.stats["specialize_rejects"] += 1
            if lowered is not None:
                self.stats["specialize_s"] += time.perf_counter() - t0
                self.stats["shares"] += 1
                # a specialized plan has the canonical's instr structure,
                # so its byte estimate is the canonical's — skip the walk
                # (unless the canonical is a restored skeleton not held
                # in the live table)
                nbytes = None
                ck = self._canonical.get(outer)
                if ck is not None:
                    entry = self._plans.get(ck)
                    if entry is not None:
                        nbytes = entry[1]
                        self._touches[ck] = self._touches.get(ck, 0) + 1
                self._insert(outer, key, lowered, nbytes)
                return lowered
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        lowered = lower(graph, plan, analysis, capture=capture)
        self.stats["lower_s"] += time.perf_counter() - t0
        self._insert(outer, key, lowered)
        return lowered

    @property
    def share_rate(self) -> float:
        """Fraction of cold (non-hit) lookups served by specialization."""
        cold = self.stats["shares"] + self.stats["misses"]
        return self.stats["shares"] / cold if cold else 0.0

    def _canonical_plan(self, outer) -> Optional[LoweredPlan]:
        key = self._canonical.get(outer)
        entry = self._plans.get(key) if key is not None else None
        return entry[0] if entry is not None else None

    def _insert(self, outer, key, lowered: LoweredPlan,
                nbytes: Optional[int] = None):
        if nbytes is None:
            nbytes = plan_nbytes(lowered)
        self._plans[key] = (lowered, nbytes)
        self._touches.setdefault(key, 0)
        self.stats["plan_bytes"] += nbytes
        self._canonical.setdefault(outer, key)
        self._dirty = True
        self._evict_plans()

    def _evict_plans(self):
        while len(self._plans) > self.plan_capacity or (
                self.plan_budget_bytes is not None
                and self.stats["plan_bytes"] > self.plan_budget_bytes
                and len(self._plans) > 1):
            key, (_, nbytes) = self._plans.popitem(last=False)
            self.stats["plan_bytes"] -= nbytes
            self.stats["evictions"] += 1
            if self._touches.pop(key, 0) == 0:
                # evicted before a second touch: a one-shot bucket.  The
                # admission policy bars it from the persisted artifact.
                self.stats["one_shot_evictions"] += 1
                self._one_shot[(key_digest(key[0]),
                                key_digest(key[1]))] = None
                while len(self._one_shot) > _ONE_SHOT_CAP:
                    self._one_shot.popitem(last=False)
            outer = key[0]
            if self._canonical.get(outer) == key:
                # promote the most-recently-used surviving bucket of this
                # outer entry (scan from the MRU end — the LRU end is next
                # in line for eviction, which would re-trigger promotion
                # on every pop under sustained pressure)
                repl = next((k for k in reversed(self._plans)
                             if k[0] == outer), None)
                if repl is None:
                    del self._canonical[outer]
                else:
                    self._canonical[outer] = repl

    # -- persistence -------------------------------------------------------
    @classmethod
    def open(cls, path: str, **kwargs) -> "PlanStore":
        """Construct a store bound to ``path``, warm-starting from it when
        the file exists (missing file = empty store, not an error).
        ``save()`` with no argument writes back to the same path."""
        store = cls(path=path, **kwargs)
        import os
        if os.path.exists(path):
            store.load(path)
        return store

    def load(self, path: Optional[str] = None) -> int:
        """Restore persisted entries from ``path`` (default: the bound
        path).  Returns the number of restorable outer entries staged.

        Entries are staged lazily: the load pass verifies the header and
        per-entry checksums only; JSON parsing and callable rebinding
        happen on first request (*redeem*).  A corrupt or
        version-mismatched file rejects wholesale (``restore_errors``);
        a corrupt entry rejects alone (``restore_rejected``) — either
        way requests degrade to a cold ``lower``.
        """
        path = path or self.path
        if path is None:
            raise ValueError("PlanStore.load: no path given or bound")
        try:
            one_shot, lines = read_store(
                path, fingerprint_version=FINGERPRINT_VERSION)
        except RestoreError:
            self.stats["restore_errors"] += 1
            return 0
        for dig in one_shot:
            self._one_shot.setdefault(dig, None)
        n = 0
        for line in lines:
            if line.startswith("V "):
                try:
                    fp, payload = split_verdict_line(line)
                except RestoreError:
                    self.stats["verdict_rejected"] += 1
                    continue
                # setdefault: a verdict put live this process wins over
                # the (older) persisted one
                self._verdicts.setdefault(fp, payload)
                continue
            try:
                fp2, _payload = split_entry_line(line)
            except RestoreError:
                self.stats["restore_rejected"] += 1
                continue
            self._restored_raw[fp2] = line
            n += 1
        self.stats["restore_entries"] += n
        return n

    def save(self, path: Optional[str] = None) -> int:
        """Atomically persist the canonical lowerings to ``path``
        (default: the bound path).  Returns the number of outer entries
        written.

        Only **canonical** buckets are serialized: every derived bucket
        is one cheap ``specialize`` away at restore time, so persisting
        it would grow the artifact without shrinking the warm path.
        Excluded entirely: entries whose outer key carries a
        process-local closure identity (they could never match after a
        restart) and canonicals recorded one-shot by the admission
        policy.  Restored-but-unredeemed entries pass through, so
        short-lived processes do not shrink the artifact.
        """
        path = path or self.path
        if path is None:
            raise ValueError("PlanStore.save: no path given or bound")
        lines = []
        covered = set()
        skipped = 0
        for outer, ckey in self._canonical.items():
            entry = self._plans.get(ckey)
            if entry is None:
                continue
            bkey = ckey[1]
            if not (persistable_key(outer) and persistable_key(bkey)):
                skipped += 1
                continue
            odig = key_digest(outer)
            if (odig, key_digest(bkey)) in self._one_shot:
                skipped += 1
                continue
            lowered = entry[0]
            lines.append(entry_line(
                outer, encode_analysis(lowered.analysis), bkey,
                [encode_lowered(bkey, lowered)], fp2=odig))
            covered.add(odig)
        # entries parsed but not superseded by a live canonical pass
        # through (their canonical bucket was never redeemed here)
        for outer, parsed in self._restored_parsed.items():
            if parsed["fp2"] in covered or not parsed["buckets"]:
                continue
            rec = parsed["buckets"].get(parsed["canonical"]) \
                or next(iter(parsed["buckets"].values()))
            lines.append(entry_line(outer, parsed["analysis"],
                                    rec["bucket"], [rec],
                                    fp2=parsed["fp2"]))
            covered.add(parsed["fp2"])
        # raw entries never touched this process pass through verbatim
        # (checksums were verified at load — no re-hash), capped so a
        # store relayed across many generations cannot accumulate stale
        # entries without bound
        passthrough = sorted(fp2 for fp2 in self._restored_raw
                             if fp2 not in covered)
        skipped += max(0, len(passthrough) - _PASSTHROUGH_CAP)
        for fp2 in passthrough[:_PASSTHROUGH_CAP]:
            lines.append(self._restored_raw[fp2])
        for fp, payload in sorted(self._verdicts.items()):
            lines.append(verdict_line(fp, payload))
        n = write_store(path, lines, one_shot=self._one_shot,
                        fingerprint_version=FINGERPRINT_VERSION)
        self.stats["restore_saved"] = n
        self.stats["restore_skipped"] += skipped
        if path == self.path:
            self._dirty = False
        return n

    @property
    def dirty(self) -> bool:
        """True when plan-level state changed since the last ``save()``
        to the bound path — lets periodic checkpoints (serve idle loop)
        skip rewriting an unchanged artifact."""
        return self._dirty

    # -- verdict level -----------------------------------------------------
    def put_verdict(self, context_fp: str, payload: dict):
        """Record an autotuner verdict (``core.autotune``) for
        persistence; last write per context fingerprint wins."""
        self._verdicts[context_fp] = payload
        self.stats["verdicts_put"] += 1
        self._dirty = True

    def get_verdict(self, context_fp: str) -> Optional[dict]:
        """The persisted/recorded verdict payload for a context
        fingerprint, or ``None`` (caller re-tunes cold)."""
        payload = self._verdicts.get(context_fp)
        if payload is None:
            self.stats["verdict_misses"] += 1
        else:
            self.stats["verdict_hits"] += 1
        return payload

    @property
    def verdict_count(self) -> int:
        return len(self._verdicts)

    def _restored_entry(self, outer) -> Optional[dict]:
        parsed = self._restored_parsed.get(outer)
        if parsed is not None:
            return parsed
        if not self._restored_raw:
            return None
        raw = self._restored_raw.pop(key_digest(outer), None)
        if raw is None:
            return None
        try:
            payload = parse_payload(raw.split(" ", 4)[4])
            # entries are digest-addressed; the salt rides along as a
            # cheap cross-check (full safety comes from rehydrate's
            # plan-fingerprint verification)
            if payload["salt"] != outer[1]:
                raise RestoreError("entry digest does not match its key")
        except RestoreError:
            self.stats["restore_rejected"] += 1
            return None
        parsed = {"fp2": key_digest(outer),
                  "analysis": payload["analysis"],
                  "canonical": payload["canonical"],
                  "buckets": {rec["bucket"]: rec
                              for rec in payload["buckets"]
                              if isinstance(rec, dict) and "bucket" in rec}}
        self._restored_parsed[outer] = parsed
        return parsed

    def _redeem(self, rec, restored, graph, plan, skey, outer,
                key) -> Optional[LoweredPlan]:
        """Exact-bucket restore: rebind callables from the live (graph,
        plan) and admit the result as a live entry — zero ``lower`` and
        zero ``specialize`` cost."""
        t0 = time.perf_counter()
        try:
            lowered = rehydrate(rec, restored["analysis"], graph, plan,
                                struct_key=skey)
        except RestoreError:
            self.stats["restore_rejected"] += 1
            return None
        if not self._verify_restored_plan(lowered):
            return None
        self.stats["restore_s"] += time.perf_counter() - t0
        self.stats["restore_hits"] += 1
        self._insert(outer, key, lowered)
        # a cross-generation reuse is by definition not one-shot
        self._touches[key] = self._touches.get(key, 0) + 1
        return lowered

    def _skeleton_canonical(self, restored, outer, graph, plan,
                            skey) -> Optional[LoweredPlan]:
        """Rehydrate the restored entry's canonical bucket as a fn-less
        skeleton for ``specialize`` to derive *unseen* buckets from.
        ``specialize`` rebinds every callable and rewrites every
        shape-dependent field, so the skeleton's dangling fns and stale
        offsets are never observable.  No memo: whatever follows this
        call — a successful specialize or a cold lower — installs a real
        canonical via ``_insert``, so the skeleton path runs at most
        once per outer entry."""
        rec = restored["buckets"].get(restored["canonical"])
        if rec is None and restored["buckets"]:
            rec = next(iter(restored["buckets"].values()))
        if rec is None:
            return None
        try:
            skel = rehydrate(rec, restored["analysis"], graph, plan,
                             struct_key=skey, bind_fns=False)
        except RestoreError:
            self.stats["restore_rejected"] += 1
            return None
        if not self._verify_restored_plan(skel):
            return None
        self.stats["restore_canonicals"] += 1
        return skel

    def _verify_restored_plan(self, lowered: LoweredPlan) -> bool:
        """Semantic gate behind the checksum: symbolically replay the
        rehydrated slot machine (``core.verify``).  A rejected artifact
        degrades to a cold lower under ``restore_verify_rejected`` — it
        is never admitted, never retried."""
        if not self.verify_restored:
            return True
        from .verify import verify_lowered
        errors = [d for d in verify_lowered(lowered)
                  if d.severity == "error"]
        if errors:
            self.stats["restore_rejected"] += 1
            self.stats["restore_verify_rejected"] += 1
            return False
        return True

    # -- executable level --------------------------------------------------
    def key_for(self, plan_fp: str, inputs: dict) -> tuple:
        """Executable cache key over a plan fingerprint + example inputs.

        Accepts arrays (anything with ``.shape``/``.dtype``) keyed
        structurally and plain Python scalars keyed by type + value
        (they are static under jit, so the value belongs in the key).
        Anything else raises — a silently id-keyed object would make
        every lookup a miss and every stale hit a wrong executable.
        """
        items = []
        for k, v in sorted(inputs.items()):
            if hasattr(v, "shape") and hasattr(v, "dtype"):
                items.append((k, tuple(v.shape), str(v.dtype)))
            elif isinstance(v, (bool, int, float, str, bytes, type(None))):
                items.append((k, "py", type(v).__name__, v))
            else:
                raise TypeError(
                    f"PlanStore.key_for: input {k!r} is neither an array "
                    f"nor a static Python scalar (got {type(v).__name__}); "
                    "it cannot form a stable executable key")
        return (plan_fp, tuple(items))

    def get_or_build(self, key, build: Callable[[], Callable],
                     example_args: Optional[tuple] = None):
        hit = self._execs.get(key)
        if hit is not None:
            self.stats["exec_hits"] += 1
            self._execs.move_to_end(key)
            return hit[0]
        self.stats["exec_misses"] += 1
        t0 = time.perf_counter()
        fn = build()
        self.stats["trace_s"] += time.perf_counter() - t0
        nbytes = 0
        if example_args is not None:
            t0 = time.perf_counter()
            fn = jax.jit(fn).lower(*example_args).compile()
            self.stats["compile_s"] += time.perf_counter() - t0
            nbytes = _exec_nbytes(fn)
        nbytes = nbytes or _EXEC_DEFAULT_NBYTES
        self._execs[key] = (fn, nbytes)
        self.stats["exec_bytes"] += nbytes
        while len(self._execs) > self.exec_capacity or (
                self.exec_budget_bytes is not None
                and self.stats["exec_bytes"] > self.exec_budget_bytes
                and len(self._execs) > 1):
            _, (_, nb) = self._execs.popitem(last=False)
            self.stats["exec_bytes"] -= nb
            self.stats["exec_evictions"] += 1
        return fn

    @property
    def exec_hit_rate(self) -> float:
        """Fraction of executable lookups served from cache (the plan
        level's ``share_rate`` analogue)."""
        total = self.stats["exec_hits"] + self.stats["exec_misses"]
        return self.stats["exec_hits"] / total if total else 0.0

    # -- introspection -----------------------------------------------------
    @property
    def n_plans(self) -> int:
        return len(self._plans)

    @property
    def n_execs(self) -> int:
        return len(self._execs)

    @property
    def n_restorable(self) -> int:
        """Restored entries staged but not yet redeemed."""
        return len(self._restored_raw) + sum(
            len(p["buckets"]) for p in self._restored_parsed.values())

    def __len__(self):
        return len(self._plans) + len(self._execs)

    def snapshot(self) -> dict:
        out = dict(self.stats)
        out["n_plans"] = self.n_plans
        out["n_execs"] = self.n_execs
        out["n_restorable"] = self.n_restorable
        out["share_rate"] = round(self.share_rate, 4)
        out["exec_hit_rate"] = round(self.exec_hit_rate, 4)
        return out


def resolve_plan_store(plan_store, plan_store_path) -> Optional[PlanStore]:
    """Bind a ``PlanStore`` to an on-disk artifact.

    No path: the given store (possibly ``None``) unchanged.  Path only:
    open/warm-start a store from it.  Both: bind the path to the given
    store so ``checkpoint_plan_store`` writes back.  Shared by the
    serve/train/launch step builders so trainer relaunches and
    multi-bucket server start-up skip re-lowering.
    """
    if not plan_store_path:
        return plan_store
    if plan_store is None:
        return PlanStore.open(plan_store_path)
    plan_store.path = plan_store_path
    return plan_store


def checkpoint_plan_store(plan_store) -> int:
    """Persist a path-bound store (no-op otherwise); builders call this
    right after lowering so the artifact exists even if the process
    dies before serving a single step."""
    if plan_store is not None and plan_store.path:
        return plan_store.save()
    return 0


def _exec_nbytes(compiled) -> int:
    """Footprint estimate of a compiled executable via XLA's memory
    analysis; 0 when the backend exposes none (caller applies a floor)."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return 0
    total = 0
    for field in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "temp_size_in_bytes"):
        try:
            total += int(getattr(ma, field, 0) or 0)
        except (TypeError, ValueError):
            pass
    return total


GLOBAL_STORE = PlanStore()


# -- deprecated aliases (pre-PR-2 split caches) ------------------------------
# The old ``core/compile_cache.py`` module body is retired; these shims
# live beside the store they restrict and warn once per process.


class LoweredPlanCache(PlanStore):
    """Deprecated alias: the plan level of a ``PlanStore`` with the
    legacy ``capacity`` constructor argument and ``len()`` scope."""

    def __init__(self, capacity: int = 256):
        warn_once("repro.core.LoweredPlanCache", "PlanStore")
        super().__init__(plan_capacity=capacity)
        self.capacity = capacity

    def __len__(self):
        return self.n_plans


class CompileCache(PlanStore):
    """Deprecated alias: the executable level of a ``PlanStore``; mirrors
    the store's ``exec_*`` counters back onto the legacy
    ``hits``/``misses``/``evictions`` stats keys."""

    def __init__(self, capacity: int = 128):
        warn_once("repro.core.CompileCache", "PlanStore")
        super().__init__(exec_capacity=capacity)
        self.capacity = capacity

    def get_or_build(self, key, build, example_args=None):
        out = super().get_or_build(key, build, example_args)
        s = self.stats
        s["hits"] = s["exec_hits"]
        s["misses"] = s["exec_misses"]
        s["evictions"] = s["exec_evictions"]
        return out

    def __len__(self):
        return self.n_execs


GLOBAL_CACHE = GLOBAL_STORE
GLOBAL_PLAN_CACHE = GLOBAL_STORE
