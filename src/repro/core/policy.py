"""First-class strategy policies — programmable per-context selection.

The paper's headline capability is that the *choice* of intra-device
parallelism strategy is itself programmable and context-dependent (§3,
Fig. 6-8): the same logical model runs DBO on a large MoE prefill bucket,
reorder-only SBO on a small one, and plain sequential decode.  Before
PR 5 that choice was a hardcoded built-in (``DynamicScheduler.pick``);
this module promotes it to an API.

A **policy** maps a :class:`ScheduleContext` to a scheduler::

    policy(ctx: ScheduleContext) -> OpSchedulerBase

and carries a stable ``identity()`` that enters the PlanStore outer key
(via ``core.plan.strategy_salt``), so two policies never alias cached or
persisted plans.  Combinators compose policies from schedulers:

    by_phase(prefill=NanoFlow(), decode=Sequential())
    by_token_threshold([(64, Sequential()), (2048, SingleBatchOverlap())],
                       above=NanoFlow())
    first_viable(when(has_ops(r"moe_a2a"), DualBatchOverlap()),
                 default=NanoFlow())

Graph-conditional predicates (``has_ops``) read the segment's traced
graph from ``ctx.extra['graph']`` — ``build_forward`` injects it before
resolving, and ``DynamicScheduler`` injects the partitioned graph when
it defers at schedule time.  Everywhere else the key is simply absent
and graph predicates answer False.

Identity caveat: predicates should be module-level functions or frozen
dataclasses (like ``has_ops``).  A lambda still *works* but its identity
degrades to ``id()`` — such a policy never aliases another, at the cost
of never sharing persisted plans across processes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .plan import fused_fn_identity, scheduler_identity
from .scheduler import OpSchedulerBase, ScheduleContext


def tokens_of(info: ScheduleContext) -> int:
    """Token count of the step — the paper's batch-size split condition."""
    if info.phase == "decode":
        return info.local_batch
    return info.local_batch * max(info.seq_len, 1)


def with_graph(info: ScheduleContext, graph) -> ScheduleContext:
    """Copy of ``info`` whose ``extra['graph']`` carries the segment
    graph, so graph-conditional predicates can see op names."""
    extra = dict(info.extra or {})
    extra["graph"] = graph
    return dataclasses.replace(info, extra=extra)


def _graph_of(ctx: ScheduleContext):
    return (ctx.extra or {}).get("graph")


class StrategyPolicy:
    """Protocol base: resolve a :class:`ScheduleContext` to a scheduler.

    Subclasses implement ``__call__`` (returning an ``OpSchedulerBase``,
    or ``None`` to *decline* — meaningful only inside ``first_viable``)
    and ``identity()`` (a stable hashable tuple; it becomes part of the
    PlanStore outer key, so it must be reproducible across processes).
    ``partition_rules`` is the union over every reachable scheduler —
    partitioning must not depend on which branch a context selects,
    or two contexts of one program would see different graphs.
    """

    name = "policy"

    def __call__(self, ctx: ScheduleContext) -> Optional[OpSchedulerBase]:
        raise NotImplementedError

    def identity(self) -> tuple:
        raise NotImplementedError

    def partition_rules(self) -> list:
        return _union_rules(self.children())

    def children(self) -> list:
        """Sub-policies this combinator can delegate to."""
        return []


def as_policy(obj) -> StrategyPolicy:
    """Normalize a scheduler, policy, or registry name into a policy.

    Names resolve through the strategy registry
    (``core.strategies.registry``): scheduler entries become a
    ``FixedPolicy``; policy entries (``"dynamic"``, ``"auto"``) resolve
    to the policy itself, so ``policy="auto"`` reaches ``api.compile``
    as a live :class:`~repro.core.autotune.AutoPolicy`.  Unknown names
    raise ``UnknownStrategyError`` listing the registered choices."""
    if isinstance(obj, StrategyPolicy):
        return obj
    if isinstance(obj, OpSchedulerBase):
        return FixedPolicy(obj)
    if isinstance(obj, str):
        from .strategies.registry import get_entry
        entry = get_entry(obj)
        if entry.policy_factory is not None:
            return as_policy(entry.policy_factory())
        return FixedPolicy(entry.factory())
    raise TypeError(
        f"expected an OpSchedulerBase, StrategyPolicy or strategy name, "
        f"got {type(obj).__name__}")


def resolve_strategy(policy_or_scheduler, info: ScheduleContext,
                     graph=None) -> OpSchedulerBase:
    """Resolve to a concrete scheduler for one context (and optionally
    one segment graph).  A top-level policy may not decline."""
    policy = as_policy(policy_or_scheduler)
    ctx = with_graph(info, graph) if graph is not None else info
    sched = policy(ctx)
    if sched is None:
        raise ValueError(
            f"policy {policy.name!r} declined to schedule context "
            f"{info.phase}/{tokens_of(info)} tokens; give first_viable a "
            "default= scheduler")
    return sched


def _union_rules(policies) -> list:
    rules, seen = [], set()
    for p in policies:
        for r in p.partition_rules():
            key = repr(r)
            if key not in seen:
                seen.add(key)
                rules.append(r)
    return rules


def _identity_of(policy: StrategyPolicy) -> tuple:
    return policy.identity()


class FixedPolicy(StrategyPolicy):
    """Always the one scheduler — how bare schedulers enter policy-land."""

    def __init__(self, scheduler: OpSchedulerBase):
        self.scheduler = scheduler
        self.name = getattr(scheduler, "name", type(scheduler).__name__)

    def __call__(self, ctx):
        return self.scheduler

    def identity(self):
        return ("fixed", scheduler_identity(self.scheduler))

    def partition_rules(self):
        return list(self.scheduler.partition_rules())


class PolicyScheduler(OpSchedulerBase):
    """Scheduler adapter over a policy — how policies enter scheduler-land
    (the inverse of :class:`FixedPolicy`).

    Branch selection is deferred to plan-record time, when the
    partitioned segment graph is in hand (``pick`` re-injects it under
    ``extra['graph']`` so graph-conditional predicates see op names).
    Every pre-facade entry point that passes schedulers around composes
    with policies through this adapter.
    """

    name = "policy"

    def __init__(self, policy: StrategyPolicy, name: Optional[str] = None):
        self.policy = policy
        self.name = name or getattr(policy, "name", "policy")

    def identity(self):
        return (self.name, self.policy.identity())

    def partition_rules(self):
        return self.policy.partition_rules()

    def pick(self, ctx) -> OpSchedulerBase:
        """Resolve the sub-strategy for a ``SchedCtx`` (record time)."""
        return self.policy(with_graph(ctx.info, ctx.graph))

    def schedule(self, ctx):
        self.pick(ctx).schedule(ctx)


class _PhasePolicy(StrategyPolicy):
    name = "by_phase"

    def __init__(self, phases: dict, default):
        self.phases = {ph: as_policy(p) for ph, p in phases.items()}
        self.default = as_policy(default) if default is not None else None

    def __call__(self, ctx):
        child = self.phases.get(ctx.phase, self.default)
        if child is None:
            raise KeyError(
                f"by_phase has no branch for phase {ctx.phase!r} and no "
                f"default (have {sorted(self.phases)})")
        return child(ctx)

    def identity(self):
        return ("by_phase",
                tuple(sorted((ph, _identity_of(p))
                             for ph, p in self.phases.items())),
                _identity_of(self.default) if self.default else None)

    def children(self):
        return list(self.phases.values()) + (
            [self.default] if self.default else [])


def by_phase(default=None, **phases) -> StrategyPolicy:
    """Route by ``ctx.phase`` (train / prefill / decode)::

        by_phase(prefill=NanoFlow(), decode=Sequential(),
                 default=Sequential())
    """
    return _PhasePolicy(phases, default)


class _TokenThresholdPolicy(StrategyPolicy):
    name = "by_tokens"

    def __init__(self, thresholds, above):
        ts = [(int(t), as_policy(p)) for t, p in thresholds]
        if ts != sorted(ts, key=lambda x: x[0]):
            raise ValueError(f"thresholds must ascend: {[t for t, _ in ts]}")
        self.thresholds = ts
        self.above = as_policy(above)

    def __call__(self, ctx):
        t = tokens_of(ctx)
        for limit, child in self.thresholds:
            if t < limit:
                return child(ctx)
        return self.above(ctx)

    def identity(self):
        return ("by_tokens",
                tuple((limit, _identity_of(p))
                      for limit, p in self.thresholds),
                _identity_of(self.above))

    def children(self):
        return [p for _, p in self.thresholds] + [self.above]


def by_token_threshold(thresholds, above) -> StrategyPolicy:
    """Route by the step's token count (``tokens_of``): the first
    ``(limit, policy)`` pair with ``tokens < limit`` wins, else
    ``above``.  The paper's Fig. 2a condition — splitting small batches
    inflates memory traffic — as a combinator."""
    return _TokenThresholdPolicy(thresholds, above)


class _WhenPolicy(StrategyPolicy):
    name = "when"

    def __init__(self, predicate, policy):
        self.predicate = predicate
        self.policy = as_policy(policy)

    def __call__(self, ctx):
        if not self.predicate(ctx):
            return None
        return self.policy(ctx)

    def identity(self):
        return ("when", _predicate_identity(self.predicate),
                _identity_of(self.policy))

    def children(self):
        return [self.policy]


def when(predicate, policy) -> StrategyPolicy:
    """Guard a policy behind ``predicate(ctx) -> bool``; declines (returns
    ``None``) when the predicate is false — compose under
    ``first_viable``."""
    return _WhenPolicy(predicate, policy)


class _FirstViablePolicy(StrategyPolicy):
    name = "first_viable"

    def __init__(self, children, default):
        self._children = [as_policy(c) for c in children if c is not None]
        self.default = as_policy(default) if default is not None else None

    def __call__(self, ctx):
        for child in self._children:
            sched = child(ctx)
            if sched is not None:
                return sched
        return self.default(ctx) if self.default is not None else None

    def identity(self):
        return ("first_viable",
                tuple(_identity_of(c) for c in self._children),
                _identity_of(self.default) if self.default else None)

    def children(self):
        return self._children + ([self.default] if self.default else [])


def first_viable(*children, default=None) -> StrategyPolicy:
    """Try each child in order; the first that does not decline wins.
    With no ``default`` the combinator itself declines when every child
    does (usable as a guarded branch of an outer ``first_viable``)."""
    return _FirstViablePolicy(children, default)


# -- predicates --------------------------------------------------------------


def _predicate_identity(fn) -> tuple:
    if dataclasses.is_dataclass(fn) and not isinstance(fn, type):
        return ("pred", type(fn).__module__, type(fn).__qualname__,
                dataclasses.astuple(fn))
    return fused_fn_identity(fn)


@dataclasses.dataclass(frozen=True)
class has_ops:
    """Predicate: the context's segment graph contains an op whose name
    matches ``pattern`` (regex search).  False when no graph rode along."""

    pattern: str

    def __call__(self, ctx: ScheduleContext) -> bool:
        g = _graph_of(ctx)
        if g is None:
            return False
        return any(re.search(self.pattern, n.name)
                   for n in g.nodes.values())


@dataclasses.dataclass(frozen=True)
class local_batch_below:
    """Predicate: ``ctx.local_batch < n`` (too small to split)."""

    n: int

    def __call__(self, ctx: ScheduleContext) -> bool:
        return ctx.local_batch < self.n


@dataclasses.dataclass(frozen=True)
class phase_is:
    """Predicate: ``ctx.phase`` equals the given phase."""

    phase: str

    def __call__(self, ctx: ScheduleContext) -> bool:
        return ctx.phase == self.phase
