"""Cost-model-driven autotuning — closing the programmable-scheduling loop.

The paper's thesis is that the *choice* of intra-device parallelism
strategy should be programmable per execution context (§3).  ``dynamic``
programs that choice by hand (threshold tables); :class:`AutoPolicy`
programs it with the repo's own roofline model: per
:class:`~repro.core.scheduler.ScheduleContext` it

  1. enumerates every candidate (strategy × parameterization) the
     strategy registry declares tunable (``registry.tunable_candidates``),
     plus — for small graphs — an enumerative :class:`ExhaustiveOrder`
     sweep over topological orders, the brute-force floor no hand-written
     strategy should lose to;
  2. records each candidate's plan on the *same partitioned graph*
     ``build_forward`` will execute (the union of every candidate's
     partition rules) and ranks them by modeled exposed time
     (:func:`~repro.roofline.overlap.plan_overlap`, charged with the
     Fig. 2a split-weight re-read penalty) with peak prealloc memory as
     the pareto second axis;
  3. optionally refines the model's top-K by measuring real step times
     through the existing lowering path (pass ``measurer=``, e.g.
     :func:`realizer_measurer`);
  4. records a :class:`TuningVerdict` — winner identity, full scoreboard,
     measurement provenance — keyed by a context fingerprint, and
     persists it into the PlanStore artifact (versioned ``V`` records,
     ``core/plan_serde.py``), so a restarted process inherits every
     decision with **zero** re-tunes.

``AutoPolicy`` is an ordinary :class:`~repro.core.policy.StrategyPolicy`:
``api.compile(model, policy="auto")`` is the whole user surface, and its
``identity()`` salts the outer plan key exactly like any other policy —
two AutoPolicies with different candidate sets or cost-model calibration
never alias persisted plans.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Optional

from .. import hw
from ..roofline.overlap import plan_overlap, split_weight_penalty
from .analysis import static_analysis
from .graph import FULL, OpGraph
from .partition import partition
from .plan import ExecutionPlan, OpHandle, PlanStep, graph_fingerprint
from .policy import StrategyPolicy
from .scheduler import OpSchedulerBase, ScheduleContext, record_plan
from .strategies import registry

# Version of the verdict semantics (candidate scoring + fingerprint
# recipe).  Enters every verdict payload and the AutoPolicy identity:
# bumping it orphans persisted verdicts (cold re-tune) instead of
# replaying decisions made under different rules.
AUTOTUNE_VERSION = 1


def context_fingerprint(info: ScheduleContext, graph: OpGraph) -> str:
    """Stable key of one tuning decision: the schedule-relevant context
    fields plus the (unpartitioned) graph structure.  Anything that can
    change which candidate wins must enter here."""
    payload = (info.arch, info.phase, int(info.local_batch),
               int(info.seq_len),
               tuple(sorted((str(k), int(v))
                            for k, v in (info.mesh_shape or {}).items())),
               graph_fingerprint(graph))
    return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class TuningVerdict:
    """One persisted tuning decision (who won, by how much, and how we
    know) — the unit ``PlanStore.put_verdict`` serializes."""

    context_fp: str
    winner: str                     # registry name, or "exhaustive"
    params: tuple                   # ((kwarg, value), ...) for the winner
    identity: str                   # repr of the winner's scheduler_identity
    t_model: float                  # modeled step seconds of the winner
    t_sequential: float             # modeled sequential-baseline seconds
    peak_bytes: int                 # winner's prealloc buffer footprint
    provenance: str                 # "model" | "measured"
    scores: tuple                   # ((label, t_model, peak_bytes), ...)
    measured_s: float = 0.0         # live/measured seconds (0 = none yet)
    # candidates excluded from the scoreboard and why:
    # ((label, code, message), ...) — code is a verify diagnostic code
    # ("VFY003", ...) or an exception class name for record-time crashes
    pruned: tuple = ()
    version: int = AUTOTUNE_VERSION
    arch: str = ""
    phase: str = ""
    local_batch: int = 0
    seq_len: int = 0

    def to_payload(self) -> dict:
        d = dataclasses.asdict(self)
        d["params"] = [[k, v] for k, v in self.params]
        d["scores"] = [[label, t, mem] for label, t, mem in self.scores]
        d["pruned"] = [[label, code, msg]
                       for label, code, msg in self.pruned]
        return d

    @classmethod
    def from_payload(cls, payload: dict) -> "TuningVerdict":
        d = dict(payload)
        if d.get("version") != AUTOTUNE_VERSION:
            raise ValueError(
                f"verdict version {d.get('version')!r} != {AUTOTUNE_VERSION}")
        missing = {f.name for f in dataclasses.fields(cls)
                   if f.default is dataclasses.MISSING} - set(d)
        if missing:
            raise ValueError(f"verdict payload missing {sorted(missing)}")
        d["params"] = tuple((str(k), v) for k, v in d["params"])
        d["scores"] = tuple((str(label), float(t), int(mem))
                            for label, t, mem in d["scores"])
        d["pruned"] = tuple((str(label), str(code), str(msg))
                            for label, code, msg in d.get("pruned") or ())
        return cls(**{k: v for k, v in d.items()
                      if k in {f.name for f in dataclasses.fields(cls)}})


def pareto_front(points):
    """Indices of the (t, mem)-pareto-optimal entries of
    ``[(label, t, mem), ...]`` — no other entry is <= on both axes and <
    on one."""
    keep = []
    for i, (_, t_i, m_i) in enumerate(points):
        dominated = any(
            (t_j <= t_i and m_j <= m_i) and (t_j < t_i or m_j < m_i)
            for j, (_, t_j, m_j) in enumerate(points) if j != i)
        if not dominated:
            keep.append(i)
    return keep


# -- enumerative fallback -----------------------------------------------------


def _topo_orders(graph: OpGraph, max_orders: int) -> list:
    """All linear extensions of the graph's dependency order, bounded by
    ``max_orders`` (deterministic: branches explored in oid order)."""
    deps = {oid: graph.node_deps(oid) for oid in graph.topo_order()}
    orders: list = []
    order: list = []
    done: set = set()

    def rec():
        if len(orders) >= max_orders:
            return
        if len(order) == len(deps):
            orders.append(tuple(order))
            return
        for oid in deps:
            if oid in done or not deps[oid] <= done:
                continue
            done.add(oid)
            order.append(oid)
            rec()
            done.discard(oid)
            order.pop()

    rec()
    return orders


def _order_plan(graph: OpGraph, order) -> ExecutionPlan:
    steps = [PlanStep("exec", (OpHandle(oid, FULL, graph.nodes[oid].name),))
             for oid in order]
    return ExecutionPlan(steps, (), graph_fingerprint(graph))


class ExhaustiveOrder(OpSchedulerBase):
    """Enumerate every topological order of a (small) graph, score each
    with the overlap model, and replay the best — the paper's "search
    the schedule space" floor for graphs where enumeration is feasible.

    Gated by ``max_ops`` (beyond it: sequential fallback, enumeration is
    factorial) and ``max_orders`` (search budget).  Deterministic: ties
    keep the first order in oid-lexicographic enumeration."""

    name = "exhaustive"

    def __init__(self, max_ops: int = 9, max_orders: int = 256,
                 tp: int = 16, bw_scale: float = 1.0,
                 coll_latency_s: float = hw.COLL_LATENCY_S):
        self.max_ops = max_ops
        self.max_orders = max_orders
        self.tp = tp
        self.bw_scale = bw_scale
        self.coll_latency_s = coll_latency_s

    def identity(self):
        return ("exhaustive", self.max_ops, self.max_orders, self.tp,
                self.bw_scale, self.coll_latency_s)

    def best_order(self, graph: OpGraph):
        """(order, t_overlapped) of the best enumerated order, or None
        when the graph exceeds ``max_ops``."""
        if len(graph.nodes) > self.max_ops:
            return None
        best = None
        for order in _topo_orders(graph, self.max_orders):
            t = plan_overlap(graph, _order_plan(graph, order), tp=self.tp,
                             bw_scale=self.bw_scale,
                             coll_latency_s=self.coll_latency_s).t_overlapped
            if best is None or t < best[1]:
                best = (order, t)
        return best

    def schedule(self, ctx):
        best = self.best_order(ctx.graph)
        if best is None:
            ctx.run_rest_sequential()
            return
        for oid in best[0]:
            ctx.execute(OpHandle(oid, FULL, ctx.graph.nodes[oid].name))


# -- measured refinement ------------------------------------------------------


def realizer_measurer(params, inputs, repeats: int = 2) -> Callable:
    """Build a ``measurer(info, graph, plan) -> seconds | None`` that
    times real executions through the existing lowering path
    (:class:`~repro.core.backend.Realizer`): one warm-up call (compile),
    then best-of-``repeats`` wall clock.  Returns ``None`` (candidate
    keeps its modeled score) when a candidate fails to lower or run."""
    import time

    import jax

    def measure(info, graph, plan):
        try:
            from .backend import Realizer
            run = Realizer(graph, plan)
            jax.block_until_ready(run(params, inputs))      # compile
            best = None
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(run(params, inputs))
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            return best
        except Exception:
            return None

    return measure


# -- the policy ---------------------------------------------------------------


class AutoPolicy(StrategyPolicy):
    """Rank every registered candidate with the roofline overlap model
    and schedule each context with the winner; see the module docstring
    for the full loop.  Construct via ``api.compile(policy="auto")`` /
    ``AutoPolicy(...)`` for custom calibration."""

    name = "auto"

    def __init__(self, tp: int = 16, bw_scale: float = 1.0,
                 coll_latency_s: float = hw.COLL_LATENCY_S,
                 exhaustive_max_ops: int = 9,
                 exhaustive_max_orders: int = 256,
                 measure_top_k: int = 0,
                 measurer: Optional[Callable] = None):
        self.tp = tp
        self.bw_scale = bw_scale
        self.coll_latency_s = coll_latency_s
        self.exhaustive_max_ops = exhaustive_max_ops
        self.exhaustive_max_orders = exhaustive_max_orders
        self.measure_top_k = measure_top_k
        self.measurer = measurer
        self.retunes = 0                 # cold tunes this process
        self._store = None               # bound PlanStore (verdict home)
        self._verdicts: dict = {}        # context_fp -> TuningVerdict
        self._schedulers: dict = {}      # context_fp -> scheduler
        self._ctx_groups: dict = {}      # (arch, phase, b, s) -> {fp}
        # speculative-decode draft-k feedback (ServeEngine spec loop):
        # (arch, k) -> {"rate", "seconds", "steps"} EWMAs
        self._spec_obs: dict = {}
        self._spec_loaded: set = set()   # arches with persisted obs read

    # identity() deliberately excludes the measurement knobs: a measured
    # and a model-only AutoPolicy share the verdict namespace (measured
    # verdicts are refinements, not different policies), and a different
    # *winner* already separates outer plan keys via the structural key.
    def identity(self):
        cands = tuple((name, tuple(sorted(params.items())))
                      for name, params in registry.tunable_candidates())
        return ("auto", AUTOTUNE_VERSION, self.tp, self.bw_scale,
                self.coll_latency_s, self.exhaustive_max_ops,
                self.exhaustive_max_orders, cands)

    def partition_rules(self):
        # union over every candidate: partitioning must not depend on
        # which candidate a context selects (StrategyPolicy contract)
        rules, seen = [], set()
        for name, params in registry.tunable_candidates():
            try:
                sched = registry.make_scheduler(name, **params)
            except Exception:
                continue
            for r in sched.partition_rules():
                key = repr(r)
                if key not in seen:
                    seen.add(key)
                    rules.append(r)
        return rules

    # -- store plumbing ------------------------------------------------------
    def bind_store(self, store):
        """Attach the PlanStore that persists verdicts (``api.compile``
        and ``ServeEngine`` call this with the store they resolved)."""
        self._store = store

    # -- StrategyPolicy ------------------------------------------------------
    def __call__(self, ctx: ScheduleContext) -> OpSchedulerBase:
        graph = (ctx.extra or {}).get("graph")
        if graph is None:
            # no graph rode along (bare resolve_strategy without graph=):
            # nothing to rank — defer to the hand-written selection
            from .strategies.dynamic import dynamic_policy
            return dynamic_policy()(ctx)
        fp = context_fingerprint(ctx, graph)
        v = self._verdicts.get(fp)
        if v is None and self._store is not None:
            payload = self._store.get_verdict(fp)
            if payload is not None:
                try:
                    v = TuningVerdict.from_payload(payload)
                except (ValueError, KeyError, TypeError):
                    v = None            # corrupt/foreign verdict: re-tune
                else:
                    self._verdicts[fp] = v
        if v is None:
            v = self._tune(ctx, graph, fp)
        self._ctx_groups.setdefault(
            (v.arch, v.phase, v.local_batch, v.seq_len), set()).add(fp)
        return self._scheduler_of(fp, v)

    # -- tuning --------------------------------------------------------------
    def _tuning_graph(self, graph: OpGraph) -> OpGraph:
        if any(n.members for n in graph.nodes.values()):
            return graph                # already partitioned (pick path)
        return partition(graph, self.partition_rules(), default_depth=2)

    def _score(self, g: OpGraph, plan: ExecutionPlan, tp: int):
        rep = plan_overlap(
            g, plan, tp=tp,
            extra_weight_read_bytes=split_weight_penalty(g, plan.num_mb),
            bw_scale=self.bw_scale, coll_latency_s=self.coll_latency_s)
        return rep, static_analysis(g, plan).buffer_bytes

    def _tune(self, info: ScheduleContext, graph: OpGraph,
              fp: str) -> TuningVerdict:
        self.retunes += 1
        g = self._tuning_graph(graph)
        tp = int((info.mesh_shape or {}).get("tp") or self.tp)
        scored = []     # (label, name, params, plan, t, mem, t_seq)
        pruned = []     # (label, code, message) — the verdict scoreboard
        for name, params in registry.tunable_candidates():
            label = name if not params else \
                name + "(" + ",".join(f"{k}={v}"
                                      for k, v in sorted(params.items())) \
                + ")"
            cand = self._try_candidate(label, g, info, tp, pruned,
                                       lambda: registry.make_scheduler(
                                           name, **params))
            if cand is not None:
                plan, rep, mem = cand
                scored.append((label, name, tuple(sorted(params.items())),
                               plan, rep.t_overlapped, mem,
                               rep.t_sequential))
        if len(g.nodes) <= self.exhaustive_max_ops:
            cand = self._try_candidate(
                "exhaustive", g, info, tp, pruned,
                lambda: ExhaustiveOrder(self.exhaustive_max_ops,
                                        self.exhaustive_max_orders, tp,
                                        self.bw_scale,
                                        self.coll_latency_s))
            if cand is not None:
                plan, rep, mem = cand
                scored.append(("exhaustive", "exhaustive", (), plan,
                               rep.t_overlapped, mem, rep.t_sequential))
        if not scored:
            why = "; ".join(f"{lab}: [{code}] {msg}"
                            for lab, code, msg in pruned[:4])
            raise RuntimeError(
                f"autotuner found no viable candidate for context "
                f"{info.arch}/{info.phase} (graph of {len(g.nodes)} units)"
                + (f"; pruned: {why}" if why else ""))

        provenance = "model"
        measured_s = 0.0
        if self.measure_top_k > 0 and self.measurer is not None:
            scored.sort(key=lambda c: (c[4], c[5],
                                   c[1] != "sequential", c[0]))
            top = scored[:self.measure_top_k]
            times = [self.measurer(info, g, c[3]) for c in top]
            if any(t is not None for t in times):
                provenance = "measured"
                # measured seconds override the model for the refined set
                scored = [
                    (lab, nm, pr, pl, (t if t is not None else tm), mem,
                     ts)
                    for (lab, nm, pr, pl, tm, mem, ts), t
                    in zip(top, times)
                ] + scored[self.measure_top_k:]

        scored.sort(key=lambda c: (c[4], c[5],
                                   c[1] != "sequential", c[0]))
        points = [(lab, t, mem) for lab, _, _, _, t, mem, _ in scored]
        front = set(pareto_front(points))
        win = scored[0]
        if provenance == "measured":
            measured_s = win[4]
        seq = next((c for c in scored if c[1] == "sequential"), None)
        t_sequential = seq[4] if seq is not None else win[6]
        sched = self._instantiate(win[1], dict(win[2]), tp)
        from .plan import scheduler_identity
        v = TuningVerdict(
            context_fp=fp, winner=win[1], params=win[2],
            identity=repr(scheduler_identity(sched)),
            t_model=win[4], t_sequential=t_sequential, peak_bytes=win[5],
            provenance=provenance,
            scores=tuple(points[i] for i in range(len(points))
                         if i in front or i < 4),
            measured_s=measured_s,
            pruned=tuple(pruned),
            arch=info.arch, phase=info.phase,
            local_batch=int(info.local_batch), seq_len=int(info.seq_len))
        self._verdicts[fp] = v
        self._schedulers[fp] = sched
        if self._store is not None:
            self._store.put_verdict(fp, v.to_payload())
        return v

    def _try_candidate(self, label: str, g: OpGraph,
                       info: ScheduleContext, tp: int, pruned: list,
                       make: Callable):
        """Record, verify and score one candidate.  A candidate that
        crashes during recording or whose plan fails static verification
        is *pruned* — excluded with a typed (label, code, message) row on
        the verdict scoreboard — never silently swallowed and never
        allowed to abort the sweep."""
        from .verify import verify as verify_plan_fn
        try:
            sched = make()
            plan = record_plan(g, sched, info)
        except Exception as e:                          # noqa: BLE001
            pruned.append((label, type(e).__name__, str(e)[:200]))
            return None
        report = verify_plan_fn(g, plan)
        if not report.ok:
            d = report.errors[0]
            pruned.append((label, d.code, str(d)[:200]))
            return None
        try:
            rep, mem = self._score(g, plan, tp)
        except Exception as e:                          # noqa: BLE001
            pruned.append((label, type(e).__name__,
                           f"cost model failed: {str(e)[:160]}"))
            return None
        return plan, rep, mem

    def _instantiate(self, winner: str, params: dict, tp: int):
        if winner == "exhaustive":
            return ExhaustiveOrder(self.exhaustive_max_ops,
                                   self.exhaustive_max_orders, tp,
                                   self.bw_scale, self.coll_latency_s)
        return registry.make_scheduler(winner, **params)

    def _scheduler_of(self, fp: str, v: TuningVerdict):
        sched = self._schedulers.get(fp)
        if sched is None:
            tp = self.tp
            sched = self._instantiate(v.winner, dict(v.params), tp)
            self._schedulers[fp] = sched
        return sched

    # -- introspection / live feedback --------------------------------------
    def lookup(self, info: ScheduleContext,
               graph: OpGraph) -> Optional[TuningVerdict]:
        """The verdict this policy holds for (context, graph), if any —
        memory first, then the bound store (no tuning)."""
        fp = context_fingerprint(info, graph)
        v = self._verdicts.get(fp)
        if v is None and self._store is not None:
            payload = self._store.get_verdict(fp)
            if payload is not None:
                try:
                    v = TuningVerdict.from_payload(payload)
                except (ValueError, KeyError, TypeError):
                    return None
        return v

    def observe(self, *, phase: str, arch: str, local_batch: int,
                seq_len: int, seconds: float, stats: Optional[dict] = None):
        """Live feedback from the serving loop: fold a measured step time
        (EWMA) into every verdict recorded for this context group and
        persist meaningful changes, so ``explain()`` and future processes
        see model-vs-reality drift.

        Speculative-decode feedback (``stats`` carrying ``draft_k``)
        routes to the per-(arch, k) acceptance/latency EWMAs behind
        :meth:`spec_draft_k` instead."""
        if stats and "draft_k" in stats:
            self._observe_spec(arch, int(stats["draft_k"]), seconds, stats)
            return
        del stats   # reserved: admission/store counters for future re-tune
        key = (arch, phase, int(local_batch), int(seq_len))
        for fp in self._ctx_groups.get(key, ()):
            v = self._verdicts.get(fp)
            if v is None:
                continue
            ewma = seconds if v.measured_s <= 0.0 else \
                0.8 * v.measured_s + 0.2 * seconds
            changed = v.measured_s <= 0.0 or \
                abs(ewma - v.measured_s) > 0.2 * v.measured_s
            v = dataclasses.replace(v, measured_s=ewma)
            self._verdicts[fp] = v
            if changed and self._store is not None:
                self._store.put_verdict(fp, v.to_payload())

    # -- speculative draft-k tuning ------------------------------------------
    def _spec_fp(self, arch: str) -> str:
        """Synthetic verdict key for the per-arch draft-k scoreboard —
        same PlanStore verdict namespace, disjoint by construction from
        any schedule-context fingerprint."""
        payload = ("spec_decode", AUTOTUNE_VERSION, arch)
        return hashlib.sha256(repr(payload).encode()).hexdigest()[:16]

    def _spec_load(self, arch: str):
        if arch in self._spec_loaded:
            return
        self._spec_loaded.add(arch)
        if self._store is None:
            return
        payload = self._store.get_verdict(self._spec_fp(arch))
        if not payload or payload.get("version") != AUTOTUNE_VERSION:
            return
        for ks, rec in (payload.get("obs") or {}).items():
            try:
                self._spec_obs.setdefault((arch, int(ks)), {
                    "rate": float(rec["rate"]),
                    "seconds": float(rec["seconds"]),
                    "steps": int(rec["steps"])})
            except (KeyError, TypeError, ValueError):
                continue            # corrupt/foreign entry: re-learn

    def _observe_spec(self, arch: str, k: int, seconds: float,
                      stats: dict):
        self._spec_load(arch)
        rec = self._spec_obs.setdefault(
            (arch, k), {"rate": 0.0, "seconds": 0.0, "steps": 0})
        rate = float(stats.get("acceptance_rate") or 0.0)
        if rec["steps"] == 0:
            rec["rate"], rec["seconds"] = rate, float(seconds)
        else:
            rec["rate"] = 0.8 * rec["rate"] + 0.2 * rate
            rec["seconds"] = 0.8 * rec["seconds"] + 0.2 * float(seconds)
        rec["steps"] += 1
        # persist on first sight and then sparsely — the serve loop
        # calls this once per verify step
        if self._store is not None and rec["steps"] % 8 == 1:
            obs = {str(kk): dict(v)
                   for (a, kk), v in self._spec_obs.items() if a == arch}
            self._store.put_verdict(self._spec_fp(arch), {
                "kind": "spec_decode", "version": AUTOTUNE_VERSION,
                "arch": arch, "obs": obs})

    def spec_draft_k(self, *, arch: str, candidates) -> int:
        """Pick the draft length for ``SpecConfig(k="auto")``: explore
        each candidate once, then maximize expected accepted-tokens/s —
        ``(1 + k * acceptance_rate(k)) / seconds(k)`` from the live
        EWMAs (seeded from the persisted scoreboard on restart)."""
        self._spec_load(arch)
        for k in candidates:
            if (arch, int(k)) not in self._spec_obs:
                return int(k)

        def score(k):
            rec = self._spec_obs[(arch, int(k))]
            return (1.0 + int(k) * rec["rate"]) \
                / max(rec["seconds"], 1e-9)

        return int(max(candidates, key=score))

    def explain(self) -> list:
        """Decision table: one row per verdict this policy holds, sorted
        by (arch, phase, tokens) — the payload behind
        ``Program.explain()``."""
        rows = []
        for fp, v in self._verdicts.items():
            rows.append({
                "context": f"{v.arch}/{v.phase} b={v.local_batch} "
                           f"s={v.seq_len}",
                "arch": v.arch, "phase": v.phase,
                "local_batch": v.local_batch, "seq_len": v.seq_len,
                "winner": v.winner, "params": dict(v.params),
                "t_model_us": round(v.t_model * 1e6, 2),
                "t_sequential_us": round(v.t_sequential * 1e6, 2),
                "speedup": round(v.t_sequential / max(v.t_model, 1e-12), 3),
                "peak_bytes": v.peak_bytes,
                "provenance": v.provenance,
                "measured_us": round(v.measured_s * 1e6, 2),
                "scores": list(v.scores),
                "pruned": list(v.pruned),
                "context_fp": fp,
            })
        rows.sort(key=lambda r: (r["arch"], r["phase"], r["local_batch"],
                                 r["seq_len"]))
        return rows
