"""Graph partitioning — paper Fig. 5 (`SplitFunc`, `SplitModule`, `mark`).

The fine-grained traced graph is carved into *schedulable subgraphs*.
Annotations pin boundaries at logical-operator granularity; everything not
claimed by a rule coalesces into its containing unit (the paper's default:
contiguous code between boundaries becomes one subgraph).

Coalescing groups only *contiguous topological runs* sharing a unit key,
which guarantees the coarse graph stays acyclic.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

from .graph import OpGraph, OpNode


@dataclasses.dataclass(frozen=True)
class SplitFunc:
    """Split on ops whose scoped name matches ``pattern`` (regex search)."""

    pattern: str

    def unit_key(self, node: OpNode) -> Optional[str]:
        if re.search(self.pattern, node.name):
            return f"func:{node.name}"
        return None


@dataclasses.dataclass(frozen=True)
class SplitModule:
    """Split on every instance of a module class: the instance's whole
    subtree becomes one schedulable unit."""

    target_cls: type

    def unit_key(self, node: OpNode) -> Optional[str]:
        # scope entries are instance names; class info rides in node.tags
        # as "cls:<depth>:<ClassName>" entries recorded at trace time.
        classes = {self.target_cls, *self.target_cls.__subclasses__()}
        want = {f"cls:{i}:{c.__name__}"
                for i in range(len(node.scope)) for c in classes}
        for tag in node.tags:
            if tag in want:
                depth = int(tag.split(":")[1])
                return "mod:" + "/".join(node.scope[: depth + 1])
        return None


@dataclasses.dataclass(frozen=True)
class Mark:
    """Split on a ``with dynaflow.mark(tag):`` block."""

    tag: str

    def unit_key(self, node: OpNode) -> Optional[str]:
        want = "#" + self.tag
        for i, s in enumerate(node.scope):
            if s == want:
                return "mark:" + "/".join(node.scope[: i + 1])
        return None


@dataclasses.dataclass(frozen=True)
class SplitEveryOp:
    """Finest granularity: every traced leaf op is its own unit."""

    def unit_key(self, node: OpNode) -> Optional[str]:
        return f"op:{node.oid}"


def partition(graph: OpGraph, rules: Sequence, default_depth: int = 1) -> OpGraph:
    """Coarsen ``graph`` into schedulable units.

    Each node gets a unit key from the first matching rule, else a default
    key from its scope prefix (depth ``default_depth``).  Contiguous
    same-key topo runs merge into composite nodes.
    """
    order = graph.topo_order()
    keys = []
    for oid in order:
        node = graph.nodes[oid]
        key = None
        for rule in rules:
            key = rule.unit_key(node)
            if key is not None:
                break
        if key is None:
            key = "dflt:" + "/".join(node.scope[:default_depth])
        keys.append(key)

    # contiguous runs
    groups: list[list[int]] = []
    for oid, key in zip(order, keys):
        if groups and keys[order.index(groups[-1][-1])] == key:
            groups[-1].append(oid)
        else:
            groups.append([oid])

    coarse = OpGraph()
    # copy tensors wholesale (tids preserved) so refs stay valid
    coarse.tensors = dict(graph.tensors)
    coarse._next_tid = graph._next_tid
    coarse.inputs = dict(graph.inputs)
    coarse.outputs = dict(graph.outputs)
    for tid in coarse.tensors:
        coarse.consumers[tid] = []

    produced_by_group: dict[int, int] = {}
    out_tids = set(graph.outputs.values())
    for gi, group in enumerate(groups):
        members = [graph.nodes[o] for o in group]
        internal = {t for m in members for t in m.outputs}
        ext_in, seen_in = [], set()
        for m in members:
            for t in m.inputs:
                if t not in internal and t not in seen_in:
                    seen_in.add(t)
                    ext_in.append(t)
        ext_out = []
        consumed_outside = set()
        for m2 in graph.nodes.values():
            if m2.oid not in group:
                consumed_outside.update(m2.inputs)
        for m in members:
            for t in m.outputs:
                if t in consumed_outside or t in out_tids:
                    ext_out.append(t)
        if len(members) == 1:
            m = members[0]
            coarse.nodes[m.oid] = m
            coarse._next_oid = max(coarse._next_oid, m.oid + 1)
            for t in m.inputs:
                coarse.consumers[t].append(m.oid)
            for t in m.outputs:
                coarse.producer[t] = m.oid
            continue
        fn = _composite_fn(members, ext_in, ext_out)
        name = _common_prefix([m.name for m in members]) or members[0].name
        res = _dominant_resource(members)
        node = coarse.add_node(
            name + f"[{len(members)}ops]", fn,
            [coarse.tensors[t] for t in ext_in],
            [coarse.tensors[t] for t in ext_out],
            param_paths=tuple(p for m in members for p in m.param_paths),
            resource=res, scope=members[0].scope,
            flops=sum(m.flops for m in members),
            bytes_moved=sum(m.bytes_moved for m in members),
            param_bytes=sum(m.param_bytes for m in members),
            members=tuple(members))
        # add_node created with fresh oid; ensure ordering: oids must stay
        # topologically increasing — use max member oid as sort basis.
        produced_by_group[gi] = node.oid

    # Re-key composite nodes so topo order (sorted oids) matches group order.
    coarse_nodes = sorted(coarse.nodes.values(),
                          key=lambda n: min(n.outputs) if n.outputs else 0)
    renumbered = OpGraph()
    renumbered.tensors = dict(coarse.tensors)
    renumbered._next_tid = coarse._next_tid
    renumbered.inputs = dict(coarse.inputs)
    renumbered.outputs = dict(coarse.outputs)
    for tid in renumbered.tensors:
        renumbered.consumers[tid] = []
    for n in coarse_nodes:
        renumbered.add_node(
            n.name, n.fn, [renumbered.tensors[t] for t in n.inputs],
            [renumbered.tensors[t] for t in n.outputs],
            param_paths=n.param_paths, resource=n.resource, scope=n.scope,
            tags=n.tags, flops=n.flops, bytes_moved=n.bytes_moved,
            param_bytes=n.param_bytes, members=n.members)
    renumbered.validate()
    return renumbered


def _composite_fn(members: list[OpNode], ext_in: list[int], ext_out: list[int]):
    """Executable for a coalesced unit: run members in topo order."""

    def fn(params_by_path: dict, *inputs):
        env = dict(zip(ext_in, inputs))
        for m in sorted(members, key=lambda n: n.oid):
            p = params_by_path.get(m.param_paths[0]) if m.param_paths else {}
            outs = m.fn(p, *[env[t] for t in m.inputs])
            for t, v in zip(m.outputs, outs):
                env[t] = v
        return tuple(env[t] for t in ext_out)

    fn._composite = True
    return fn


def _dominant_resource(members) -> str:
    flops = sum(m.flops for m in members)
    if any(m.resource == "network" for m in members):
        # a unit containing a collective is network-dominated only if no
        # large compute accompanies it
        if flops < 1e6:
            return "network"
    by = {}
    for m in members:
        by[m.resource] = by.get(m.resource, 0.0) + max(m.flops, m.bytes_moved)
    return max(by, key=by.get) if by else "compute"


def _common_prefix(names: list[str]) -> str:
    if not names:
        return ""
    parts = [n.split("/") for n in names]
    out = []
    for chunk in zip(*parts):
        if all(c == chunk[0] for c in chunk):
            out.append(chunk[0])
        else:
            break
    return "/".join(out)
