"""Module system + symbolic tracer — DynaFlow's graph-capture frontend.

PyTorch DynaFlow captures the operator graph with TorchDynamo.  The JAX
analogue here is a symbolic trace over a ``Module`` tree: composite modules
keep the familiar sequential ``forward``; leaf ``Op`` modules are the
*logical operators* (attention, norm, matmul, collective) that become
schedulable ``OpNode``s.  Model code stays a plain sequential program —
the physical execution order is decided later by the scheduler, which is
the paper's core decoupling.

Two execution modes share the same model code:
  * trace mode  — ``trace(model, ...)`` records an ``OpGraph`` (shapes via
    ``jax.eval_shape``; nothing is allocated).
  * direct mode — ``model.apply(params, *xs)`` runs eagerly (reference
    semantics for tests).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .graph import OpGraph, TensorRef

# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Param:
    """Declared parameter of an Op: shape/dtype/init + sharding metadata.

    ``pspec`` names mesh axes per dimension (manual-SPMD: shapes declared
    here are the *per-shard local* shapes; the global view is assembled by
    the launch layer from ``global_shape``).
    """

    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    init: Optional[Callable] = None          # (key, shape, dtype) -> array
    pspec: tuple = ()                        # global PartitionSpec entries
    global_shape: Optional[tuple[int, ...]] = None

    def initializer(self):
        if self.init is not None:
            return self.init
        def _default(key, shape, dtype):
            fan_in = shape[0] if shape else 1
            scale = 1.0 / max(1.0, float(fan_in)) ** 0.5
            return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
        return _default


# ---------------------------------------------------------------------------
# trace context
# ---------------------------------------------------------------------------


class _TraceCtx:
    def __init__(self, graph: OpGraph):
        self.graph = graph
        self.scope: list[str] = []
        self.scope_cls: list[type] = []

    def scoped_name(self, leaf: str) -> str:
        return "/".join(self.scope + [leaf])


_TRACE: list[_TraceCtx] = []
_PARAMS: list[dict] = []


def _cur_trace() -> Optional[_TraceCtx]:
    return _TRACE[-1] if _TRACE else None


@contextlib.contextmanager
def mark(tag: str):
    """Paper Fig. 5 ``dynaflow.mark``: wrap a code block as a partition
    boundary.  During trace, ops recorded inside get scope entry ``#tag``
    which partition rules can target; in direct mode it is a no-op."""
    tc = _cur_trace()
    if tc is None:
        yield
        return
    tc.scope.append("#" + tag)
    tc.scope_cls.append(type(None))
    try:
        yield
    finally:
        tc.scope.pop()
        tc.scope_cls.pop()


# ---------------------------------------------------------------------------
# modules
# ---------------------------------------------------------------------------


class Module:
    """Composite module: ``forward`` composes child modules / Ops."""

    def __init__(self):
        object.__setattr__(self, "_children", {})
        object.__setattr__(self, "_params", {})

    def __setattr__(self, k, v):
        if isinstance(v, Module):
            self._children[k] = v
        elif isinstance(v, Param):
            self._params[k] = v
        object.__setattr__(self, k, v)

    # -- params -----------------------------------------------------------
    def init(self, key, global_: bool = False) -> dict:
        """Build the nested param dict mirroring the module tree.

        Keys are folded in from the child *name* (stable across phases:
        prefill/decode variants of a layer that share param names get
        identical weights).  ``global_=True`` builds the *global*
        (unsharded) arrays declared by ``Param.global_shape``.
        """
        import zlib
        out = {}
        items = list(self._params.items()) + list(self._children.items())
        for name, item in items:
            k = jax.random.fold_in(key, zlib.crc32(name.encode()))
            if isinstance(item, Param):
                shape = (item.global_shape if global_ and item.global_shape
                         else item.shape)
                out[name] = item.initializer()(k, shape, item.dtype)
            else:
                sub = item.init(k, global_=global_)
                if sub:
                    out[name] = sub
        return out

    def global_param_shapes(self) -> dict:
        """ShapeDtypeStructs of the global param arrays (dry-run stand-ins)."""
        out = {}
        for name, p in self._params.items():
            out[name] = jax.ShapeDtypeStruct(p.global_shape or p.shape, p.dtype)
        for name, c in self._children.items():
            sub = c.global_param_shapes()
            if sub:
                out[name] = sub
        return out

    def param_shapes(self) -> dict:
        out = {}
        for name, p in self._params.items():
            out[name] = jax.ShapeDtypeStruct(p.shape, p.dtype)
        for name, c in self._children.items():
            sub = c.param_shapes()
            if sub:
                out[name] = sub
        return out

    def param_pspecs(self) -> dict:
        """Nested dict of PartitionSpec tuples (for launch-layer shardings)."""
        out = {}
        for name, p in self._params.items():
            out[name] = p.pspec
        for name, c in self._children.items():
            sub = c.param_pspecs()
            if sub:
                out[name] = sub
        return out

    # -- execution ----------------------------------------------------------
    def forward(self, *args, **kw):
        raise NotImplementedError(type(self).__name__)

    def __call__(self, *args, **kw):
        tc = _cur_trace()
        if tc is None:
            return self.forward(*args, **kw)
        tc.scope.append(getattr(self, "_scope_name", type(self).__name__))
        tc.scope_cls.append(type(self))
        try:
            return self.forward(*args, **kw)
        finally:
            tc.scope.pop()
            tc.scope_cls.pop()

    def named(self, name: str):
        object.__setattr__(self, "_scope_name", name)
        return self

    def apply(self, params, *args, **kw):
        """Direct (eager) execution with a bound param tree."""
        _assign_paths(self)
        _PARAMS.append(params if params is not None else {})
        try:
            return self(*args, **kw)
        finally:
            _PARAMS.pop()

    def _own_params(self, path: tuple[str, ...]):
        tree = _PARAMS[-1]
        for k in path:
            if k in tree:
                tree = tree[k]
            else:
                return None
        return tree


class Op(Module):
    """Leaf logical operator; becomes one ``OpNode`` when traced.

    Subclasses implement ``kernel(p, *inputs)`` in pure jnp/lax against the
    *local shard* (manual SPMD; mesh axis names are visible inside
    ``shard_map``).  ``p`` is a dict of this op's own params (or ``{}``).
    """

    resource = "compute"
    out_batch_dim: Optional[int] = 0   # batch dim of outputs (None = not batched)

    def kernel(self, p: dict, *inputs):
        raise NotImplementedError(type(self).__name__)

    def share_params(self, path: tuple[str, ...]):
        """Use the params living at absolute ``path`` (weight tying)."""
        object.__setattr__(self, "_shared_path", tuple(path))
        return self

    # Collectives can't run under eval_shape outside shard_map — they (and
    # any op that wants to skip eval_shape) override ``infer_out``.
    def infer_out(self, in_shapes: Sequence[jax.ShapeDtypeStruct]):
        p_shapes = {n: jax.ShapeDtypeStruct(pp.shape, pp.dtype)
                    for n, pp in self._params.items()}
        return jax.eval_shape(lambda p, *xs: self.kernel(p, *xs), p_shapes, *in_shapes)

    def flops_estimate(self, in_shapes) -> float:
        return 0.0

    def bytes_estimate(self, in_shapes, out_shapes) -> float:
        import numpy as np
        tot = 0
        for s in list(in_shapes) + list(out_shapes):
            tot += int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        for p in self._params.values():
            size = 1
            for d in p.shape:
                size *= d
            tot += size * np.dtype(p.dtype).itemsize
        return float(tot)

    def __call__(self, *args, **kw):
        tc = _cur_trace()
        if tc is None:
            # Direct mode: resolve params by path captured at init-walk time.
            path = getattr(self, "_shared_path", None) or self._abs_path()
            p = _resolve_params(_PARAMS[-1] if _PARAMS else {}, path) or {}
            return self.kernel(p, *args)
        # ---- traced path: record an OpNode ----
        name = tc.scoped_name(getattr(self, "_scope_name", type(self).__name__))
        in_refs = []
        for a in args:
            if not isinstance(a, TensorRef):
                raise TypeError(
                    f"Op {name} received non-TensorRef input {type(a)}; wrap "
                    "constants as graph inputs or params")
            in_refs.append(a)
        in_shapes = [jax.ShapeDtypeStruct(r.shape, r.dtype) for r in in_refs]
        out = self.infer_out(in_shapes)
        flat, treedef = jax.tree_util.tree_flatten(out)
        obds = getattr(self, "out_batch_dims", None)  # per-output override
        out_refs = [tc.graph.new_tensor(
                        o.shape, o.dtype,
                        obds[i] if obds is not None else self.out_batch_dim,
                        name=f"{name}:o{i}")
                    for i, o in enumerate(flat)]
        path = getattr(self, "_shared_path", None) or self._abs_path()
        op_self = self

        def fn(params, *inputs):
            r = op_self.kernel(params or {}, *inputs)
            return tuple(jax.tree_util.tree_leaves(r))

        has_params = bool(self._params or self._children
                          or getattr(self, "_shared_path", None))
        cls_tags = tuple(f"cls:{i}:{c.__name__}"
                         for i, c in enumerate(tc.scope_cls))
        import numpy as _np
        pbytes = sum(int(_np.prod(pp.shape)) * _np.dtype(pp.dtype).itemsize
                     for pp in self._params.values())
        tc.graph.add_node(
            name, fn, in_refs, out_refs,
            param_paths=(path,) if has_params else (),
            resource=self.resource, scope=tuple(tc.scope) + (name.split("/")[-1],),
            tags=cls_tags + (f"cls:{len(tc.scope)}:{type(self).__name__}",),
            flops=self.flops_estimate(in_shapes),
            bytes_moved=self.bytes_estimate(in_shapes, flat),
            param_bytes=float(pbytes))
        res = jax.tree_util.tree_unflatten(treedef, out_refs)
        return res

    def _abs_path(self) -> tuple[str, ...]:
        return getattr(self, "_abs_path_", ())


def _resolve_params(tree, path):
    for k in path:
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree


def _assign_paths(mod: Module, prefix: tuple[str, ...] = ()):
    """Record each submodule's absolute path into the param tree."""
    object.__setattr__(mod, "_abs_path_", prefix)
    for name, child in mod._children.items():
        _assign_paths(child, prefix + (name,))


# ---------------------------------------------------------------------------
# tracing entry point
# ---------------------------------------------------------------------------


def trace(model: Module, inputs: dict[str, jax.ShapeDtypeStruct],
          batch_dims: Optional[dict[str, Optional[int]]] = None,
          out_names: Optional[Sequence[str]] = None) -> OpGraph:
    """Symbolically run ``model`` on named inputs, recording the OpGraph.

    ``inputs``: name -> ShapeDtypeStruct of the *local shard*.
    ``batch_dims``: name -> batch dim (default 0; None = unsplittable).
    """
    _assign_paths(model)
    g = OpGraph()
    tc = _TraceCtx(g)
    refs = {}
    for name, sds in inputs.items():
        bd = (batch_dims or {}).get(name, 0)
        refs[name] = g.add_input(name, sds.shape, sds.dtype, batch_dim=bd)
    _TRACE.append(tc)
    try:
        out = model(**refs) if _wants_kwargs(model) else model(*refs.values())
    finally:
        _TRACE.pop()
    if isinstance(out, TensorRef):
        out = {"out": out}
    elif isinstance(out, (tuple, list)):
        out = {(out_names[i] if out_names else f"out{i}"): o
               for i, o in enumerate(out)}
    for name, ref in out.items():
        g.mark_output(name, ref)
    g.validate()
    return g


def _wants_kwargs(model) -> bool:
    import inspect
    try:
        sig = inspect.signature(model.forward)
        return any(p.kind == p.KEYWORD_ONLY for p in sig.parameters.values())
    except (TypeError, ValueError):
        return False


# ---------------------------------------------------------------------------
# convenience leaf op: wrap a pure function
# ---------------------------------------------------------------------------


class FnOp(Op):
    """Wrap a pure ``fn(*inputs)`` (no params) as a schedulable Op."""

    def __init__(self, fn: Callable, name: str, resource: str = "compute",
                 out_batch_dim: Optional[int] = 0, flops_fn=None):
        super().__init__()
        self._fn = fn
        self.resource = resource
        self.out_batch_dim = out_batch_dim
        self._flops_fn = flops_fn
        self.named(name)

    def kernel(self, p, *inputs):
        return self._fn(*inputs)

    def flops_estimate(self, in_shapes):
        return self._flops_fn(in_shapes) if self._flops_fn else 0.0
