"""Plan lowering — compile an ExecutionPlan to a slot-based instruction IR.

The interpreted backend (``Realizer`` with ``lowered=False``) re-derives
everything per step at trace time: dict-keyed ``(tid, part)`` env lookups,
read-mode resolution, param-path walks, ``jnp.zeros``-initialized merge
buffers.  That interpretation layer dominates plan-to-dispatch latency —
the cost the paper's CUDA-graph mode (§3.3.2) engineers away by capturing
once and replaying.

``lower(graph, plan, analysis)`` does the capture: it simulates the plan
once against the Alg.-1 analysis and emits a flat ``LoweredPlan`` whose
instructions are fully pre-resolved:

  * every read is an integer **env slot** (the env becomes a flat list);
    slots are allocated from liveness, so a dead tensor's slot is reused
    by later writes instead of dict-popped,
  * every micro-batch slice carries precomputed ``(axis, offset, size)``,
  * every step's param subtree is an index into one per-call resolved
    param list (one path-walk pass per call, not per step),
  * prealloc merge buffers are **created by the first producer** via a
    single ``lax.pad`` placing its slice at its offset (the JAX analogue
    of writing through an uninitialized buffer — no ``jnp.zeros`` init,
    one fewer ``dynamic_update_slice``); remaining producers update in
    place.  The zero fill is semantically irrelevant: Alg. 1 only lets a
    merged read resolve once every slice has been written.

Replaying the ``LoweredPlan`` is a thin loop: list-index reads, one
callable per step, list-index frees at the precomputed death sites.

On top of the instruction stream sits the actual CUDA-graph-replay
analogue: the first execution under a given (pytree structure, avals,
bound-mesh-axes) signature is captured as a jaxpr, and every later
execution under the same signature replays it with ``eval_jaxpr`` —
op-level Python (jnp dispatch, broadcasting, dtype promotion) runs once
per capture instead of once per trace.  Re-tracing a cached segment is
~50x faster than interpreting it; serving workloads that re-jit per
bucket pay the capture once per signature.
"""
from __future__ import annotations

import copy
import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Optional

import numpy as np
from jax import lax

from .analysis import BUF, AnalysisResult, static_analysis
from .graph import FULL, OpGraph
from .plan import ExecutionPlan, graph_fingerprint, structural_key


class LoweringError(ValueError):
    """Plan / analysis / graph triple is inconsistent — refuse to lower."""


@dataclasses.dataclass
class Instr:
    """One pre-resolved plan step.

    ``reads``  — ((slot, slice), ...); slice is None or (axis, off, size)
    ``writes`` — ((slot, buf), ...); slot -1 drops the value (dead at
                 birth), buf is None or (buf_slot, start, pad_cfg, pad0):
                 pad_cfg set => create the merge buffer via ``lax.pad``,
                 else ``dynamic_update_slice`` at the precomputed start.
    ``frees``  — env slots cleared after the step (death sites).

    Not frozen: ``specialize`` re-derives instrs per shape bucket via
    shallow copy + targeted field writes, which is measurably cheaper
    than a frozen dataclass's object.__setattr__-per-field __init__ on
    the PlanStore warm-up path.  Treat instances as immutable otherwise.
    """

    fn: Callable
    reads: tuple
    writes: tuple
    frees: tuple
    fused: bool = False
    param_ix: int = -1                 # index into the resolved param list
    member_pairs: Optional[tuple] = None   # ((path, ix), ...) composite node
    fused_pairs: tuple = ()            # ((path, ix), ...) fused param dict
    step: Any = None                   # originating PlanStep (fused info)
    ext_inputs: tuple = ()             # fused: external (tid, part) reads
    ext_outputs: tuple = ()            # fused: external (tid, part) writes
    label: str = ""


_AXIS_PROBE = ("data", "model", "pod")   # mesh axes the model layer uses
_MAX_REPLAYS = 16                        # captured jaxprs kept per plan


@dataclasses.dataclass
class LoweredPlan:
    """Flat instruction stream + metadata; callable like a Realizer."""

    graph: OpGraph
    split_sizes: tuple
    instrs: tuple
    input_slots: tuple                 # ((graph input name, slot), ...)
    output_slots: tuple                # ((graph output name, slot), ...)
    param_paths: tuple                 # distinct param paths, index order
    n_slots: int
    fingerprint: str
    analysis: AnalysisResult
    stats: dict
    capture: bool = True               # jaxpr capture/replay of executions
    struct_key: tuple = ()             # shape-free (graph, plan) identity
    _replays: OrderedDict = dataclasses.field(
        default_factory=OrderedDict, repr=False, compare=False)
    _spec_cache: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    def __call__(self, params, inputs: dict) -> dict:
        if not self.capture:
            return self._execute(params, inputs)
        import jax
        import jax.tree_util as jtu
        from jax.api_util import shaped_abstractify
        flat, treedef = jtu.tree_flatten((params, inputs))
        try:
            avals = tuple(shaped_abstractify(x) for x in flat)
        except (TypeError, ValueError):       # unabstractable leaf: run raw
            return self._execute(params, inputs)
        # a capture made without a mesh must not be replayed inside one
        # (collectives would be missing), and vice versa
        from ..dist.collectives import _bound
        ctx = tuple(a for a in _AXIS_PROBE if _bound(a))
        key = (treedef, avals, ctx)
        hit = self._replays.get(key)
        if hit is None:
            closed, shape = jax.make_jaxpr(
                self._execute, return_shape=True)(params, inputs)
            # the jitted wrapper's *stable identity* is the point: jax
            # memoizes pjit tracing on (function, avals), so every later
            # re-trace of this capture binds one cached call instead of
            # re-running op-level Python
            stable = jax.jit(jax.core.jaxpr_as_fun(closed))
            hit = (closed, jtu.tree_structure(shape), stable)
            self._replays[key] = hit
            self.stats["captures"] = self.stats.get("captures", 0) + 1
            while len(self._replays) > _MAX_REPLAYS:
                self._replays.popitem(last=False)
        else:
            self._replays.move_to_end(key)
            self.stats["replays"] = self.stats.get("replays", 0) + 1
        closed, out_tree, stable = hit
        if any(isinstance(x, jax.core.Tracer) for x in flat):
            outs = stable(*flat)
        else:
            # eager one-shot: op-by-op eval, don't pay an XLA compile
            outs = jax.core.eval_jaxpr(closed.jaxpr, closed.consts, *flat)
        return jtu.tree_unflatten(out_tree, outs)

    def _execute(self, params, inputs: dict) -> dict:
        from .backend import FusedCallInfo, _resolve_path
        pvals = [_resolve_path(params, p) for p in self.param_paths]
        env: list = [None] * self.n_slots
        for name, slot in self.input_slots:
            if name not in inputs:
                raise KeyError(f"missing graph input {name!r}")
            env[slot] = inputs[name]
        for ins in self.instrs:
            args = []
            for slot, sl in ins.reads:
                v = env[slot]
                if sl is not None:
                    axis, off, sz = sl
                    v = lax.slice_in_dim(v, off, off + sz, axis=axis)
                args.append(v)
            if ins.fused:
                pdict = {p: pvals[ix] for p, ix in ins.fused_pairs}
                info = FusedCallInfo(ins.step, self.graph,
                                     list(ins.ext_inputs),
                                     list(ins.ext_outputs),
                                     self.split_sizes, pdict)
                outs = ins.fn(info, *args)
            else:
                if ins.member_pairs is not None:
                    p = {pp: pvals[ix] for pp, ix in ins.member_pairs}
                elif ins.param_ix >= 0:
                    p = pvals[ins.param_ix] or {}
                else:
                    p = {}
                outs = ins.fn(p, *args)
            if not isinstance(outs, tuple):
                outs = (outs,)
            if len(outs) != len(ins.writes):
                raise ValueError(
                    f"{ins.label} returned {len(outs)} outputs; expected "
                    f"{len(ins.writes)}")
            for (slot, buf), v in zip(ins.writes, outs):
                if slot >= 0:
                    env[slot] = v
                if buf is not None:
                    bslot, start, pad_cfg, pad0 = buf
                    if pad_cfg is not None:
                        env[bslot] = lax.pad(v, pad0, pad_cfg)
                    else:
                        env[bslot] = lax.dynamic_update_slice(
                            env[bslot], v, start)
            for s in ins.frees:
                env[s] = None
        return {name: env[slot] for name, slot in self.output_slots}


def lower(graph: OpGraph, plan: ExecutionPlan,
          analysis: Optional[AnalysisResult] = None,
          capture: bool = True) -> LoweredPlan:
    """Compile ``(plan, analysis, graph)`` into a ``LoweredPlan``."""
    if plan.graph_fingerprint:
        gfp = graph_fingerprint(graph)
        if plan.graph_fingerprint != gfp:
            raise LoweringError(
                f"plan was recorded for graph {plan.graph_fingerprint}, "
                f"got graph {gfp}")
    plan_fp = plan.fingerprint()
    if analysis is None:
        analysis = static_analysis(graph, plan)
    if analysis.plan_fingerprint and analysis.plan_fingerprint != plan_fp:
        raise LoweringError(
            f"analysis belongs to plan {analysis.plan_fingerprint}, "
            f"got plan {plan_fp}")
    if analysis.n_steps != len(plan.steps):
        raise LoweringError(
            f"analysis covers {analysis.n_steps} steps, plan has "
            f"{len(plan.steps)}")

    offsets = []
    acc = 0
    for s in plan.split_sizes:
        offsets.append(acc)
        acc += s

    deaths_by_step: dict[int, list] = {}
    for key, d in analysis.death.items():
        deaths_by_step.setdefault(d, []).append(key)

    # slot allocator: liveness-driven reuse
    slot_of: dict = {}
    free: list[int] = []
    n_slots = 0
    reused = 0

    def alloc(pending: list[int]) -> int:
        nonlocal n_slots, reused
        if pending:
            reused += 1
            return pending.pop()
        if free:
            reused += 1
            return free.pop()
        s = n_slots
        n_slots += 1
        return s

    # param-path interning: one resolve pass per call, integer refs per step
    path_ix: dict = {}

    def ix_of(path) -> int:
        if path not in path_ix:
            path_ix[path] = len(path_ix)
        return path_ix[path]

    input_slots = []
    for name, t in graph.inputs.items():
        slot_of[(t, FULL)] = alloc([])
        input_slots.append((name, slot_of[(t, FULL)]))

    def slot_for_read(t, part, mode, key, i):
        try:
            if mode == "direct":
                return slot_of[(t, key)]
            if mode == "assemble":
                return slot_of[(t, BUF)]
            return slot_of[(t, FULL)]          # slice
        except KeyError:
            raise LoweringError(
                f"step {i} reads tensor {t} part {part} ({mode}) before "
                "any live producer — plan/analysis mismatch") from None

    pad_inits = 0
    instrs = []
    for i, step in enumerate(plan.steps):
        reads = []
        for (t, p, mode, key) in analysis.reads[i]:
            slot = slot_for_read(t, p, mode, key, i)
            sl = None
            if mode == "slice":
                ref = graph.tensors[t]
                sl = (ref.batch_dim, offsets[p], plan.split_sizes[p])
            reads.append((slot, sl))

        # keys whose last read was this step free up before the writes,
        # so this step's outputs can reuse their slots (reads are already
        # materialized as Python references when the writes land)
        pending = []
        for key in deaths_by_step.get(i, ()):
            if key in slot_of:
                pending.append(slot_of.pop(key))

        writes = []
        for (t, p) in analysis.writes[i]:
            key = (t, p)
            if analysis.death.get(key) == i:
                slot = -1                      # dead at birth: never stored
            else:
                slot = alloc(pending)
                slot_of[key] = slot
            buf = None
            if t in analysis.prealloc and p != FULL:
                ref = graph.tensors[t]
                bd = ref.batch_dim
                bkey = (t, BUF)
                if bkey not in slot_of:
                    bslot = alloc(pending)
                    slot_of[bkey] = bslot
                    pad_cfg = tuple(
                        (offsets[p], ref.shape[d] - offsets[p]
                         - plan.split_sizes[p], 0) if d == bd else (0, 0, 0)
                        for d in range(len(ref.shape)))
                    buf = (bslot, None, pad_cfg, np.zeros((), ref.dtype))
                    pad_inits += 1
                else:
                    start = tuple(offsets[p] if d == bd else 0
                                  for d in range(len(ref.shape)))
                    buf = (slot_of[bkey], start, None, None)
            writes.append((slot, buf))

        frees = tuple(pending)
        free.extend(pending)

        if step.kind == "fused":
            fseen, fpairs = set(), []
            for h in step.handles:
                for pp in graph.nodes[h.oid].param_paths:
                    if pp not in fseen:
                        fseen.add(pp)
                        fpairs.append((pp, ix_of(pp)))
            instrs.append(Instr(
                fn=step.replace_fn, reads=tuple(reads), writes=tuple(writes),
                frees=frees, fused=True, fused_pairs=tuple(fpairs),
                step=step,
                ext_inputs=tuple((t, p) for (t, p, m, k) in analysis.reads[i]),
                ext_outputs=tuple(analysis.writes[i]),
                label=f"fused kernel {step.replace_name}"))
        else:
            node = graph.nodes[step.handles[0].oid]
            param_ix, member_pairs = -1, None
            if node.param_paths:
                if node.members:
                    member_pairs = tuple((pp, ix_of(pp))
                                         for pp in node.param_paths)
                else:
                    param_ix = ix_of(node.param_paths[0])
            instrs.append(Instr(
                fn=node.fn, reads=tuple(reads), writes=tuple(writes),
                frees=frees, param_ix=param_ix, member_pairs=member_pairs,
                label=f"op {node.name}"))

    output_slots = []
    for (t, _p, mode, key), name in zip(analysis.reads[-1],
                                       graph.outputs.keys()):
        output_slots.append((name, slot_for_read(t, FULL, mode, key,
                                                 len(plan.steps))))

    n_keys = len(analysis.death) + len(graph.inputs)
    return LoweredPlan(
        graph=graph, split_sizes=plan.split_sizes, instrs=tuple(instrs),
        input_slots=tuple(input_slots), output_slots=tuple(output_slots),
        param_paths=tuple(path_ix), n_slots=n_slots, fingerprint=plan_fp,
        analysis=analysis, capture=capture,
        struct_key=structural_key(graph, plan),
        stats={"n_slots": n_slots, "n_env_keys": n_keys,
               "slots_reused": reused, "pad_inits": pad_inits,
               "n_instrs": len(instrs)})


def specialize(canonical: LoweredPlan, graph: OpGraph, plan: ExecutionPlan,
               capture: Optional[bool] = None,
               struct_key: Optional[tuple] = None) -> LoweredPlan:
    """Re-derive a canonical lowering for a new shape bucket.

    The cross-bucket share path: a prefill bucket re-traces the same
    layer program at a different sequence length, and a decode batch
    tier re-traces it at a different *batch* size — either way the
    (graph, plan) pair is *structurally* identical to an already-lowered
    one — same nodes, same step stream, same slots and death sites — and
    only the shape-dependent pieces differ: slice ``(axis, offset,
    size)`` triples (micro-batch offsets/sizes are re-read from the new
    plan's ``split_sizes``, so a split over a smaller batch rewrites
    cleanly), merge-buffer pad configs (padding widths come from the new
    graph's tensor shapes, batch dim included), and the op callables
    (closures re-traced with the new shapes).  ``specialize`` rewrites
    exactly those from ``canonical``, skipping static analysis and slot
    allocation entirely; everything liveness-derived (slots, frees,
    param interning, input/output slot maps) is reused verbatim.  The
    serve engine's decode tiers lean on the batch half: tiers 2..N of
    ``max_batch`` are shares off one canonical capture, with the tier
    living in the PlanStore's inner (shape-bucket) key.  A tier whose
    scheduler asks for a different micro-batch *count* (e.g. batch 1
    cannot split in two) changes the structural key and cold-lowers as
    its own canonical — counted under ``specialize_rejects`` when it
    reached the specialize attempt.  This loop is the per-bucket warm-up
    cost,
    so it stays allocation-light: unchanged read/write tuples are reused,
    and ``Instr`` is rebuilt positionally (``dataclasses.replace`` is
    several times slower and would erase the share-path speedup).

    Raises ``LoweringError`` when the structural keys disagree — the
    caller (``PlanStore``) then falls back to a full ``lower``.
    ``struct_key``, when given, must be ``structural_key(graph, plan)``
    already computed by the caller (the store computes it for its outer
    key anyway; computing it twice would cost as much as the rewrite).
    """
    skey = struct_key or structural_key(graph, plan)
    if canonical.struct_key != skey:
        import hashlib

        def _digest(k):
            return hashlib.sha256(repr(k).encode()).hexdigest()[:16]
        raise LoweringError(
            f"cannot specialize: canonical lowering has structure "
            f"{_digest(canonical.struct_key)}, new (graph, plan) has "
            f"{_digest(skey)}")
    plan_fp = plan.fingerprint()
    ana = canonical.analysis
    sizes = plan.split_sizes
    tensors = graph.tensors
    nodes = graph.nodes

    offsets = []
    acc = 0
    for s in sizes:
        offsets.append(acc)
        acc += s

    # which instrs carry shape-dependent reads/writes — and the op id each
    # non-fused instr rebinds to — is itself structural: compute once per
    # canonical, not once per bucket (the oids come from this call's plan,
    # but the structural-key match guarantees they are bucket-invariant)
    recipe = canonical._spec_cache.get("recipe")
    if recipe is None:
        recipe = tuple(
            (any(sl is not None for _, sl in ins.reads),
             any(b is not None for _, b in ins.writes),
             -1 if ins.fused else step.handles[0].oid)
            for ins, step in zip(canonical.instrs, plan.steps))
        canonical._spec_cache["recipe"] = recipe

    copy_ = copy.copy
    instrs = []
    for i, ins in enumerate(canonical.instrs):
        dyn_r, dyn_w, oid = recipe[i]
        new = copy_(ins)
        if oid < 0:                       # fused: rebind kernel + step
            step = plan.steps[i]
            new.fn = step.replace_fn
            new.step = step
        else:
            new.fn = nodes[oid].fn
        if dyn_r:
            rr = []
            for (slot, sl), (t, p, _m, _k) in zip(ins.reads, ana.reads[i]):
                if sl is not None:
                    ref = tensors[t]
                    sl = (ref.batch_dim, offsets[p], sizes[p])
                rr.append((slot, sl))
            new.reads = tuple(rr)
        if dyn_w:
            ww = []
            for (slot, buf), (t, p) in zip(ins.writes, ana.writes[i]):
                if buf is not None:
                    bslot, _, pad_cfg, _ = buf
                    ref = tensors[t]
                    bd = ref.batch_dim
                    if pad_cfg is not None:   # first producer: pad create
                        cfg = tuple(
                            (offsets[p], ref.shape[d] - offsets[p]
                             - sizes[p], 0) if d == bd else (0, 0, 0)
                            for d in range(len(ref.shape)))
                        buf = (bslot, None, cfg, np.zeros((), ref.dtype))
                    else:
                        start = tuple(offsets[p] if d == bd else 0
                                      for d in range(len(ref.shape)))
                        buf = (bslot, start, None, None)
                ww.append((slot, buf))
            new.writes = tuple(ww)
        instrs.append(new)

    analysis = dataclasses.replace(
        ana, plan_fingerprint=plan_fp,
        buffer_bytes=sum(tensors[t].nbytes for t in ana.prealloc))
    return LoweredPlan(
        graph=graph, split_sizes=sizes, instrs=tuple(instrs),
        input_slots=canonical.input_slots,
        output_slots=canonical.output_slots,
        param_paths=canonical.param_paths, n_slots=canonical.n_slots,
        fingerprint=plan_fp, analysis=analysis,
        capture=canonical.capture if capture is None else capture,
        struct_key=skey,
        stats={**{k: v for k, v in canonical.stats.items()
                  if k not in ("captures", "replays")},
               "specialized_from": canonical.fingerprint})
