"""Shared dependency-aware greedy interleaver.

Emits a plan order where every network op is issued as early as possible
and the gap until its first consumer is filled with *independent* compute
from other micro-batches (or independent sections) — the order XLA's
latency-hiding scheduler needs to overlap async collectives on TPU.

Selection rule per step, given the set of in-flight collective outputs:
  1. never pick an op consuming an in-flight tensor if an alternative
     exists (it would close the overlap window),
  2. with a collective in flight prefer compute/memory ops (fill the
     window); otherwise prefer issuing the next network op,
  3. tie-break by (oid, micro-batch) for determinism.
"""
from __future__ import annotations


def greedy_overlap(ctx, parts, within=None):
    """Schedule all remaining ops of ``parts`` (micro-batch ids), restricted
    to oids in ``within`` when given."""
    g = ctx.graph
    inflight: set = set()          # {(tid, mb)} produced by issued collectives

    def ins_of(h):
        return {(t, h.mb) for t in g.nodes[h.oid].inputs}

    def net_outs(h):
        """Outputs that are true collective payloads: for composite units,
        only tensors produced by *network* member ops count (riders from
        fused memory ops don't close an overlap window)."""
        n = g.nodes[h.oid]
        ts = set(n.outputs)
        if n.members:
            ts &= {t for m in n.members if m.resource == "network"
                   for t in m.outputs}
        return {(t, h.mb) for t in ts}

    while True:
        ready = [h for i in parts for h in ctx.get_ready_ops(i)
                 if within is None or h.oid in within]
        if not ready:
            break

        def key(h):
            dep = bool(ins_of(h) & inflight)
            is_net = ctx.resource_of(h) == "network"
            pref = 0 if is_net == (not inflight) else 1
            return (dep, pref, h.oid, h.mb)

        ready.sort(key=key)
        pick = ready[0]
        ctx.execute(pick)
        inflight -= ins_of(pick)
        if ctx.resource_of(pick) == "network":
            inflight |= net_outs(pick)
