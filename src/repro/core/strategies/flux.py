"""Flux integration (paper §5.3.5): fused GEMM+AllReduce via
``replace_func``.  Reproduces the paper's negative result — the chunked
collective multiplies per-message latency, so the roofline model shows a
regression at small batch; kept as the rapid-prototyping demonstration."""
import functools

from ..scheduler import OpSchedulerBase
from .fused import flux_fused


class Flux(OpSchedulerBase):
    name = "flux"

    def __init__(self, axis: str = "model", n_chunks: int = 4):
        self.axis = axis
        self.n_chunks = n_chunks

    def pairs(self, g):
        """[linear, psum] pairs: GEMM output feeds only the all-reduce."""
        out = []
        for oid in g.topo_order():
            n = g.nodes[oid]
            if not ("o_proj" in n.name or "mlp_out" in n.name):
                continue
            cons = g.consumers.get(n.outputs[0], [])
            if len(cons) != 1:
                continue
            ar = g.nodes[cons[0]]
            if ar.resource == "network" and "ar_" in ar.name:
                out.append((n.oid, ar.oid))
        return out

    def schedule(self, ctx):
        fn = functools.partial(flux_fused, axis=self.axis,
                               n_chunks=self.n_chunks)
        fused = {}
        for pair in self.pairs(ctx.graph):
            for oid in pair:
                fused[oid] = pair
        done = set()
        while True:
            ready = [h for h in ctx.get_ready_ops() if h.oid not in done]
            if not ready:
                break
            h = ready[0]
            pair = fused.get(h.oid)
            if pair and h.oid == pair[0]:
                handles = [x for x in ctx.handles() if x.oid in pair]
                ctx.execute(tuple(handles), replace_func=fn,
                            replace_name="flux")
                done.update(pair)
            else:
                ctx.execute(h)
                done.add(h.oid)
