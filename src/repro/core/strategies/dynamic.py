"""Context-driven strategy selection — the paper's headline capability.

Since PR 5 the selection logic is no longer a hardcoded ``pick`` method:
``dynamic_policy()`` states it as :mod:`repro.core.policy` combinators —
the same API users compose their own policies from — and
``DynamicScheduler`` is a thin scheduler adapter over that policy (kept
because every pre-facade entry point passes schedulers around):

  MoE graph, large batch   -> DBO  (attention merged, MoE split+overlap)
  dense graph, large batch -> NanoFlow split + TokenWeave fusion targets
  any graph, small batch   -> SBO reorder-only (no split: the paper's
                              Fig. 2a point — splitting small batches
                              inflates memory traffic)
  tiny batch               -> sequential fallback (lowest CPU overhead,
                              paper Fig. 8)
"""
import dataclasses

from ..._deprecation import warn_once
from ..policy import (PolicyScheduler, StrategyPolicy, by_token_threshold,
                      first_viable, has_ops, local_batch_below, when)
from .dbo import DualBatchOverlap
from .nanoflow import NanoFlow
from .sbo import SingleBatchOverlap
from .sequential import Sequential
from .tokenweave import TokenWeave


@dataclasses.dataclass(frozen=True)
class has_fusable_triples:
    """Predicate: the graph has [all-reduce -> add -> RMSNorm] chains
    TokenWeave can replace with its fused kernel."""

    def __call__(self, ctx) -> bool:
        g = (ctx.extra or {}).get("graph")
        return g is not None and bool(TokenWeave().triples(g))


def dynamic_policy(split_tokens: int = 2048, seq_tokens: int = 64,
                   fuse: bool = True) -> StrategyPolicy:
    """The built-in ``dynamic`` selection, stated as policy combinators.

    Token thresholds route tiny steps to sequential and sub-split steps
    to SBO; above the split threshold a viability chain prefers DBO on
    MoE graphs, TokenWeave where its fusion targets exist, and NanoFlow
    otherwise.  Users swap any branch without touching the others."""
    sbo = SingleBatchOverlap()
    fuse_branch = (when(has_fusable_triples(), TokenWeave()),) if fuse \
        else ()
    big = first_viable(
        when(local_batch_below(2), sbo),
        when(has_ops(r"moe_a2a|expert_ffn"),
             DualBatchOverlap(min_tokens=split_tokens)),
        *fuse_branch,
        default=NanoFlow(min_tokens=split_tokens))
    return by_token_threshold(
        [(seq_tokens, Sequential()), (split_tokens, sbo)], above=big)


class _DynamicAdapter(PolicyScheduler):
    """Scheduler adapter over ``dynamic_policy`` (or any policy passed as
    ``policy=``): resolves the sub-strategy at plan-record time from the
    partitioned graph + context, then delegates ``schedule``.

    This is the registry's scheduler-path form of ``"dynamic"``
    (``get_strategy("dynamic")``) and carries no deprecation warning —
    the name, identity tuple and PlanStore salts are unchanged from the
    pre-PR-8 ``DynamicScheduler``, so persisted artifacts keep
    redeeming."""

    name = "dynamic"

    def __init__(self, split_tokens: int = 2048, seq_tokens: int = 64,
                 fuse: bool = True, policy: StrategyPolicy = None):
        self.split_tokens = split_tokens
        self.seq_tokens = seq_tokens
        self.fuse = fuse
        super().__init__(policy or dynamic_policy(split_tokens, seq_tokens,
                                                  fuse),
                         name="dynamic")


class DynamicScheduler(_DynamicAdapter):
    """Deprecated entry point for the built-in pick table.

    Spell the same behavior as ``policy="dynamic"`` (registry name, the
    ``api.compile`` path), ``get_strategy("dynamic")`` (scheduler
    adapter), or ``dynamic_policy()`` (the combinator tree itself) —
    or close the loop entirely with ``policy="auto"``."""

    def __init__(self, *args, **kwargs):
        warn_once("repro.core.strategies.DynamicScheduler",
                  "policy='dynamic' (the strategy registry) or "
                  "dynamic_policy()")
        super().__init__(*args, **kwargs)
