"""Context-driven strategy selection — the paper's headline capability.

The scheduler inspects the execution context (token count, phase, graph
contents) at plan-record time and delegates to the best sub-strategy:

  MoE graph, large batch   -> DBO  (attention merged, MoE split+overlap)
  dense graph, large batch -> NanoFlow split + TokenWeave fusion targets
  any graph, small batch   -> SBO reorder-only (no split: the paper's
                              Fig. 2a point — splitting small batches
                              inflates memory traffic)
  tiny batch               -> sequential fallback (lowest CPU overhead,
                              paper Fig. 8)
"""
from ..scheduler import OpSchedulerBase
from .dbo import DualBatchOverlap
from .nanoflow import NanoFlow
from .sbo import SingleBatchOverlap
from .sequential import Sequential
from .tokenweave import TokenWeave


class DynamicScheduler(OpSchedulerBase):
    name = "dynamic"

    def __init__(self, split_tokens: int = 2048, seq_tokens: int = 64,
                 fuse: bool = True):
        self.split_tokens = split_tokens
        self.seq_tokens = seq_tokens
        self.fuse = fuse
        self._dbo = DualBatchOverlap(min_tokens=split_tokens)
        self._nano = NanoFlow(min_tokens=split_tokens)
        self._sbo = SingleBatchOverlap()
        self._seq = Sequential()
        self._tw = TokenWeave()

    def partition_rules(self):
        return self._dbo.partition_rules()

    def pick(self, ctx):
        from . import tokens_of
        t = tokens_of(ctx.info)
        has_moe = bool(ctx.find(r"moe_a2a|expert_ffn"))
        if t < self.seq_tokens:
            return self._seq
        if t < self.split_tokens or ctx.info.local_batch < 2:
            return self._sbo
        if has_moe:
            return self._dbo
        if self.fuse and self._tw.triples(ctx.graph):
            return self._tw
        return self._nano

    def schedule(self, ctx):
        self.pick(ctx).schedule(ctx)
