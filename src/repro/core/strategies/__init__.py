"""Intra-device parallelism strategies (paper Table 2), each implemented
as an ``OpSchedulerBase`` on the DynaFlow frontend APIs — the LoC of these
files is the reproduction of the paper's engineering-cost claim.

  sequential   fallback (paper §3.2.2: execute without a kernel)
  nanoflow     split micro-batches + resource-interleave  (Zhu et al.)
  dbo          dual-batch overlap: attention merged, MoE split (DeepSeek)
  sbo          single-batch overlap: reorder independent compute behind
               network ops (LongCat-style)
  tokenweave   fused AR+add+RMSNorm via replace_func        (Gond et al.)
  comet        chunked a2a/expert-GEMM overlap via replace_func
  flux         fused GEMM+AR via replace_func (reproduces the paper's
               negative result §5.3.5)
  dynamic      context-driven selection among the above (the paper's
               headline contribution: per-bucket strategy choice)
"""
from ..policy import tokens_of  # noqa: F401  (re-export: legacy home)
from .comet import Comet
from .dbo import DualBatchOverlap
from .dynamic import DynamicScheduler, dynamic_policy  # noqa: F401
from .flux import Flux
from .nanoflow import NanoFlow
from .sbo import SingleBatchOverlap
from .sequential import Sequential
from .tokenweave import TokenWeave

STRATEGIES = {
    "sequential": Sequential,
    "nanoflow": NanoFlow,
    "dbo": DualBatchOverlap,
    "sbo": SingleBatchOverlap,
    "tokenweave": TokenWeave,
    "comet": Comet,
    "flux": Flux,
    "dynamic": DynamicScheduler,
}


def get_strategy(name: str, **kw):
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kw)
