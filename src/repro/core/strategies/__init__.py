"""Intra-device parallelism strategies (paper Table 2), each implemented
as an ``OpSchedulerBase`` on the DynaFlow frontend APIs — the LoC of these
files is the reproduction of the paper's engineering-cost claim.

  sequential   fallback (paper §3.2.2: execute without a kernel)
  nanoflow     split micro-batches + resource-interleave  (Zhu et al.)
  dbo          dual-batch overlap: attention merged, MoE split (DeepSeek)
  sbo          single-batch overlap: reorder independent compute behind
               network ops (LongCat-style)
  tokenweave   fused AR+add+RMSNorm via replace_func        (Gond et al.)
  comet        chunked a2a/expert-GEMM overlap via replace_func
  flux         fused GEMM+AR via replace_func (reproduces the paper's
               negative result §5.3.5)
  dynamic      context-driven selection among the above (the paper's
               headline contribution: per-bucket strategy choice)
  auto         cost-model-driven selection + parameterization per context
               (core/autotune.py — the self-programming closing of the
               loop)

Since PR 8 the authoritative name -> strategy mapping is the
**registry** (:mod:`.registry`): ``register_strategy`` adds a strategy
to every consumer at once (``get_strategy``, ``policy="name"`` through
``api.compile``, the launch ``--strategy`` flags, and the autotuner's
candidate enumeration).  ``STRATEGIES`` remains as a read-only
compatibility view of the registered factories.
"""
from ..policy import tokens_of  # noqa: F401  (re-export: legacy home)
from .comet import Comet  # noqa: F401
from .dbo import DualBatchOverlap  # noqa: F401
from .dynamic import DynamicScheduler, dynamic_policy  # noqa: F401
from .flux import Flux  # noqa: F401
from .nanoflow import NanoFlow  # noqa: F401
from .registry import (UnknownStrategyError,  # noqa: F401
                       get_entry, make_scheduler, register_strategy,
                       strategy_names, tunable_candidates)
from .registry import _REGISTRY as _REG
from .sbo import SingleBatchOverlap  # noqa: F401
from .sequential import Sequential  # noqa: F401
from .tokenweave import TokenWeave  # noqa: F401

# compatibility view over the registry (name -> factory); prefer
# get_strategy()/register_strategy() — mutating this dict has no effect
STRATEGIES = {name: entry.factory for name, entry in sorted(_REG.items())}


def get_strategy(name: str, **kw):
    """Build a scheduler by registry name.  Unknown names raise
    :class:`UnknownStrategyError` (a ``KeyError``) listing choices."""
    return make_scheduler(name, **kw)
