"""Sequential fallback — the paper's baseline mode (Fig. 8 'fallback')."""
from ..scheduler import OpSchedulerBase


class Sequential(OpSchedulerBase):
    name = "sequential"

    def schedule(self, ctx):
        ctx.run_rest_sequential()
