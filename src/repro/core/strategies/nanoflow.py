"""NanoFlow-style intra-device parallelism (paper §5.3.1).

Split the batch into nano-batches, then greedily interleave ready ops so
consecutive plan steps use different resources (compute / memory /
network): the plan order is the HLO emission order, so a network op
followed by the other nano-batch's compute op overlaps on TPU.  Below the
token threshold the strategy falls back to sequential — the dynamic
context condition whose absence degrades the naive SGLang integration to
0.35x (paper Fig. 9).
"""
from ..scheduler import OpSchedulerBase


class NanoFlow(OpSchedulerBase):
    name = "nanoflow"

    def __init__(self, min_tokens: int = 2048, n_split: int = 2):
        self.min_tokens = min_tokens
        self.n_split = n_split

    def schedule(self, ctx):
        from . import tokens_of
        b = ctx.info.local_batch
        if tokens_of(ctx.info) < self.min_tokens or b < self.n_split:
            ctx.run_rest_sequential()
            return
        from ._greedy import greedy_overlap
        n = self.n_split
        sizes = [b // n] * n
        sizes[-1] += b - sum(sizes)
        ctx.split(sizes)
        greedy_overlap(ctx, range(n))
