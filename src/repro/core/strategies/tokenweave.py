"""TokenWeave integration (paper §5.3.4, Fig. 7 bottom).

Finds every [all-reduce -> residual-add -> RMSNorm] chain and replaces it
with the fused RS + add/norm-on-shard + AG kernel.  The paper's runtime
CTA-count knob maps to the Pallas kernel's ``block_rows``, selected here
per batch bucket (the §5.3.4 'up to 12%' adaptive win).
"""
import functools

from ..scheduler import OpSchedulerBase
from .fused import tokenweave_fused


class TokenWeave(OpSchedulerBase):
    name = "tokenweave"

    def __init__(self, axis: str = "model"):
        self.axis = axis

    def triples(self, g):
        """[ar, add, norm] chains: ar out only feeds add; add feeds norm."""
        out = []
        for oid in g.topo_order():
            n = g.nodes[oid]
            if n.resource != "network" or "ar_" not in n.name:
                continue
            cons = g.consumers.get(n.outputs[0], [])
            if len(cons) != 1:
                continue
            add = g.nodes[cons[0]]
            if "add" not in add.name or len(add.inputs) != 2:
                continue
            norms = [g.nodes[c] for c in g.consumers.get(add.outputs[0], [])
                     if "ln_" in g.nodes[c].name or "rmsnorm" in g.nodes[c].name]
            if not norms:
                continue
            out.append((n.oid, add.oid, norms[0].oid))
        return out

    def schedule(self, ctx):
        from . import tokens_of
        # CTA-count analogue: smaller row blocks for small batches
        br = 128 if tokens_of(ctx.info) < 4096 else 256
        fn = functools.partial(tokenweave_fused, axis=self.axis,
                               block_rows=br)
        fused = {}
        for tri in self.triples(ctx.graph):
            for oid in tri:
                fused[oid] = tri
        done = set()
        while True:
            ready = ctx.get_ready_ops()
            ready = [h for h in ready if h.oid not in done]
            if not ready:
                break
            h = ready[0]
            tri = fused.get(h.oid)
            if tri and h.oid == tri[0]:
                handles = [x for x in ctx.handles() if x.oid in tri]
                ctx.execute(tuple(handles), replace_func=fn,
                            replace_name="tokenweave")
                done.update(tri)
            else:
                ctx.execute(h)
                done.add(h.oid)
