"""Strategy registry — every schedulable strategy, addressable by name.

Before PR 8, strategy construction was ad hoc: a hardcoded
``STRATEGIES`` dict plus per-call-site class imports, and the *choice*
among them lived in ``dynamic_policy``'s hand-written threshold table.
The registry makes the strategy surface a first-class, extensible API:

  * ``register_strategy(name, factory, param_space)`` — one call adds a
    strategy to every consumer: ``get_strategy(name)``,
    ``as_policy("name")`` / ``api.compile(policy="name")``, the launch
    ``--strategy`` flags, and the :class:`~repro.core.autotune.AutoPolicy`
    candidate enumeration;
  * ``param_space`` declares the parameterizations the autotuner sweeps
    (a mapping of constructor-kwarg name to a tuple of values — the
    cartesian product is the candidate set);
  * entries may also carry a ``policy_factory`` — names like
    ``"dynamic"`` and ``"auto"`` denote *policies* (context-dependent
    selection), which ``as_policy`` resolves to the policy itself while
    ``get_strategy`` still hands back a scheduler adapter;
  * unknown names raise :class:`UnknownStrategyError` (a ``KeyError``)
    whose message lists every registered choice.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterator, Mapping, Optional


class UnknownStrategyError(KeyError):
    """A strategy name with no registry entry; lists the valid choices."""

    def __init__(self, name: str, choices):
        self.unknown_name = name
        self.choices = tuple(choices)
        super().__init__(
            f"unknown strategy {name!r}; registered strategies: "
            f"{', '.join(self.choices)}")

    def __str__(self):          # KeyError.__str__ would repr the message
        return self.args[0]


@dataclasses.dataclass(frozen=True)
class StrategyEntry:
    """One registered strategy.

    ``factory(**params)`` builds a scheduler; ``param_space`` is a
    canonical tuple of ``(kwarg, (values...))`` pairs the autotuner
    enumerates; ``policy_factory`` (optional) builds the
    ``StrategyPolicy`` form of policy-kind entries; ``tunable`` gates
    whether :class:`AutoPolicy` considers the entry a candidate
    (policy-kind entries are selectors, not schedules — never tuned)."""

    name: str
    factory: Callable
    param_space: tuple = ()
    policy_factory: Optional[Callable] = None
    tunable: bool = True

    def candidates(self) -> Iterator[dict]:
        """Parameter dicts over the cartesian product of ``param_space``
        (one empty dict when the strategy has no tunable knobs)."""
        if not self.param_space:
            yield {}
            return
        names = [n for n, _ in self.param_space]
        for combo in itertools.product(*(vs for _, vs in self.param_space)):
            yield dict(zip(names, combo))


_REGISTRY: dict = {}


def register_strategy(name: str, factory: Callable,
                      param_space: Optional[Mapping] = None, *,
                      policy_factory: Optional[Callable] = None,
                      tunable: bool = True,
                      overwrite: bool = False) -> StrategyEntry:
    """Register (or with ``overwrite=True`` replace) a strategy."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"strategy {name!r} is already registered; pass overwrite=True "
            "to replace it")
    space = tuple(sorted(
        (str(k), tuple(v)) for k, v in dict(param_space or {}).items()))
    entry = StrategyEntry(name, factory, space, policy_factory, tunable)
    _REGISTRY[name] = entry
    return entry


def strategy_names() -> list:
    return sorted(_REGISTRY)


def get_entry(name: str) -> StrategyEntry:
    entry = _REGISTRY.get(name)
    if entry is None:
        raise UnknownStrategyError(name, strategy_names())
    return entry


def make_scheduler(name: str, **params):
    """Build a scheduler instance by registry name (typed error on an
    unknown name) — the implementation behind ``get_strategy``."""
    return get_entry(name).factory(**params)


def tunable_candidates() -> Iterator[tuple]:
    """``(name, params)`` pairs the autotuner enumerates, in a
    deterministic order (sorted names × declared param space)."""
    for name in strategy_names():
        entry = _REGISTRY[name]
        if not entry.tunable:
            continue
        for params in entry.candidates():
            yield name, params


# -- built-in registrations --------------------------------------------------
# Scheduler entries declare the parameterizations worth sweeping:
# NanoFlow/DBO register with min_tokens=1 in the tuning space — the
# autotuner's cost model (split_weight_penalty) decides where splitting
# stops paying, instead of a hand-picked token threshold.


def _dynamic_scheduler(**kw):
    from .dynamic import _DynamicAdapter
    return _DynamicAdapter(**kw)


def _dynamic_as_policy(**kw):
    from .dynamic import dynamic_policy
    return dynamic_policy(**kw)


def _auto_as_policy(**kw):
    from ..autotune import AutoPolicy
    return AutoPolicy(**kw)


def _auto_scheduler(**kw):
    from ..policy import PolicyScheduler
    return PolicyScheduler(_auto_as_policy(**kw), name="auto")


def _spec_decode_scheduler(**kw):
    # spec_decode is a knob carrier, not a graph scheduler: the serve
    # engine reads its param_space ("draft_k") for SpecConfig(k="auto")
    # candidates, while the op-schedule of the verify step is whatever
    # strategy/policy the engine was compiled with.  Resolving it as a
    # strategy hands back plain sequential scheduling.
    from .sequential import Sequential
    kw.pop("draft_k", None)
    return Sequential(**kw)


def _register_builtins():
    from .comet import Comet
    from .dbo import DualBatchOverlap
    from .flux import Flux
    from .nanoflow import NanoFlow
    from .sbo import SingleBatchOverlap
    from .sequential import Sequential
    from .tokenweave import TokenWeave
    register_strategy("sequential", Sequential)
    register_strategy("nanoflow", NanoFlow,
                      {"min_tokens": (1,), "n_split": (2, 4)})
    register_strategy("dbo", DualBatchOverlap, {"min_tokens": (1,)})
    register_strategy("sbo", SingleBatchOverlap)
    register_strategy("tokenweave", TokenWeave)
    register_strategy("comet", Comet)
    register_strategy("flux", Flux)
    register_strategy("dynamic", _dynamic_scheduler,
                      policy_factory=_dynamic_as_policy, tunable=False)
    register_strategy("auto", _auto_scheduler,
                      policy_factory=_auto_as_policy, tunable=False)
    # draft-k tunable for serve-side speculative decode.  tunable=False
    # keeps it out of the autotuner's *scheduler* sweep (it does not
    # schedule ops); AutoPolicy.spec_draft_k picks from this param_space
    # using acceptance rates fed through AutoPolicy.observe.
    register_strategy("spec_decode", _spec_decode_scheduler,
                      {"draft_k": (2, 4, 8)}, tunable=False)


_register_builtins()
