"""Single-batch overlap (paper Table 2 'SBO'; LongCat-style).

No batch split: reorder the plan so every network op is issued as early
as its dependencies allow and independent compute/memory ops are placed
between the collective and its first consumer — on TPU, XLA's
latency-hiding scheduler turns that program order into async-collective
overlap.  Captures the paper's Fig. 1a pattern (shared expert ∥ dispatch)
and the ZeRO weight-gather prefetch without touching model code.
"""
from ..graph import FULL
from ..scheduler import OpSchedulerBase


class SingleBatchOverlap(OpSchedulerBase):
    name = "sbo"

    def schedule(self, ctx):
        g = ctx.graph
        while True:
            ready = ctx.get_ready_ops(FULL)
            if not ready:
                break
            nets = [h for h in ready if ctx.resource_of(h) == "network"]
            rest = [h for h in ready if ctx.resource_of(h) != "network"]
            if nets:
                # issue EVERY ready collective back-to-back (weight
                # gathers, dispatch a2a, ...) so later ones see the whole
                # downstream compute chain as their overlap window, then
                # fill with the ready non-dependent compute
                blocked = set()
                for h in nets:
                    ctx.execute(h)
                    blocked |= set(g.nodes[h.oid].outputs)
                for h in rest:
                    if not (set(g.nodes[h.oid].inputs) & blocked):
                        ctx.execute(h)
            elif rest:
                ctx.execute(rest[0])
