"""Dual-batch overlap (paper Fig. 7a-c, §5.3.2; DeepSeek-V3).

Attention runs as a single merged batch (compute-dense, no benefit from
splitting); the MoE section runs as two micro-batches whose all-to-alls
interleave with the other micro-batch's expert GEMM.  The batch-size
condition is checked at schedule time — the dynamic choice vLLM's static
threshold lacks (paper §5.3.2).
"""
from ..partition import Mark
from ..plan import OpHandle
from ..scheduler import OpSchedulerBase


class DualBatchOverlap(OpSchedulerBase):
    name = "dbo"

    def __init__(self, min_tokens: int = 2048):
        self.min_tokens = min_tokens

    def partition_rules(self):
        return [Mark("moe_dispatch"), Mark("moe_combine")]

    def partition_rules(self):
        from ..partition import SplitFunc
        # keep weight gathers as standalone units so the prefetch hoist
        # can issue them ahead of the whole layer (coalescing them into
        # their consumer destroys the overlap window)
        return [Mark("moe_dispatch"), Mark("moe_combine"),
                Mark("moe_shared"), SplitFunc(r"gather")]

    def schedule(self, ctx):
        from . import tokens_of
        from ._greedy import greedy_overlap
        g = ctx.graph
        moe = ctx.find(
            r"moe_dispatch|moe_combine|expert_ffn|moe_a2a|moe_shared")
        b = ctx.info.local_batch
        if not moe or tokens_of(ctx.info) < self.min_tokens or b < 2:
            ctx.run_rest_sequential()
            return
        ctx.split([b // 2, b - b // 2])
        region = {h.oid for h in moe}
        lo = min(region)
        # prefetch: issue every dependency-free weight gather (ZeRO/FSDP)
        # up front so the whole layer is its overlap window (§2.1)
        prefetched = set()
        for h in ctx.get_ready_ops(0):
            if (ctx.resource_of(h) == "network"
                    and not g.splittable(h.oid) and h.oid not in region):
                ctx.execute(h)
                prefetched.add(h.oid)
        region_done = False
        for oid in g.topo_order():
            n = g.nodes[oid]
            if oid >= lo and not region_done:
                greedy_overlap(ctx, (0, 1), within=region)
                region_done = True
            if oid in region or oid in prefetched:
                continue
            if g.splittable(oid):
                ctx.execute(tuple(OpHandle(oid, i, n.name) for i in (0, 1)))
            else:
                ctx.execute(OpHandle(oid, 0, n.name))
