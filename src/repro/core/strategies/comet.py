"""Comet integration (paper §5.3.6): fused expert-parallel
dispatch/GEMM/combine with chunked communication-computation overlap,
dropped in via ``replace_func`` without forking the framework."""
import functools

from ..scheduler import OpSchedulerBase
from .fused import comet_fused


class Comet(OpSchedulerBase):
    name = "comet"

    def __init__(self, axis: str = "model", n_chunks: int = 4):
        self.axis = axis
        self.n_chunks = n_chunks

    def chains(self, g):
        """[a2a_dispatch, expert_ffn, a2a_combine] chains."""
        out = []
        for oid in g.topo_order():
            n = g.nodes[oid]
            if "moe_a2a_dispatch" not in n.name:
                continue
            ffn = [g.nodes[c] for c in g.consumers.get(n.outputs[0], [])
                   if "expert_ffn" in g.nodes[c].name]
            if not ffn or not ffn[0].param_paths:
                continue   # FSDP-gathered weights: fusion not composed
            comb = [g.nodes[c] for c in g.consumers.get(ffn[0].outputs[0], [])
                    if "moe_a2a_combine" in g.nodes[c].name]
            if not comb:
                continue
            out.append((n.oid, ffn[0].oid, comb[0].oid))
        return out

    def schedule(self, ctx):
        fn = functools.partial(comet_fused, axis=self.axis,
                               n_chunks=self.n_chunks)
        fused = {}
        for tri in self.chains(ctx.graph):
            for oid in tri:
                fused[oid] = tri
        done = set()
        while True:
            ready = [h for h in ctx.get_ready_ops() if h.oid not in done]
            if not ready:
                break
            h = ready[0]
            tri = fused.get(h.oid)
            if tri and h.oid == tri[0]:
                handles = [x for x in ctx.handles() if x.oid in tri]
                ctx.execute(tuple(handles), replace_func=fn,
                            replace_name="comet")
                done.update(tri)
            else:
                ctx.execute(h)
                done.add(h.oid)
