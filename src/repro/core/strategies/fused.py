"""Fused replacement kernels used via ``execute(..., replace_func=...)``.

Each takes the ``FusedCallInfo`` the backend hands to ``replace_func``
plus the group's external inputs, and returns the group's external
outputs.  They run inside the jitted step (and inside shard_map when the
mesh is bound), so lax collectives and Pallas kernels compose freely.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ...dist import collectives as col


def tokenweave_fused(info, *vals, axis: str = "model", block_rows: int = 256):
    """Replace [psum, add, rmsnorm] with RS + fused add/norm + AG.

    Handles order: (ar, add, norm).  Returns (s, h) = (x + psum(y),
    rmsnorm(x + psum(y)) * g) matching the group's external outputs."""
    from ...kernels import ops as kops
    g_param = info.params_of(2)["g"]
    ar_node = info.node(0)
    y_tid = ar_node.inputs[0]
    idx = {t: i for i, (t, p) in enumerate(info.ext_inputs)}
    y_partial = vals[idx[y_tid]]
    add_node = info.node(1)
    x_tid = next(t for t in add_node.inputs if t != ar_node.outputs[0])
    x = vals[idx[x_tid]]
    tp = col.axis_size(axis)
    if x.shape[1] % max(tp, 1):   # sequence not divisible: plain fused path
        s, h = kops.fused_add_rmsnorm(x, col.psum(y_partial, axis), g_param)
        return s, h
    s, h = kops.fused_ar_add_rmsnorm(y_partial, x, g_param, axis=axis,
                                     block_rows=block_rows)
    return s, h


def comet_fused(info, *vals, axis: str = "model", n_chunks: int = 4):
    """Replace [a2a_dispatch, expert_ffn, a2a_combine] with a chunked
    pipeline: chunk i's expert GEMM overlaps chunk i+1's dispatch a2a and
    chunk i-1's combine a2a (XLA async collectives + program order)."""
    from ...kernels import ops as kops
    buf = vals[0]                       # (V, C, d) capacity-packed tokens
    p = info.params_of(1)
    w1, w3, w2 = p["w1"], p["w3"], p["w2"]
    V, C, d = buf.shape
    G = n_chunks
    while C % G:
        G //= 2
    G = max(G, 1)
    Cc = C // G
    outs = []
    for i in range(G):
        x_i = lax.slice_in_dim(buf, i * Cc, (i + 1) * Cc, axis=1)
        y_i = col.all_to_all(x_i, axis, split_dim=0, concat_dim=1)
        z_i = kops.grouped_ffn(y_i, w1, w3, w2)
        outs.append(col.all_to_all(z_i, axis, split_dim=1, concat_dim=0))
    return jnp.concatenate(outs, axis=1) if G > 1 else outs[0]


def flux_fused(info, *vals, axis: str = "model", n_chunks: int = 4):
    """Replace [linear, psum] with a row-chunked GEMM+AR pipeline —
    the paper's §5.3.5 negative result: the chunked all-reduces multiply
    the per-collective latency term, which the roofline model surfaces."""
    x = vals[0]
    p = info.params_of(0)
    w = p["w"] if p else vals[1]        # FSDP variant: weight is an input
    B, S, _ = x.shape
    G = n_chunks
    while S % G:
        G //= 2
    G = max(G, 1)
    Sc = S // G
    outs = []
    for i in range(G):
        x_i = lax.slice_in_dim(x, i * Sc, (i + 1) * Sc, axis=1)
        y_i = jnp.einsum("bsd,df->bsf", x_i, w,
                         preferred_element_type=x.dtype)
        outs.append(col.psum(y_i, axis))
    return jnp.concatenate(outs, axis=1) if G > 1 else outs[0]
