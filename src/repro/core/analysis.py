"""Static data-flow & memory analysis — paper Algorithm 1.

``StaticAnalysis(G, M)`` precomputes, per tensor × micro-batch:
  * reference counts / death sites (lifetime management — the JAX analogue
    of GC is dropping the env reference so XLA liveness ends there), and
  * ``prealloc`` flags: tensors produced per-micro-batch but consumed merged
    get a preallocated contiguous buffer; producers write their slice via
    ``dynamic_update_slice`` at production time (zero-copy resharding —
    no ``concatenate`` on the merge path).

The analysis simulates the plan with the *same* resolution rules the
runtime uses (`resolve_read`), so the two can never disagree.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

from .graph import FULL, OpGraph, TensorRef
from .plan import ExecutionPlan, PlanStep

BUF = "buf"  # env-key tag for a prealloc merge buffer


def resolve_read(avail: set, ref: TensorRef, part: int, nparts: int):
    """How to obtain tensor ``ref`` for micro-batch ``part`` given the set
    of currently available parts.  Returns (mode, key_part):
      ('direct', p)   — env[(tid, p)] as-is
      ('slice', FULL) — slice micro-batch out of the FULL value
      ('assemble', _) — read the completed prealloc buffer (as FULL)
    """
    from .graph import VBATCH
    if part != FULL:
        if part in avail:
            return ("direct", part)
        if FULL in avail:
            if ref.batch_dim is None:
                return ("direct", FULL)
            if ref.batch_dim == VBATCH:
                raise KeyError(
                    f"virtual-batch tensor {ref.tid}({ref.name}) cannot be "
                    f"sliced per-micro-batch; its producer must run per-mb")
            return ("slice", FULL)
        raise KeyError(
            f"tensor {ref.tid}({ref.name}) part {part} unavailable; have {avail}")
    if FULL in avail:
        return ("direct", FULL)
    if nparts and avail >= set(range(nparts)):
        if ref.batch_dim is None or ref.batch_dim == VBATCH:
            raise KeyError(
                f"tensor {ref.tid}({ref.name}) has no sliceable batch dim; "
                f"cannot assemble a merged value from micro-batch parts")
        return ("assemble", None)
    raise KeyError(
        f"tensor {ref.tid}({ref.name}) FULL unavailable; have {avail}")


def step_reads(graph: OpGraph, step: PlanStep, nparts: int):
    """External (tid, part) reads of a plan step, in deterministic order."""
    reads = []
    if step.kind == "fused":
        internal = {t for h in step.handles
                    for t in graph.nodes[h.oid].outputs}
        for h in step.handles:
            for t in graph.nodes[h.oid].inputs:
                if t in internal:
                    continue
                part = h.mb if graph.tensors[t].batch_dim is not None else FULL
                if (t, part) not in reads:
                    reads.append((t, part))
        return reads
    h = step.handles[0]
    node = graph.nodes[h.oid]
    part = FULL if step.kind == "merged" else h.mb
    for t in node.inputs:
        p = part if graph.tensors[t].batch_dim is not None else FULL
        reads.append((t, p))
    return reads


def step_writes(graph: OpGraph, step: PlanStep, nparts: int):
    """(tid, part) outputs a plan step produces."""
    writes = []
    if step.kind == "fused":
        internal_consumers: dict[int, set] = {}
        group = {h.oid for h in step.handles}
        for t, cons in graph.consumers.items():
            internal_consumers[t] = set(cons) - group
        out_tids = set(graph.outputs.values())
        for h in step.handles:
            for t in graph.nodes[h.oid].outputs:
                if internal_consumers.get(t) or t in out_tids:
                    p = h.mb if graph.tensors[t].batch_dim is not None else FULL
                    writes.append((t, p))
        return writes
    h = step.handles[0]
    node = graph.nodes[h.oid]
    part = FULL if step.kind == "merged" else h.mb
    for t in node.outputs:
        p = part if graph.tensors[t].batch_dim is not None else FULL
        writes.append((t, p))
    return writes


@dataclasses.dataclass
class AnalysisResult:
    prealloc: set                      # tids needing a merge buffer
    death: dict                        # env key -> last step index using it
    reads: list                        # per step: [(tid, part, mode, key)]
    writes: list                       # per step: [(tid, part)]
    buffer_bytes: int                  # total prealloc buffer footprint
    n_steps: int
    plan_fingerprint: str = ""         # fingerprint of the analyzed plan
    # (tid, part) -> read count, built once; excluded from eq/repr so
    # rehydrated/replaced results stay comparable without it.
    _ref_counts: Optional[collections.Counter] = dataclasses.field(
        default=None, repr=False, compare=False)

    def ref_count(self, key) -> int:
        """Paper Alg.1 line 4 equivalent (for tests/introspection)."""
        if self._ref_counts is None:
            # lazy fallback for results built without the precomputed
            # table (dataclasses.replace, decoded artifacts)
            object.__setattr__(self, "_ref_counts", collections.Counter(
                (t, p) for step_reads_ in self.reads
                for (t, p, _m, _k) in step_reads_))
        return self._ref_counts[key]


def static_analysis(graph: OpGraph, plan: ExecutionPlan) -> AnalysisResult:
    nparts = plan.num_mb if plan.split_sizes else 0

    # pass 1: find prealloc set (tensors consumed at FULL but produced
    # per-part) by walking the plan once.
    prealloc = set()
    avail1 = {t: {FULL} for t in graph.inputs.values()}
    all_reads, all_writes = [], []
    for step in plan.steps:
        rs = []
        for (t, p) in step_reads(graph, step, nparts):
            mode, key = resolve_read(avail1.get(t, set()), graph.tensors[t],
                                     p, nparts)
            if mode == "assemble":
                prealloc.add(t)
            rs.append((t, p, mode, key))
        all_reads.append(rs)
        ws = step_writes(graph, step, nparts)
        all_writes.append(ws)
        for (t, p) in ws:
            avail1.setdefault(t, set()).add(p)
    # outputs are consumed at FULL by the virtual final step
    final_reads = []
    for _name, t in graph.outputs.items():
        mode, key = resolve_read(avail1.get(t, set()), graph.tensors[t],
                                 FULL, nparts)
        if mode == "assemble":
            prealloc.add(t)
        final_reads.append((t, FULL, mode, key))
    all_reads.append(final_reads)

    # pass 2: death sites.  Key space: (tid, part) values and (tid, BUF).
    death: dict = {}
    for i, rs in enumerate(all_reads):
        for (t, _p, mode, key) in rs:
            if mode == "direct":
                death[(t, key)] = i
            elif mode == "slice":
                death[(t, FULL)] = i
            elif mode == "assemble":
                death[(t, BUF)] = i
    # producers whose value is never read die at production; buffer writes
    # keep the per-part value alive only through the dus.
    for i, ws in enumerate(all_writes):
        for (t, p) in ws:
            death.setdefault((t, p), i)
            if t in prealloc and p != FULL:
                death.setdefault((t, BUF), i)

    buffer_bytes = sum(graph.tensors[t].nbytes for t in prealloc)
    ref_counts = collections.Counter(
        (t, p) for rs in all_reads for (t, p, _m, _k) in rs)
    return AnalysisResult(prealloc, death, all_reads, all_writes,
                          buffer_bytes, len(plan.steps),
                          plan_fingerprint=plan.fingerprint(),
                          _ref_counts=ref_counts)
