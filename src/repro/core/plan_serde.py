"""Persistent PlanStore format — canonical lowerings as on-disk artifacts.

The PlanStore amortizes lowering cost *within* a process; this module
makes the artifact outlive it.  A lowered plan is mostly pure data —
instruction tuples, slot maps, liveness, interned param paths, merge-pad
metadata — plus two things that must never touch disk: the op callables
(``Instr.fn`` / ``PlanStep.replace_fn``) and the captured jaxprs.  We
therefore serialize a **skeleton**: everything ``specialize()`` relies
on, with callables dropped.  ``rehydrate()`` rebinds them from the
caller's live ``(graph, plan)`` at load time — which is safe exactly
when the fingerprint-v2 outer key matches, because that key covers the
structural identity *and* the op-closure config the callables were
traced with.  Jaxpr captures are rebuilt lazily on the first replayed
call, never unpickled.

File format (text, line-oriented, deterministic):

  line 1   JSON header::

      {"magic": "dynaflow-planstore", "format_version": F,
       "fingerprint_version": 2, "entries": N, "one_shot": [...]}

  lines 2+ one outer entry per line::

      E <format_version> <fp2-digest> <sha256[:16] of payload> <payload>

  ``payload`` is compact JSON over a pure-primitive dict (str, int,
  float, bool, None, with tuples as arrays and bytes as
  ``{"__bytes__": base64}`` tags) — no pickle, no code execution, and
  C-speed parsing on the restore path (``ast.literal_eval`` measured
  ~30x slower on real entries, which would eat the warm-start win).
  Entries are addressed by the fingerprint-v2 *digest*; one payload
  holds the salt cross-check, the bucket-invariant analysis, and the
  persisted shape bucket records (the canonical lowering — derived
  buckets are re-specialized, not stored).

Guarantees:

  * **atomicity** — ``write_store`` writes a tempfile in the target
    directory and ``os.replace``s it over the destination; readers
    never observe a torn file,
  * **determinism** — entries and buckets are emitted in sorted-digest
    order with no timestamps, so identical stores produce identical
    bytes (CI can cache on content),
  * **graceful rejection** — a corrupt or version-mismatched header
    fails the whole load (``RestoreError``); a corrupt entry line fails
    only that entry.  Callers fall back to a cold ``lower`` either way.
"""
from __future__ import annotations

import base64
import hashlib
import json
import os
import tempfile
from typing import Iterable, Optional

import numpy as np

from .analysis import AnalysisResult
from .lowering import Instr, LoweredPlan

MAGIC = "dynaflow-planstore"
FORMAT_VERSION = 1
# Version of the "V" (tuning verdict) record schema.  Independent of the
# entry FORMAT_VERSION: verdicts are an additive record kind (PR 8) —
# older readers reject unknown "V ..." lines per-line (restore_rejected)
# and keep restoring plan entries, so artifacts stay forward-shareable.
VERDICT_VERSION = 1


class RestoreError(ValueError):
    """File or entry cannot be restored — caller falls back to cold lower."""


# ---------------------------------------------------------------------------
# primitive-tuple <-> JSON bijection
# ---------------------------------------------------------------------------
# The key/instruction world is tuples over (str, int, float, bool, bytes,
# None).  JSON arrays stand in for tuples (no bare lists exist in any
# payload), bytes are base64-tagged; everything else maps natively.
#
# Decoding is deliberately *shallow*: ``parse_payload`` runs C-speed
# ``json.loads`` and leaves arrays as lists — a full Python tuple-walk
# measured ~10x the json cost on real entries, most of which the restore
# path never needs as tuples.  ``deep_tuple`` converts exactly the spots
# where tuple-ness is semantic: dict keys (outer/bucket keys, death
# sites, param paths) and values handed to jax primitives.


def _to_jsonable(obj):
    if isinstance(obj, (tuple, list)):
        return [_to_jsonable(x) for x in obj]
    if isinstance(obj, dict):
        return {k: _to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, bytes):
        return {"__bytes__": base64.b64encode(obj).decode("ascii")}
    if isinstance(obj, np.generic):
        # numpy scalars (e.g. split sizes off an int64 computation)
        # compare equal to their Python values, so demoting them keeps
        # round-tripped keys matching live ones
        return obj.item()
    return obj


def deep_tuple(obj):
    """Recursively convert decoded JSON (lists, bytes tags) to the
    hashable tuple world keys live in."""
    t = type(obj)
    if t is list:
        return tuple([deep_tuple(x) for x in obj])
    if t is dict:
        if len(obj) == 1 and "__bytes__" in obj:
            try:
                return base64.b64decode(obj["__bytes__"])
            except (ValueError, TypeError) as e:
                raise RestoreError(f"bad bytes tag: {e}") from None
        return {k: deep_tuple(v) for k, v in obj.items()}
    return obj


# ---------------------------------------------------------------------------
# key helpers
# ---------------------------------------------------------------------------


_DIGEST_MEMO: dict = {}


def key_digest(key) -> str:
    """Stable printable digest of a raw (repr-able) key tuple.

    Memoized: digesting is pure, and the repr of a structural outer key
    costs ~40us — paid once per key per process instead of once per
    store lookup (hashing the tuple itself is C-speed)."""
    d = _DIGEST_MEMO.get(key)
    if d is None:
        if len(_DIGEST_MEMO) > 4096:
            _DIGEST_MEMO.clear()
        d = _DIGEST_MEMO[key] = hashlib.sha256(
            repr(key).encode()).hexdigest()[:16]
    return d


def persistable_key(key) -> bool:
    """True when ``key`` round-trips through the JSON encoding *and*
    stays meaningful in another process.

    ``fused_fn_identity`` falls back to ``("id", id(fn))`` for opaque
    closures — a process-local identity that would never match after a
    restart, so entries carrying one are excluded from the artifact.
    """
    if isinstance(key, tuple):
        if len(key) == 2 and key[0] == "id" and isinstance(key[1], int):
            return False
        return all(persistable_key(k) for k in key)
    return isinstance(key, (str, int, float, bool, bytes, type(None)))


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------


def encode_analysis(ana: AnalysisResult) -> dict:
    """Bucket-invariant analysis parts (per-bucket fields are stored with
    each bucket record: ``plan_fp``; ``buffer_bytes`` is re-derived from
    the live graph at rehydration, exactly as ``specialize`` does)."""
    return {
        "prealloc": tuple(sorted(ana.prealloc)),
        # (key, value) pairs: death keys are (tid, part) tuples, which
        # JSON objects cannot key on
        "death": tuple(sorted(ana.death.items(), key=repr)),
        "reads": tuple(tuple(tuple(r) for r in step) for step in ana.reads),
        "writes": tuple(tuple(tuple(w) for w in step)
                        for step in ana.writes),
        "n_steps": ana.n_steps,
    }


def _encode_instr(ins: Instr) -> tuple:
    writes = []
    for slot, buf in ins.writes:
        if buf is not None:
            bslot, start, pad_cfg, pad0 = buf
            buf = (bslot, start, pad_cfg,
                   np.dtype(pad0.dtype).name if pad_cfg is not None
                   else None)
        writes.append((slot, buf))
    return (ins.reads, tuple(writes), ins.frees, bool(ins.fused),
            ins.param_ix, ins.member_pairs, ins.fused_pairs,
            ins.ext_inputs, ins.ext_outputs, ins.label)


def encode_lowered(bucket, lowered: LoweredPlan) -> dict:
    """One shape bucket of an outer entry.  ``Instr.fn`` / ``.step`` and
    the jaxpr replay cache are dropped; stats keep only scalars (capture
    counters are per-process and reset on restore)."""
    stats = {k: v for k, v in lowered.stats.items()
             if isinstance(v, (int, float, str))
             and k not in ("captures", "replays")}
    return {
        "bucket": bucket,
        "plan_fp": lowered.fingerprint,
        "split_sizes": tuple(lowered.split_sizes),
        "capture": bool(lowered.capture),
        "n_slots": lowered.n_slots,
        "input_slots": lowered.input_slots,
        "output_slots": lowered.output_slots,
        "param_paths": lowered.param_paths,
        "instrs": tuple(_encode_instr(i) for i in lowered.instrs),
        "stats": stats,
    }


def entry_line(outer, analysis: dict, canonical, buckets: Iterable[dict],
               fp2: Optional[str] = None) -> str:
    """One outer entry.  The full outer key is NOT serialized — entries
    are addressed by its digest (the fp2 field), which keeps the
    payload ~40% smaller and the restore path off a large decode; only
    the human-auditable ``salt`` component is embedded as a cross-check.
    A digest collision is caught downstream: ``rehydrate`` verifies the
    live plan fingerprint before an entry ever serves."""
    payload = json.dumps(
        _to_jsonable({"salt": outer[1] if len(outer) > 1 else "",
                      "analysis": analysis, "canonical": canonical,
                      "buckets": tuple(buckets)}),
        sort_keys=True, separators=(",", ":"))
    check = hashlib.sha256(payload.encode()).hexdigest()[:16]
    return f"E {FORMAT_VERSION} {fp2 or key_digest(outer)} {check} {payload}"


# ---------------------------------------------------------------------------
# verdict records (autotuner decisions)
# ---------------------------------------------------------------------------


def verdict_line(context_fp: str, payload: dict) -> str:
    """One autotuner verdict record::

        V <verdict_version> <context-fp> <sha256[:16] of payload> <payload>

    Addressed by the *context fingerprint* (``core.autotune``), not the
    plan outer key: a verdict decides which strategy a context gets
    before any plan exists.  The payload is the compact-JSON
    ``TuningVerdict.to_payload()`` dict — pure primitives, no pickle."""
    body = json.dumps(_to_jsonable(payload), sort_keys=True,
                      separators=(",", ":"))
    check = hashlib.sha256(body.encode()).hexdigest()[:16]
    return f"V {VERDICT_VERSION} {context_fp} {check} {body}"


def split_verdict_line(line: str) -> tuple:
    """Validate and parse a verdict line -> ``(context_fp, payload_dict)``.
    Raises ``RestoreError`` on a malformed, version-mismatched or
    corrupt record (caller skips it: cold re-tune, never a crash)."""
    parts = line.split(" ", 4)
    if len(parts) != 5 or parts[0] != "V":
        raise RestoreError(f"malformed verdict line: {line[:40]!r}")
    _, ver, fp, check, body = parts
    if ver != str(VERDICT_VERSION):
        raise RestoreError(
            f"verdict version {ver} != {VERDICT_VERSION}")
    if hashlib.sha256(body.encode()).hexdigest()[:16] != check:
        raise RestoreError("verdict checksum mismatch (corrupt payload)")
    try:
        payload = json.loads(body)
    except (ValueError, TypeError) as e:
        raise RestoreError(f"unparseable verdict payload: {e}") from None
    if not isinstance(payload, dict):
        raise RestoreError("verdict payload is not an object")
    return fp, payload


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def split_entry_line(line: str) -> tuple:
    """Cheap validation pass: ``(fp2_digest, payload_str)``.

    Verifies the marker, per-entry format version and checksum without
    paying the JSON parse — full parsing is deferred to first use so
    loading a large store stays O(bytes hashed).
    """
    parts = line.split(" ", 4)
    if len(parts) != 5 or parts[0] != "E":
        raise RestoreError(f"malformed entry line: {line[:40]!r}")
    _, ver, fp2, check, payload = parts
    if ver != str(FORMAT_VERSION):
        raise RestoreError(f"entry format version {ver} != {FORMAT_VERSION}")
    if hashlib.sha256(payload.encode()).hexdigest()[:16] != check:
        raise RestoreError("entry checksum mismatch (corrupt payload)")
    return fp2, payload


def parse_payload(payload: str) -> dict:
    """Parse an entry payload.  Arrays stay lists (see ``deep_tuple``);
    only the key-bearing fields — ``canonical`` and each bucket
    record's ``bucket`` — are converted to tuples here, so they compare
    and hash against live keys."""
    try:
        obj = json.loads(payload)
    except (ValueError, TypeError, RecursionError) as e:
        raise RestoreError(f"unparseable entry payload: {e}") from None
    if not isinstance(obj, dict) or not {"salt", "analysis", "canonical",
                                         "buckets"} <= set(obj):
        raise RestoreError("entry payload missing required fields")
    try:
        obj["canonical"] = deep_tuple(obj["canonical"])
        for rec in obj["buckets"]:
            rec["bucket"] = deep_tuple(rec["bucket"])
    except (TypeError, KeyError) as e:
        raise RestoreError(f"malformed entry keys: {e}") from None
    return obj


def decode_analysis(rec: dict, graph, plan_fp: str) -> AnalysisResult:
    """Rebuild the bucket-invariant analysis.  ``reads``/``writes`` keep
    their decoded (list) spine as-is — every consumer unpacks or
    iterates them, and the parse owns the objects — while ``death``
    keys are re-tupled (dict keys)."""
    prealloc = set(rec["prealloc"])
    return AnalysisResult(
        prealloc=prealloc,
        death={tuple(k): v for k, v in rec["death"]},
        reads=rec["reads"],
        writes=rec["writes"],
        buffer_bytes=sum(graph.tensors[t].nbytes for t in prealloc),
        n_steps=rec["n_steps"],
        plan_fingerprint=plan_fp)


_PAD0_CACHE: dict = {}


def _pad0(dtype_name: str):
    """Shared zero scalar per dtype (``lax.pad`` never mutates it)."""
    z = _PAD0_CACHE.get(dtype_name)
    if z is None:
        z = _PAD0_CACHE[dtype_name] = np.zeros((), np.dtype(dtype_name))
    return z


def rehydrate(record: dict, analysis_rec: dict, graph, plan,
              struct_key: tuple, bind_fns: bool = True) -> LoweredPlan:
    """Rebuild a servable ``LoweredPlan`` from a bucket record.

    Callables are rebound from the caller's live ``(graph, plan)`` —
    the outer-key match guarantees they are the ones the skeleton was
    lowered against; the plan fingerprint is still cross-checked so a
    key collision degrades to a clean ``RestoreError`` (cold lower),
    never a silent wrong replay.

    ``bind_fns=False`` rebuilds a **canonical skeleton** instead: the
    caller's plan belongs to a *different* shape bucket of the same
    structure, so the fingerprint/split checks are skipped and every
    ``Instr.fn`` is left ``None`` — such a skeleton exists only to feed
    ``specialize()``, which rebinds all callables and rewrites all
    shape-dependent fields, and must never be executed directly.
    """
    # the whole rebuild runs under one RestoreError net: a checksum-valid
    # but schema-malformed record (missing field, wrong arity) must
    # degrade to a cold lower, never crash the serving request
    try:
        steps = plan.steps
        if len(record["instrs"]) != len(steps):
            raise RestoreError(
                f"restored entry has {len(record['instrs'])} instrs, plan "
                f"has {len(steps)} steps")
        plan_fp = record["plan_fp"]
        if bind_fns:
            plan_fp = plan.fingerprint()
            if record["plan_fp"] != plan_fp:
                raise RestoreError(
                    f"restored entry was lowered for plan "
                    f"{record['plan_fp']}, got plan {plan_fp}")
            if tuple(record["split_sizes"]) != tuple(plan.split_sizes):
                raise RestoreError(
                    "restored entry split sizes disagree with plan")
        nodes = graph.nodes
        instrs = []
        # this loop is the whole redeem cost, so it stays allocation-
        # light: reads/frees keep their decoded list spine (only ever
        # unpacked or iterated), tuples are rebuilt only where
        # hashability or a jax primitive demands it, and Instr is
        # materialized via __new__ + __dict__ (the dataclass __init__
        # measured ~3x slower here, same reasoning as ``specialize``'s
        # positional rebuild)
        new_instr = object.__new__
        for enc, step in zip(record["instrs"], steps):
            (reads, writes_e, frees, fused, param_ix, member_pairs,
             fused_pairs, ext_in, ext_out, label) = enc
            writes = []
            for slot, buf in writes_e:
                if buf is not None:
                    bslot, start, pad_cfg, pad_dt = buf
                    if pad_cfg is not None:
                        buf = (bslot, None, tuple(map(tuple, pad_cfg)),
                               _pad0(pad_dt))
                    else:
                        buf = (bslot, tuple(start), None, None)
                writes.append((slot, buf))
            fused = bool(fused)
            if fused != (step.kind == "fused"):
                raise RestoreError(
                    f"restored instr {label!r} fused-ness disagrees with "
                    f"plan step kind {step.kind!r}")
            if not bind_fns:
                fn, live_step = None, None
            elif fused:
                if step.replace_fn is None:
                    raise RestoreError(
                        f"restored fused instr {label!r} has no live "
                        "replacement kernel in the plan")
                fn, live_step = step.replace_fn, step
            else:
                fn, live_step = nodes[step.handles[0].oid].fn, None
            ins = new_instr(Instr)
            ins.__dict__ = {
                "fn": fn, "reads": reads, "writes": writes, "frees": frees,
                "fused": fused, "param_ix": param_ix,
                # param paths key pdicts at execution time: re-tuple
                # (with empty fast paths — most instrs carry neither)
                "member_pairs": None if member_pairs is None else tuple(
                    (tuple(p), ix) for p, ix in member_pairs),
                "fused_pairs": tuple((tuple(p), ix)
                                     for p, ix in fused_pairs)
                if fused_pairs else (),
                "step": live_step,
                "ext_inputs": tuple(map(tuple, ext_in)) if ext_in else (),
                "ext_outputs": tuple(map(tuple, ext_out))
                if ext_out else (),
                "label": label}
            instrs.append(ins)
        analysis = decode_analysis(analysis_rec, graph, plan_fp)
        stats = dict(record["stats"])
        stats["restored"] = stats.get("restored", 0) + 1
        return LoweredPlan(
            graph=graph, split_sizes=tuple(record["split_sizes"]),
            instrs=tuple(instrs), input_slots=tuple(record["input_slots"]),
            output_slots=tuple(record["output_slots"]),
            param_paths=tuple(record["param_paths"]),
            n_slots=record["n_slots"], fingerprint=plan_fp,
            analysis=analysis, capture=bool(record["capture"]),
            struct_key=struct_key, stats=stats)
    except (KeyError, IndexError, TypeError, ValueError,
            AttributeError) as e:
        if isinstance(e, RestoreError):
            raise
        raise RestoreError(f"malformed restored entry: {e}") from None


# ---------------------------------------------------------------------------
# file I/O
# ---------------------------------------------------------------------------


def write_store(path: str, entry_lines: Iterable[str],
                one_shot: Iterable[tuple] = (),
                fingerprint_version: int = 2) -> int:
    """Atomically write a store file; returns the number of entries."""
    lines = sorted(entry_lines, key=lambda s: s.split(" ", 3)[2])
    header = json.dumps(
        {"magic": MAGIC, "format_version": FORMAT_VERSION,
         "fingerprint_version": fingerprint_version,
         "entries": len(lines),
         "one_shot": sorted(list(d) for d in one_shot)},
        sort_keys=True)
    path = os.path.abspath(path)
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(prefix=".planstore-", dir=d)
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            f.write(header + "\n")
            for line in lines:
                f.write(line + "\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return len(lines)


def read_store(path: str, fingerprint_version: int = 2):
    """Validate the header and return ``(one_shot, raw_entry_lines)``.

    Raises ``RestoreError`` for a missing/corrupt/version-mismatched
    file; per-entry problems are left for ``split_entry_line``.
    """
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError as e:
        raise RestoreError(f"cannot read plan store: {e}") from None
    lines = text.splitlines()
    if not lines:
        raise RestoreError("empty plan store file")
    try:
        header = json.loads(lines[0])
    except (ValueError, TypeError) as e:
        raise RestoreError(f"corrupt plan store header: {e}") from None
    if not isinstance(header, dict) or header.get("magic") != MAGIC:
        raise RestoreError("not a plan store file (bad magic)")
    if header.get("format_version") != FORMAT_VERSION:
        raise RestoreError(
            f"plan store format version {header.get('format_version')} "
            f"!= supported {FORMAT_VERSION}")
    if header.get("fingerprint_version") != fingerprint_version:
        raise RestoreError(
            f"plan store fingerprint version "
            f"{header.get('fingerprint_version')} != {fingerprint_version}")
    one_shot = {tuple(d) for d in header.get("one_shot", ())
                if isinstance(d, (list, tuple)) and len(d) == 2}
    return one_shot, [ln for ln in lines[1:] if ln.strip()]
