"""Plan verifier & schedule linter — typed diagnostics over plans and IR.

Programmable scheduling (paper Fig. 6) hands users the rope to hang
themselves: a buggy ``schedule()`` used to die on the first opaque
``RuntimeError`` mid-recording, a mutated lowered plan could execute
with aliased slots, and a restored ``plan_serde`` artifact was trusted
on checksum + fingerprint alone.  This module is the static safety
layer: it checks a ``(graph, ExecutionPlan)`` pair and (optionally) its
lowered instruction IR and reports **every** problem it finds as a
typed, provenance-carrying :class:`Diagnostic` instead of crashing on
the first.

Three analysis layers:

  1. **plan-level data-flow** (:func:`verify_plan`) — read-before-write,
     double/missing execution per micro-batch, merged-step coverage and
     merged-read feasibility, fused-group convexity, dead ops.  Read
     resolution reuses :func:`~repro.core.analysis.resolve_read` — the
     same rules the interpreter, Alg.-1 analysis and lowering use — so
     the verifier and the runtime can never disagree about whether a
     read is satisfiable.
  2. **lowered-IR memory safety** (:func:`verify_lowered`) — a symbolic
     replay of the slot machine against the plan's Alg.-1 analysis:
     use-after-death under liveness-driven slot reuse, writes that
     clobber live values (donation aliasing), premature frees, and
     prealloc merge-buffer hazards (a part written twice, the buffer
     re-created after parts landed, or assembled before every part is
     written).  This is the semantic check behind the PlanStore restore
     path: a persisted artifact whose checksum and fingerprint both pass
     can still carry a stale or tampered instruction stream.
  3. **lint-severity warnings** (:func:`lint_plan`) — scheduling smells
     that run correctly but leave performance behind: two collectives
     scheduled into one overlap window (they serialize on the
     interconnect, per the ``roofline/overlap.py`` model), an exposed
     collective with reorderable independent work available, and
     degenerate split sizes.

Diagnostic codes are stable API (tests and docs key on them); see
``CODES``.  Severity ``"error"`` means the plan would crash or compute
the wrong value; ``"warning"`` means it runs but smells.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

from .analysis import BUF, resolve_read, step_reads, step_writes
from .graph import FULL, VBATCH, OpGraph
from .plan import ExecutionPlan, OpHandle, graph_fingerprint

ERROR = "error"
WARNING = "warning"

#: code -> (severity, one-line description).  Stable: tests, the README
#: table and the lint CLI key on these.
CODES = {
    "VFY001": (ERROR, "unknown op / graph mismatch"),
    "VFY002": (ERROR, "invalid split sizes"),
    "VFY003": (ERROR, "read-before-write (operand unavailable)"),
    "VFY004": (ERROR, "op executed more than once per micro-batch"),
    "VFY005": (ERROR, "op never executed for some micro-batch"),
    "VFY006": (ERROR, "merged step does not cover all micro-batches"),
    "VFY007": (ERROR, "merged read infeasible (no sliceable batch dim)"),
    "VFY008": (ERROR, "fused group not dependency-closed (non-convex)"),
    "VFY009": (WARNING, "dead op: outputs never consumed"),
    "VFY101": (ERROR, "slot read invalid / use-after-death"),
    "VFY102": (ERROR, "write clobbers a live slot (donation aliasing)"),
    "VFY103": (ERROR, "prealloc merge-buffer hazard"),
    "VFY104": (ERROR, "premature free: slot has reads owed"),
    "VFY105": (ERROR, "lowered plan / analysis metadata inconsistent"),
    "VFY201": (WARNING, "resource oversubscription in overlap window"),
    "VFY202": (WARNING, "missed overlap: exposed collective"),
    "VFY203": (WARNING, "degenerate split sizes"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding.  ``step_index`` is the plan step (or instruction)
    the finding anchors to, ``-1`` for plan-wide findings and
    ``n_steps`` for the virtual final-output step; ``op_handles`` carry
    the provenance (op names + micro-batch) of the involved ops."""

    severity: str
    code: str
    step_index: int
    op_handles: tuple
    message: str
    fix_hint: str = ""

    @property
    def ops(self) -> str:
        """Compact ``name[mb]`` provenance string."""
        return ", ".join(
            h.name if h.mb == FULL else f"{h.name}[mb={h.mb}]"
            for h in self.op_handles)

    def __str__(self):
        where = "plan" if self.step_index < 0 else f"step {self.step_index}"
        ops = f" ({self.ops})" if self.op_handles else ""
        hint = f"  hint: {self.fix_hint}" if self.fix_hint else ""
        return (f"[{self.severity.upper()} {self.code}] {where}{ops}: "
                f"{self.message}{hint}")


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """All diagnostics of one verification pass, queryable by severity."""

    diagnostics: tuple = ()

    @property
    def errors(self) -> tuple:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> tuple:
        return tuple(d for d in self.diagnostics if d.severity == WARNING)

    @property
    def ok(self) -> bool:
        """True when no *error*-severity diagnostics exist (warnings are
        advisory and never fail a verification)."""
        return not self.errors

    def raise_if_errors(self, what: str = "plan"):
        if self.errors:
            raise PlanVerificationError(self, what=what)

    def merged(self, other: "VerifyReport") -> "VerifyReport":
        return VerifyReport(self.diagnostics + other.diagnostics)

    def pretty(self) -> str:
        """Human-readable table, one diagnostic per line."""
        if not self.diagnostics:
            return "verification clean: no diagnostics"
        lines = [f"{len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s):"]
        lines.extend(f"  {d}" for d in self.diagnostics)
        return "\n".join(lines)


class PlanVerificationError(RuntimeError):
    """Raised under ``verify="strict"`` when a plan carries error-severity
    diagnostics; ``.report`` holds the full :class:`VerifyReport`."""

    def __init__(self, report: VerifyReport, what: str = "plan"):
        self.report = report
        super().__init__(
            f"{what} failed verification with {len(report.errors)} "
            f"error(s):\n{report.pretty()}")


def format_missing(missing: Sequence[tuple], cap: int = 8) -> str:
    """Render ``[(op_name, missing_parts), ...]`` with the full count and
    an explicit overflow marker — the incomplete-schedule report format
    shared by ``SchedCtx.finalize`` and the VFY005 diagnostics."""
    def one(name, parts):
        ps = sorted(parts, key=lambda p: (p == FULL, p))
        if ps == [FULL]:
            return name
        return f"{name}[mb={','.join(str(p) for p in ps)}]"
    shown = ", ".join(one(n, p) for n, p in missing[:cap])
    more = len(missing) - cap
    if more > 0:
        shown += f" … and {more} more"
    return f"{len(missing)} op(s) missing: {shown}"


def _fmt_key(graph: OpGraph, key) -> str:
    t, p = key
    name = graph.tensors[t].name if t in graph.tensors else "?"
    part = "buf" if p == BUF else ("FULL" if p == FULL else f"mb{p}")
    return f"t{t}({name})/{part}"


# ---------------------------------------------------------------------------
# layer 1: plan-level data-flow
# ---------------------------------------------------------------------------


def verify_plan(graph: OpGraph, plan: ExecutionPlan) -> list:
    """Simulate the plan against the graph, collecting every data-flow
    violation.  A failing step is assumed to have executed anyway so one
    root cause does not cascade into dozens of downstream findings."""
    diags = []
    if plan.graph_fingerprint:
        gfp = graph_fingerprint(graph)
        if plan.graph_fingerprint != gfp:
            diags.append(Diagnostic(
                ERROR, "VFY001", -1, (),
                f"plan was recorded for graph {plan.graph_fingerprint}, "
                f"verifying against graph {gfp}",
                "re-record the plan against this graph"))
    sizes = tuple(plan.split_sizes)
    nparts = len(sizes)
    if any(s <= 0 for s in sizes):
        diags.append(Diagnostic(
            ERROR, "VFY002", -1, (),
            f"split sizes must be positive, got {sizes}",
            "fix the ctx.split() sizes"))
    parts = list(range(nparts)) if nparts else [FULL]
    first_part = 0 if nparts else FULL
    producer = {}
    for oid, n in graph.nodes.items():
        for t in n.outputs:
            producer[t] = oid
    out_tids = set(graph.outputs.values())

    avail: dict = {t: {FULL} for t in graph.inputs.values()}
    done: dict = {}
    for i, step in enumerate(plan.steps):
        known = []
        for h in step.handles:
            if h.oid in graph.nodes:
                known.append(h)
            else:
                diags.append(Diagnostic(
                    ERROR, "VFY001", i, (h,),
                    f"references op {h.name or h.oid!r} (oid {h.oid}) "
                    "which is not in the graph",
                    "the plan belongs to a different graph"))
        if not known:
            continue
        if step.kind == "merged":
            diags.extend(_check_merged(graph, step, known, nparts, i))
        elif step.kind == "fused":
            diags.extend(_check_fused(graph, step, known, producer, i))
        # double execution (same bookkeeping as SchedCtx._record)
        exec_handles = known[:1] if step.kind == "merged" else known
        for h in exec_handles:
            d = done.setdefault(h.oid, set())
            newparts = set(parts) if step.kind == "merged" else {h.mb}
            dup = d & newparts
            if dup:
                diags.append(Diagnostic(
                    ERROR, "VFY004", i, (h,),
                    f"op {h.name!r} executed again (micro-batch(es) "
                    f"{sorted(dup, key=repr)} already done)",
                    "each op runs exactly once per micro-batch"))
            d |= newparts
        # reads through the runtime's own resolution rules
        for (t, p) in step_reads(graph, step, nparts):
            if t not in graph.tensors:
                diags.append(Diagnostic(
                    ERROR, "VFY001", i, tuple(known),
                    f"reads tensor {t} which is not in the graph"))
                continue
            ref = graph.tensors[t]
            a = avail.get(t, set())
            try:
                resolve_read(a, ref, p, nparts)
            except KeyError as e:
                infeasible = (
                    (p != FULL and FULL in a and ref.batch_dim == VBATCH)
                    or (p == FULL and nparts and a >= set(range(nparts))
                        and ref.batch_dim in (None, VBATCH)))
                if infeasible:
                    diags.append(Diagnostic(
                        ERROR, "VFY007", i, tuple(known),
                        str(e).strip("'\""),
                        "merge/split only tensors with a real batch dim"))
                else:
                    diags.append(Diagnostic(
                        ERROR, "VFY003", i, tuple(known),
                        str(e).strip("'\""),
                        "schedule the producer (for every micro-batch) "
                        "before this step"))
        for (t, p) in step_writes(graph, step, nparts):
            avail.setdefault(t, set()).add(p)

    # completeness — the finalize() contract, reported per op
    for oid in graph.topo_order():
        need = set(parts) if graph.splittable(oid) else {first_part}
        d = done.get(oid, set())
        if not (need <= d or FULL in d):
            name = graph.nodes[oid].name
            lack = need - d
            diags.append(Diagnostic(
                ERROR, "VFY005", -1,
                tuple(OpHandle(oid, p, name)
                      for p in sorted(lack, key=repr)),
                format_missing([(name, lack)]),
                "execute every op for every micro-batch (or merged)"))

    # the virtual final step: every graph output is consumed at FULL
    for name, t in graph.outputs.items():
        if t not in graph.tensors:
            continue
        ref = graph.tensors[t]
        a = avail.get(t, set())
        try:
            resolve_read(a, ref, FULL, nparts)
        except KeyError as e:
            infeasible = (nparts and a >= set(range(nparts))
                          and ref.batch_dim in (None, VBATCH))
            diags.append(Diagnostic(
                ERROR, "VFY007" if infeasible else "VFY003",
                len(plan.steps), (),
                f"graph output {name!r}: {str(e).strip(chr(39))}",
                "the output's producer must run (for every micro-batch)"))

    # dead ops: outputs feed neither another op nor a graph output
    for oid, n in graph.nodes.items():
        if n.outputs and all(not graph.consumers.get(t)
                             and t not in out_tids for t in n.outputs):
            diags.append(Diagnostic(
                WARNING, "VFY009", -1, (OpHandle(oid, FULL, n.name),),
                f"op {n.name!r} outputs are never consumed",
                "drop the op from the graph or consume its outputs"))
    return diags


def _check_merged(graph, step, known, nparts, i):
    oids = {h.oid for h in known}
    if len(oids) > 1:
        return [Diagnostic(
            ERROR, "VFY006", i, tuple(known),
            f"merged step mixes {len(oids)} different ops "
            f"({', '.join(sorted(graph.nodes[o].name for o in oids))})",
            "a merged step is one op across all micro-batches")]
    mbs = sorted(h.mb for h in known)
    if mbs != list(range(nparts)) or not nparts:
        return [Diagnostic(
            ERROR, "VFY006", i, tuple(known),
            f"merged execution of {known[0].name!r} covers micro-batches "
            f"{mbs}, plan has {nparts} micro-batch(es)",
            "pass the op's handle for every micro-batch")]
    return []


def _check_fused(graph, step, known, producer, i):
    """Fused-group convexity: an external input produced *downstream* of
    the group's own outputs means some excluded op must run both before
    and after the (atomic) kernel — impossible.  The kernel body itself
    is unverifiable (arbitrary user code); convexity is what static
    analysis can promise."""
    group = {h.oid for h in known}
    group_out = {t for h in known for t in graph.nodes[h.oid].outputs}
    ext_in = {t for h in known for t in graph.nodes[h.oid].inputs} \
        - group_out
    # ops reachable downstream of the group's outputs, excluding members
    reach = set()
    frontier = [c for t in group_out
                for c in graph.consumers.get(t, ()) if c not in group]
    while frontier:
        oid = frontier.pop()
        if oid in reach:
            continue
        reach.add(oid)
        for t in graph.nodes[oid].outputs:
            frontier.extend(c for c in graph.consumers.get(t, ())
                            if c not in reach and c not in group)
    bad = sorted(t for t in ext_in if producer.get(t) in reach)
    if bad:
        names = ", ".join(
            f"t{t}({graph.tensors[t].name})" for t in bad)
        return [Diagnostic(
            ERROR, "VFY008", i, tuple(known),
            f"fused group {step.replace_name!r} is not dependency-closed: "
            f"external input(s) {names} are produced downstream of the "
            "group's own outputs",
            "include the intermediate op in the group or split the "
            "kernel")]
    return []


# ---------------------------------------------------------------------------
# layer 2: lowered-IR memory safety
# ---------------------------------------------------------------------------


def verify_lowered(lowered) -> list:
    """Symbolically replay a ``LoweredPlan``'s slot machine against its
    Alg.-1 analysis: every read must find the key the analysis says it
    needs, every write must not clobber a live value, every free must
    not owe future reads, and prealloc merge buffers must be created
    once, written once per part, and assembled only complete.  Works on
    freshly-lowered, specialized and rehydrated plans alike (the restore
    path's semantic check behind the checksum)."""
    diags = []
    ana = lowered.analysis
    graph = lowered.graph
    n = len(lowered.instrs)
    n_slots = lowered.n_slots
    nmb = len(lowered.split_sizes)

    def meta(i, msg):
        diags.append(Diagnostic(
            ERROR, "VFY105", i, _instr_handles(lowered, i), msg,
            "re-lower the plan; the artifact is stale or corrupt"))

    if ana.n_steps != n or len(ana.writes) != n \
            or len(ana.reads) != n + 1:
        meta(-1, f"lowered plan has {n} instrs; analysis covers "
                 f"{ana.n_steps} steps ({len(ana.reads)} read rows, "
                 f"{len(ana.writes)} write rows)")
        return diags

    contents: dict = {}                # slot -> env key currently held
    for name, slot in lowered.input_slots:
        t = graph.inputs.get(name)
        if t is None or not _slot_ok(slot, n_slots):
            meta(-1, f"input slot map entry ({name!r}, {slot}) is invalid")
            continue
        contents[slot] = (t, FULL)
    death = ana.death
    buf_parts: dict = {}               # tid -> set of parts written
    buf_created: set = set()           # tids whose merge buffer exists

    for i, ins in enumerate(lowered.instrs):
        handles = _instr_handles(lowered, i)
        rs, ws = ana.reads[i], ana.writes[i]
        if len(ins.reads) != len(rs) or len(ins.writes) != len(ws):
            meta(i, f"{ins.label or 'instr'}: {len(ins.reads)} reads / "
                    f"{len(ins.writes)} writes vs analysis "
                    f"{len(rs)} / {len(ws)}")
            continue
        for (slot, sl), r in zip(ins.reads, rs):
            t, p, mode, key = r
            expect = ((t, key) if mode == "direct"
                      else (t, BUF) if mode == "assemble" else (t, FULL))
            if (mode == "slice") != (sl is not None):
                meta(i, f"{ins.label}: read of {_fmt_key(graph, (t, p))} "
                        f"slice spec disagrees with mode {mode!r}")
            if not _slot_ok(slot, n_slots):
                diags.append(Diagnostic(
                    ERROR, "VFY101", i, handles,
                    f"{ins.label} reads invalid slot {slot!r} "
                    f"(plan has {n_slots} slots)"))
                continue
            got = contents.get(slot)
            if got != expect:
                if got is None:
                    msg = (f"{ins.label} reads slot {slot} expecting "
                           f"{_fmt_key(graph, expect)}, but the slot is "
                           "dead (freed or never written) — "
                           "use-after-death")
                else:
                    msg = (f"{ins.label} reads slot {slot} expecting "
                           f"{_fmt_key(graph, expect)}, but it holds "
                           f"{_fmt_key(graph, got)}")
                diags.append(Diagnostic(
                    ERROR, "VFY101", i, handles, msg,
                    "the instruction stream disagrees with liveness; "
                    "re-lower the plan"))
            if mode == "assemble":
                have = buf_parts.get(t, set())
                if nmb and len(have) < nmb:
                    diags.append(Diagnostic(
                        ERROR, "VFY103", i, handles,
                        f"{ins.label} assembles merge buffer of "
                        f"{_fmt_key(graph, (t, FULL))} with only "
                        f"{sorted(have)} of {nmb} part(s) written",
                        "every producer part must run before the "
                        "merged read"))
        for (slot, buf), w in zip(ins.writes, ws):
            t, p = w
            key = (t, p)
            if slot == -1:
                if death.get(key, i) != i:
                    diags.append(Diagnostic(
                        ERROR, "VFY101", i, handles,
                        f"{ins.label} drops {_fmt_key(graph, key)} "
                        f"(slot -1) but it is read again at step "
                        f"{death[key]}"))
            elif not _slot_ok(slot, n_slots):
                diags.append(Diagnostic(
                    ERROR, "VFY101", i, handles,
                    f"{ins.label} writes invalid slot {slot!r}"))
            else:
                got = contents.get(slot)
                if got is not None and got != key \
                        and death.get(got, -1) > i:
                    diags.append(Diagnostic(
                        ERROR, "VFY102", i, handles,
                        f"{ins.label} writes {_fmt_key(graph, key)} into "
                        f"slot {slot}, clobbering live "
                        f"{_fmt_key(graph, got)} (still read at step "
                        f"{death[got]}) — aliasing hazard",
                        "slot reuse must wait for the holder's death "
                        "site"))
                contents[slot] = key
            in_prealloc = t in ana.prealloc and p != FULL
            if in_prealloc and buf is None:
                diags.append(Diagnostic(
                    ERROR, "VFY103", i, handles,
                    f"{ins.label} produces part {p} of merge tensor "
                    f"t{t}({graph.tensors[t].name}) but never writes "
                    "the prealloc buffer",
                    "the merged consumer would read a hole"))
            if buf is not None:
                if not in_prealloc:
                    meta(i, f"{ins.label}: buffer write for "
                            f"{_fmt_key(graph, key)} which the analysis "
                            "does not prealloc")
                    continue
                bslot, _start, pad_cfg, _pad0 = buf
                if pad_cfg is not None:
                    if t in buf_created:
                        diags.append(Diagnostic(
                            ERROR, "VFY103", i, handles,
                            f"{ins.label} re-creates the merge buffer of "
                            f"t{t}({graph.tensors[t].name}), discarding "
                            f"part(s) {sorted(buf_parts.get(t, ()))} "
                            "already written"))
                    buf_created.add(t)
                elif t not in buf_created:
                    diags.append(Diagnostic(
                        ERROR, "VFY103", i, handles,
                        f"{ins.label} updates the merge buffer of "
                        f"t{t}({graph.tensors[t].name}) before any "
                        "producer created it"))
                seen = buf_parts.setdefault(t, set())
                if p in seen:
                    diags.append(Diagnostic(
                        ERROR, "VFY103", i, handles,
                        f"{ins.label} writes part {p} of merge tensor "
                        f"t{t}({graph.tensors[t].name}) twice"))
                seen.add(p)
                if _slot_ok(bslot, n_slots):
                    got = contents.get(bslot)
                    if got is not None and got != (t, BUF) \
                            and death.get(got, -1) > i:
                        diags.append(Diagnostic(
                            ERROR, "VFY102", i, handles,
                            f"{ins.label} merge-buffer write into slot "
                            f"{bslot} clobbers live "
                            f"{_fmt_key(graph, got)}"))
                    contents[bslot] = (t, BUF)
                else:
                    meta(i, f"{ins.label}: invalid merge-buffer slot "
                            f"{bslot!r}")
        for s in ins.frees:
            if not _slot_ok(s, n_slots):
                meta(i, f"{ins.label}: frees invalid slot {s!r}")
                continue
            got = contents.get(s)
            if got is not None and death.get(got, -1) > i:
                diags.append(Diagnostic(
                    ERROR, "VFY104", i, handles,
                    f"{ins.label} frees slot {s} holding "
                    f"{_fmt_key(graph, got)}, which is still read at "
                    f"step {death[got]} — premature free",
                    "frees belong at the key's death site"))
            contents.pop(s, None)

    # the virtual final step: graph outputs must sit in their slots
    for (name, slot), r in zip(lowered.output_slots, ana.reads[-1]):
        t, _p, mode, key = r
        expect = ((t, key) if mode == "direct"
                  else (t, BUF) if mode == "assemble" else (t, FULL))
        if not _slot_ok(slot, n_slots):
            meta(n, f"output slot map entry ({name!r}, {slot}) is invalid")
            continue
        got = contents.get(slot)
        if got != expect:
            diags.append(Diagnostic(
                ERROR, "VFY101", n, (),
                f"graph output {name!r} reads slot {slot} expecting "
                f"{_fmt_key(graph, expect)}, but it holds "
                + ("nothing (dead slot)" if got is None
                   else _fmt_key(graph, got))))
    return diags


def _slot_ok(slot, n_slots) -> bool:
    return isinstance(slot, int) and 0 <= slot < n_slots


def _instr_handles(lowered, i) -> tuple:
    if not (0 <= i < len(lowered.instrs)):
        return ()
    ins = lowered.instrs[i]
    step = getattr(ins, "step", None)
    if step is not None and getattr(step, "handles", None):
        return tuple(step.handles)
    label = getattr(ins, "label", "") or f"instr {i}"
    return (OpHandle(-1, FULL, label),)


# ---------------------------------------------------------------------------
# layer 3: lint-severity schedule smells
# ---------------------------------------------------------------------------


def lint_plan(graph: OpGraph, plan: ExecutionPlan) -> list:
    """Warnings only: the plan is correct but leaves the overlap model's
    wins on the table (mirrors ``roofline/overlap.py``'s window logic —
    a collective overlaps the following transitively-independent steps
    until its first consumer)."""
    diags = []
    sizes = tuple(plan.split_sizes)
    if len(sizes) >= 2 and max(sizes) / max(sum(sizes), 1) >= 0.9:
        diags.append(Diagnostic(
            WARNING, "VFY203", -1, (),
            f"split sizes {sizes} put "
            f"{100 * max(sizes) // sum(sizes)}% of the batch in one "
            "micro-batch; overlap cannot pay",
            "balance the ctx.split() sizes"))
    nparts = len(sizes)
    steps = plan.steps
    reads = [set(t for t, _ in step_reads(graph, s, nparts))
             if all(h.oid in graph.nodes for h in s.handles) else set()
             for s in steps]
    writes = [set(t for t, _ in step_writes(graph, s, nparts))
              if all(h.oid in graph.nodes for h in s.handles) else set()
              for s in steps]
    res = [_step_resource(graph, s) for s in steps]
    for i, step in enumerate(steps):
        if res[i] != "network":
            continue
        tainted = set(writes[i])
        window, contended, exposed_alt = [], [], None
        for j in range(i + 1, len(steps)):
            if reads[j] & tainted:
                tainted |= writes[j]
                if j == i + 1 and exposed_alt is None:
                    # first consumer is immediate: collective exposed
                    exposed_alt = False
                continue
            if not window and j > i + 1 and exposed_alt is False:
                exposed_alt = steps[j]
            window.append(j)
            if res[j] == "network":
                contended.append(j)
            tainted |= writes[j] & tainted  # independent: taint unchanged
        if contended:
            other = steps[contended[0]]
            diags.append(Diagnostic(
                WARNING, "VFY201", i, tuple(step.handles),
                f"collective {step.handles[0].name!r} overlaps "
                f"collective {other.handles[0].name!r} (step "
                f"{contended[0]}) on the same interconnect — they "
                "serialize",
                "interleave compute between the two collectives"))
        if exposed_alt not in (None, False):
            diags.append(Diagnostic(
                WARNING, "VFY202", i, tuple(step.handles),
                f"collective {step.handles[0].name!r} is immediately "
                f"followed by its consumer while independent work "
                f"({exposed_alt.handles[0].name!r}) is available later "
                "in the plan",
                "reorder the independent step into the overlap window"))
    return diags


def _step_resource(graph, step) -> str:
    rs = {graph.nodes[h.oid].resource for h in step.handles
          if h.oid in graph.nodes}
    if "network" in rs:
        return "network"
    return next(iter(rs), "compute")


# ---------------------------------------------------------------------------
# umbrella
# ---------------------------------------------------------------------------


def verify(graph: OpGraph, plan: ExecutionPlan, lowered=None,
           lint: bool = False, mode: str = "report") -> VerifyReport:
    """Run every applicable layer and return one :class:`VerifyReport`.

    ``lowered`` adds the IR memory-safety layer, ``lint=True`` adds the
    warning-severity smells.  ``mode="strict"`` raises
    :class:`PlanVerificationError` when error diagnostics exist;
    ``"report"`` (default) always returns."""
    diags = list(verify_plan(graph, plan))
    if lowered is not None:
        diags.extend(verify_lowered(lowered))
    if lint:
        diags.extend(lint_plan(graph, plan))
    report = VerifyReport(tuple(diags))
    if mode == "strict":
        report.raise_if_errors()
    return report


def enforce(report: VerifyReport, mode: str, what: str = "plan"):
    """Apply a ``verify=`` mode to a report: ``"strict"`` raises on
    errors, ``"warn"`` emits a Python warning, ``"off"``/``"report"`` do
    nothing.  Warnings-severity diagnostics never raise or warn."""
    if mode not in ("off", "report", "warn", "strict"):
        raise ValueError(
            f"unknown verify mode {mode!r}; use 'off', 'warn' or 'strict'")
    if report.ok or mode in ("off", "report"):
        return
    if mode == "strict":
        report.raise_if_errors(what=what)
    else:
        import warnings
        warnings.warn(
            f"{what} failed verification with {len(report.errors)} "
            f"error(s); first: {report.errors[0]}",
            RuntimeWarning, stacklevel=3)


def lint_table(rows: Iterable[tuple], include_clean: bool = False) -> str:
    """Render ``(label, VerifyReport)`` rows as the CLI's diagnostic
    table."""
    out = []
    for label, report in rows:
        if not report.diagnostics and not include_clean:
            continue
        if not report.diagnostics:
            out.append(f"{label:<48} clean")
            continue
        for d in report.diagnostics:
            out.append(f"{label:<48} {d}")
    return "\n".join(out) if out else "all plans clean"


__all__ = [
    "CODES", "Diagnostic", "VerifyReport", "PlanVerificationError",
    "verify", "verify_plan", "verify_lowered", "lint_plan", "enforce",
    "format_missing", "lint_table",
]
