"""Executable + lowered-plan caches — the CUDA-Graph analogue (§3.3.2).

DynaFlow-on-GPU captures one CUDA graph per (subgraph, micro-batch config)
and replays it; here we cache at two levels:

  * ``CompileCache`` — one XLA executable per (plan fingerprint, input
    shapes) bucket.  The runtime dispatcher (serve engine / train loop)
    rounds incoming batches to a bucket and replays the cached executable.
  * ``LoweredPlanCache`` — one ``LoweredPlan`` per plan fingerprint, so
    re-recording the same schedule for a new bucket/segment skips static
    analysis *and* lowering entirely (the plan-to-dispatch hot path).

Both caches are bounded LRU: bucketed serving workloads churn through
(shape, plan) pairs and an unbounded dict grows without limit.  Evictions
are counted in ``stats``.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Any, Callable, Optional

import jax


class CompileCache:
    def __init__(self, capacity: int = 128):
        self.capacity = capacity
        self._cache: OrderedDict = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "compile_s": 0.0, "trace_s": 0.0}

    def key_for(self, plan_fp: str, inputs: dict) -> tuple:
        shapes = tuple(sorted(
            (k, tuple(v.shape), str(getattr(v, "dtype", type(v))))
            for k, v in inputs.items()))
        return (plan_fp, shapes)

    def get_or_build(self, key, build: Callable[[], Callable],
                     example_args: Optional[tuple] = None):
        if key in self._cache:
            self.stats["hits"] += 1
            self._cache.move_to_end(key)
            return self._cache[key]
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        fn = build()
        self.stats["trace_s"] += time.perf_counter() - t0
        if example_args is not None:
            t0 = time.perf_counter()
            fn = jax.jit(fn).lower(*example_args).compile()
            self.stats["compile_s"] += time.perf_counter() - t0
        self._cache[key] = fn
        self._evict()
        return fn

    def _evict(self):
        while len(self._cache) > self.capacity:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1

    def __len__(self):
        return len(self._cache)


class LoweredPlanCache:
    """LRU of ``LoweredPlan``s keyed by plan fingerprint.

    The fingerprint covers graph structure, split sizes and every step
    (including fused-kernel names), so structurally identical plans from
    different trace runs share one lowered artifact.

    The fingerprint does not see *inside* op callables, so callers that
    build structurally identical graphs with different kernel choices must
    disambiguate via ``salt`` (``build_forward`` salts with arch, phase
    and scheduler class).
    """

    def __init__(self, capacity: int = 256):
        self.capacity = capacity
        self._cache: OrderedDict = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "lower_s": 0.0}

    def get_or_lower(self, graph, plan, analysis=None, salt="",
                     capture=True):
        from .lowering import lower
        key = (plan.fingerprint(), salt, capture)
        if key in self._cache:
            self.stats["hits"] += 1
            self._cache.move_to_end(key)
            return self._cache[key]
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        lowered = lower(graph, plan, analysis, capture=capture)
        self.stats["lower_s"] += time.perf_counter() - t0
        self._cache[key] = lowered
        while len(self._cache) > self.capacity:
            self._cache.popitem(last=False)
            self.stats["evictions"] += 1
        return lowered

    def __len__(self):
        return len(self._cache)


GLOBAL_CACHE = CompileCache()
GLOBAL_PLAN_CACHE = LoweredPlanCache()
