"""Deprecated shim — the split caches merged into ``core/plan_store.py``.

``CompileCache`` (executables) and ``LoweredPlanCache`` (lowered plans)
were unified into the single two-level ``PlanStore``; see that module for
the fingerprint-v2 / shape-bucket key schema.  These aliases keep old
import sites working: each is a ``PlanStore`` restricted to one level,
with the legacy ``capacity`` constructor argument, ``len()`` scope, and
``stats`` key names (``CompileCache`` mirrors the store's ``exec_*``
counters back onto the old ``hits``/``misses``/``evictions`` keys).
``GLOBAL_CACHE``/``GLOBAL_PLAN_CACHE`` both alias the raw
``GLOBAL_STORE`` — its ``stats`` uses the new split key names and its
``len()`` spans both levels.
"""
from __future__ import annotations

from .plan_store import GLOBAL_STORE, PlanStore


class LoweredPlanCache(PlanStore):
    """Legacy alias: plan level of a ``PlanStore``."""

    def __init__(self, capacity: int = 256):
        super().__init__(plan_capacity=capacity)
        self.capacity = capacity

    def __len__(self):
        return self.n_plans


class CompileCache(PlanStore):
    """Legacy alias: executable level of a ``PlanStore``."""

    def __init__(self, capacity: int = 128):
        super().__init__(exec_capacity=capacity)
        self.capacity = capacity

    def get_or_build(self, key, build, example_args=None):
        out = super().get_or_build(key, build, example_args)
        # legacy contract: exec counters were 'hits'/'misses'/'evictions'
        s = self.stats
        s["hits"] = s["exec_hits"]
        s["misses"] = s["exec_misses"]
        s["evictions"] = s["exec_evictions"]
        return out

    def __len__(self):
        return self.n_execs


GLOBAL_CACHE = GLOBAL_STORE
GLOBAL_PLAN_CACHE = GLOBAL_STORE
