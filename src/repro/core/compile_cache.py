"""Retired module — the split caches live on as deprecation shims in
``core/plan_store.py`` (``CompileCache`` / ``LoweredPlanCache`` warn once
on construction; ``GLOBAL_CACHE``/``GLOBAL_PLAN_CACHE`` alias the raw
``GLOBAL_STORE``).  This file only re-exports them so old import sites
keep resolving."""
from .plan_store import (GLOBAL_CACHE, GLOBAL_PLAN_CACHE,  # noqa: F401
                         CompileCache, LoweredPlanCache)
