"""Executable cache — the CUDA-Graph analogue (paper §3.3.2).

DynaFlow-on-GPU captures one CUDA graph per (subgraph, micro-batch config)
and replays it; here we compile one XLA executable per
(plan fingerprint, input shapes) bucket and dispatch to it at run time.
The runtime dispatcher (serve engine / train loop) rounds incoming batches
to a bucket, asks the scheduler for a plan for that bucket, and reuses the
cached executable — dynamic schedule choice with static-graph performance.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax


class CompileCache:
    def __init__(self):
        self._cache: dict = {}
        self.stats = {"hits": 0, "misses": 0, "compile_s": 0.0,
                      "trace_s": 0.0}

    def key_for(self, plan_fp: str, inputs: dict) -> tuple:
        shapes = tuple(sorted(
            (k, tuple(v.shape), str(getattr(v, "dtype", type(v))))
            for k, v in inputs.items()))
        return (plan_fp, shapes)

    def get_or_build(self, key, build: Callable[[], Callable],
                     example_args: Optional[tuple] = None):
        if key in self._cache:
            self.stats["hits"] += 1
            return self._cache[key]
        self.stats["misses"] += 1
        t0 = time.perf_counter()
        fn = build()
        self.stats["trace_s"] += time.perf_counter() - t0
        if example_args is not None:
            t0 = time.perf_counter()
            fn = jax.jit(fn).lower(*example_args).compile()
            self.stats["compile_s"] += time.perf_counter() - t0
        self._cache[key] = fn
        return fn

    def __len__(self):
        return len(self._cache)


GLOBAL_CACHE = CompileCache()
