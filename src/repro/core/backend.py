"""Plan realization — paper Alg. 1 ``RuntimeExecute`` + backend engine.

``realize`` executes an ``ExecutionPlan`` against real arrays *inside* a
jitted (and usually shard_mapped) step function.  The plan order becomes
the HLO emission order — on TPU this is the physical schedule knob: XLA's
latency-hiding scheduler overlaps async collectives with whatever
independent compute the plan interleaves around them.

Data-flow follows the static analysis verbatim:
  * micro-batch reads of a FULL value  -> static ``lax.slice`` (zero-copy)
  * merged reads of per-part values    -> preallocated contiguous buffer;
    producers wrote slices via ``dynamic_update_slice`` at production
    (no ``concatenate`` anywhere on the merge path)
  * env references are dropped at the precomputed death site, bounding
    XLA liveness (the GC analogue of Alg. 1 ref_count).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp
from jax import lax

from .analysis import BUF, AnalysisResult, static_analysis
from .graph import FULL, OpGraph
from .plan import ExecutionPlan, PlanStep


def _resolve_path(tree, path):
    for k in path:
        if tree is None or k not in tree:
            return None
        tree = tree[k]
    return tree


@dataclasses.dataclass
class FusedCallInfo:
    """Handed to ``replace_func`` so fused kernels know what they replace."""

    step: PlanStep
    graph: OpGraph
    ext_inputs: list          # [(tid, part)]
    ext_outputs: list         # [(tid, part)]
    split_sizes: tuple
    params: dict              # {param_path: subtree}

    def node(self, i: int = 0):
        return self.graph.nodes[self.step.handles[i].oid]

    def params_of(self, i: int = 0):
        n = self.node(i)
        return self.params.get(n.param_paths[0]) if n.param_paths else {}


class Realizer:
    """Executes plans.  One instance per (graph, plan, analysis).

    By default the plan is lowered once to a slot-based instruction
    stream (``core.lowering``) and ``__call__`` replays that; pass
    ``lowered=False`` to run the original step-by-step interpreter
    (kept as the reference semantics for differential testing).
    """

    def __init__(self, graph: OpGraph, plan: ExecutionPlan,
                 analysis: Optional[AnalysisResult] = None,
                 lowered: bool = True, plan_cache=None, plan_salt: str = "",
                 capture: bool = True, op_config=()):
        graph_nodes = graph.nodes
        self.graph = graph
        self.plan = plan
        self.lowered = None
        self._nodes = graph_nodes
        if lowered:
            if plan_cache is not None:
                self.lowered = plan_cache.get_or_lower(
                    graph, plan, analysis, salt=plan_salt, capture=capture,
                    op_config=op_config)
            else:
                from .lowering import lower
                self.lowered = lower(graph, plan, analysis, capture=capture)
            self.analysis = self.lowered.analysis
            return          # interpreter-only state built lazily if needed
        self.analysis = analysis or static_analysis(graph, plan)
        self._build_interp_state()

    def _build_interp_state(self):
        self.offsets = []
        acc = 0
        for s in self.plan.split_sizes:
            self.offsets.append(acc)
            acc += s
        self._deaths_by_step: dict[int, list] = {}
        for key, d in self.analysis.death.items():
            self._deaths_by_step.setdefault(d, []).append(key)

    # -- value plumbing ----------------------------------------------------
    def _read(self, env, t, part, mode, key):
        ref = self.graph.tensors[t]
        if mode == "direct":
            return env[(t, key)]
        if mode == "slice":
            full = env[(t, FULL)]
            bd = ref.batch_dim
            off, sz = self.offsets[part], self.plan.split_sizes[part]
            return lax.slice_in_dim(full, off, off + sz, axis=bd)
        if mode == "assemble":
            return env[(t, BUF)]
        raise AssertionError(mode)

    def _write(self, env, t, part, val):
        ref = self.graph.tensors[t]
        env[(t, part)] = val
        if t in self.analysis.prealloc and part != FULL:
            bkey = (t, BUF)
            if bkey not in env:
                env[bkey] = jnp.zeros(ref.shape, ref.dtype)
            bd = ref.batch_dim
            start = [0] * val.ndim
            start[bd] = self.offsets[part]
            env[bkey] = lax.dynamic_update_slice(env[bkey], val, tuple(start))

    def _node_params(self, node, params):
        if not node.param_paths:
            return {}
        resolved = {p: _resolve_path(params, p) for p in node.param_paths}
        if node.members:
            # coalesced units take {param_path: subtree}, keyed per member
            return resolved
        return resolved[node.param_paths[0]] or {}

    # -- execution -----------------------------------------------------------
    def __call__(self, params, inputs: dict[str, Any]) -> dict[str, Any]:
        if self.lowered is not None:
            return self.lowered(params, inputs)
        return self._interpret(params, inputs)

    def _interpret(self, params, inputs: dict[str, Any]) -> dict[str, Any]:
        if not hasattr(self, "offsets"):
            self._build_interp_state()
        g, plan, ana = self.graph, self.plan, self.analysis
        env: dict = {}
        for name, t in g.inputs.items():
            if name not in inputs:
                raise KeyError(f"missing graph input {name!r}")
            env[(t, FULL)] = inputs[name]
        for i, step in enumerate(plan.steps):
            reads = ana.reads[i]
            vals = [self._read(env, t, p, m, k) for (t, p, m, k) in reads]
            byref = {(t, p): v for (t, p, m, k), v in zip(reads, vals)}
            if step.kind == "fused":
                self._run_fused(env, step, byref, params)
            else:
                h = step.handles[0]
                node = self._nodes[h.oid]
                part = FULL if step.kind == "merged" else h.mb
                args = []
                for t in node.inputs:
                    p = part if g.tensors[t].batch_dim is not None else FULL
                    args.append(byref[(t, p)])
                outs = node.fn(self._node_params(node, params), *args)
                if not isinstance(outs, tuple):
                    outs = (outs,)
                for t, v in zip(node.outputs, outs):
                    p = part if g.tensors[t].batch_dim is not None else FULL
                    self._write(env, t, p, v)
            # GC at the death site (Alg. 1 ref_count reaching zero)
            for key in self._deaths_by_step.get(i, ()):
                env.pop(key, None)
        # final outputs, merged to FULL
        out = {}
        for (t, _p, m, k), name in zip(ana.reads[-1], g.outputs.keys()):
            out[name] = self._read(env, t, FULL, m, k)
        return out

    def _run_fused(self, env, step: PlanStep, byref, params):
        g = self.graph
        internal = {t for h in step.handles for t in g.nodes[h.oid].outputs}
        ext_in, seen = [], set()
        for h in step.handles:
            for t in g.nodes[h.oid].inputs:
                if t in internal:
                    continue
                p = h.mb if g.tensors[t].batch_dim is not None else FULL
                if (t, p) not in seen:
                    seen.add((t, p))
                    ext_in.append((t, p))
        from .analysis import step_writes
        ext_out = step_writes(g, step, len(self.plan.split_sizes))
        pdict = {}
        for h in step.handles:
            n = g.nodes[h.oid]
            for pp in n.param_paths:
                pdict[pp] = _resolve_path(params, pp)
        info = FusedCallInfo(step, g, ext_in, ext_out,
                             self.plan.split_sizes, pdict)
        vals = [byref[key] for key in ext_in]
        outs = step.replace_fn(info, *vals)
        if not isinstance(outs, tuple):
            outs = (outs,)
        if len(outs) != len(ext_out):
            raise ValueError(
                f"fused kernel {step.replace_name} returned {len(outs)} "
                f"outputs; expected {len(ext_out)} ({ext_out})")
        for (t, p), v in zip(ext_out, outs):
            self._write(env, t, p, v)


def realize(graph: OpGraph, plan: ExecutionPlan, params, inputs,
            analysis: Optional[AnalysisResult] = None,
            lowered: bool = True) -> dict:
    """One-shot helper (tests / small models)."""
    return Realizer(graph, plan, analysis, lowered=lowered)(params, inputs)


def sequential_plan(graph: OpGraph) -> ExecutionPlan:
    """Reference plan: topo order, no split (the paper's fallback mode)."""
    from .plan import OpHandle, graph_fingerprint
    steps = [PlanStep("exec", (OpHandle(oid, FULL, graph.nodes[oid].name),))
             for oid in graph.topo_order()]
    return ExecutionPlan(steps, (), graph_fingerprint(graph))
