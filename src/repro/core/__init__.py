"""DynaFlow core — transparent & flexible intra-device parallelism via
programmable operator scheduling (the paper's primary contribution), as a
composable JAX substrate.

Public API:
  Module / Op / Param / FnOp / trace / mark       — frontend capture
  SplitModule / SplitFunc / Mark / partition      — graph partition (Fig. 5)
  OpSchedulerBase / SchedCtx / record_plan        — programmable scheduling (Fig. 6)
  static_analysis / Realizer / realize            — backend (Alg. 1)
  lower / LoweredPlan / specialize                — plan IR + capture/replay
  PlanStore / fingerprint_v2                      — unified plan/exec cache
  sequential_plan                                 — reference fallback
"""
from .graph import FULL, OpGraph, OpNode, TensorRef
from .module import FnOp, Module, Op, Param, mark, trace
from .partition import Mark, SplitEveryOp, SplitFunc, SplitModule, partition
from .plan import (ExecutionPlan, OpHandle, PlanStep, graph_fingerprint,
                   structural_fingerprint)
from .scheduler import (OpSchedulerBase, SchedCtx, ScheduleContext,
                        record_plan)
from .analysis import AnalysisResult, static_analysis
from .backend import FusedCallInfo, Realizer, realize, sequential_plan
from .lowering import LoweredPlan, LoweringError, lower, specialize
from .plan_store import GLOBAL_STORE, PlanStore, fingerprint_v2
from .compile_cache import (GLOBAL_CACHE, GLOBAL_PLAN_CACHE, CompileCache,
                            LoweredPlanCache)

__all__ = [
    "FULL", "OpGraph", "OpNode", "TensorRef",
    "FnOp", "Module", "Op", "Param", "mark", "trace",
    "Mark", "SplitEveryOp", "SplitFunc", "SplitModule", "partition",
    "ExecutionPlan", "OpHandle", "PlanStep", "graph_fingerprint",
    "structural_fingerprint",
    "OpSchedulerBase", "SchedCtx", "ScheduleContext", "record_plan",
    "AnalysisResult", "static_analysis",
    "FusedCallInfo", "Realizer", "realize", "sequential_plan",
    "LoweredPlan", "LoweringError", "lower", "specialize",
    "GLOBAL_STORE", "PlanStore", "fingerprint_v2",
    "GLOBAL_CACHE", "GLOBAL_PLAN_CACHE", "CompileCache", "LoweredPlanCache",
]
