"""DynaFlow core — transparent & flexible intra-device parallelism via
programmable operator scheduling (the paper's primary contribution), as a
composable JAX substrate.

Public API:
  Module / Op / Param / FnOp / trace / mark       — frontend capture
  SplitModule / SplitFunc / Mark / partition      — graph partition (Fig. 5)
  OpSchedulerBase / SchedCtx / record_plan        — programmable scheduling (Fig. 6)
  StrategyPolicy / by_phase / by_token_threshold
  / first_viable / when                           — per-context strategy policies
  static_analysis / Realizer / realize            — backend (Alg. 1)
  lower / LoweredPlan / specialize                — plan IR + capture/replay
  PlanStore / fingerprint_v2 / strategy_salt      — unified plan/exec cache
  RestoreError / FINGERPRINT_VERSION              — persisted-store contract
  sequential_plan                                 — reference fallback
"""
from .analysis import AnalysisResult, static_analysis
from .backend import FusedCallInfo, Realizer, realize, sequential_plan
from .graph import FULL, OpGraph, OpNode, TensorRef
from .lowering import LoweredPlan, LoweringError, lower, specialize
from .module import FnOp, Module, Op, Param, mark, trace
from .partition import Mark, SplitEveryOp, SplitFunc, SplitModule, partition
from .plan import (FINGERPRINT_VERSION, ExecutionPlan, OpHandle, PlanStep,
                   graph_fingerprint, scheduler_identity, strategy_salt,
                   structural_fingerprint)
from .plan_serde import FORMAT_VERSION, RestoreError
from .plan_store import (GLOBAL_CACHE, GLOBAL_PLAN_CACHE, GLOBAL_STORE,
                         CompileCache, LoweredPlanCache, PlanStore,
                         fingerprint_v2)
from .policy import (StrategyPolicy, as_policy, by_phase,
                     by_token_threshold, first_viable, has_ops,
                     local_batch_below, phase_is, resolve_strategy,
                     tokens_of, when)
from .scheduler import (OpSchedulerBase, SchedCtx, ScheduleContext,
                        ScheduleError, record_plan)
from .verify import (CODES, Diagnostic, PlanVerificationError,
                     VerifyReport, lint_plan, verify, verify_lowered,
                     verify_plan)

__all__ = [
    "FULL", "OpGraph", "OpNode", "TensorRef",
    "FnOp", "Module", "Op", "Param", "mark", "trace",
    "Mark", "SplitEveryOp", "SplitFunc", "SplitModule", "partition",
    "ExecutionPlan", "OpHandle", "PlanStep", "graph_fingerprint",
    "structural_fingerprint", "FINGERPRINT_VERSION",
    "OpSchedulerBase", "SchedCtx", "ScheduleContext", "ScheduleError",
    "record_plan",
    "CODES", "Diagnostic", "PlanVerificationError", "VerifyReport",
    "lint_plan", "verify", "verify_lowered", "verify_plan",
    "StrategyPolicy", "as_policy", "by_phase", "by_token_threshold",
    "first_viable", "when", "has_ops", "local_batch_below", "phase_is",
    "resolve_strategy", "tokens_of",
    "scheduler_identity", "strategy_salt",
    "AnalysisResult", "static_analysis",
    "FusedCallInfo", "Realizer", "realize", "sequential_plan",
    "LoweredPlan", "LoweringError", "lower", "specialize",
    "GLOBAL_STORE", "PlanStore", "fingerprint_v2",
    "FORMAT_VERSION", "RestoreError",
    "GLOBAL_CACHE", "GLOBAL_PLAN_CACHE", "CompileCache", "LoweredPlanCache",
]
