"""Execution plans — the physical schedule produced by a scheduler.

A plan is the JAX analogue of the stream of ``execute()`` dispatches the
paper's backend consumes: an ordered list of steps, each executing one op
for one micro-batch, a merged execution of one op across all micro-batches,
or a fused group replaced by a custom kernel (``replace_func``).

Plans are recorded once per (graph, context) and then realized inside a
jitted step — the analogue of capturing CUDA graphs per micro-batch and
replaying them.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import weakref
from typing import Callable, Optional

from .graph import FULL, OpGraph

# Version of the structural-key / outer-key schema ("fingerprint v2").
# Bump whenever ``structural_key`` / ``fused_fn_identity`` / ``outer_key``
# / ``strategy_salt`` change shape: persisted PlanStore files embed it and
# refuse to restore across versions (core/plan_serde.py), and CI keys its
# warm-start cache on it so stale artifacts are never replayed.
# v3: the strategy salt became a digest of the full scheduler/policy
# identity (class + config + combinator tree) instead of a bare class
# name, so entries persisted under v2 salts can never be redeemed.
FINGERPRINT_VERSION = 3


@dataclasses.dataclass(frozen=True)
class OpHandle:
    """An (operator, micro-batch) instance; ``mb == FULL`` when unsplit."""

    oid: int
    mb: int
    name: str = ""

    def __repr__(self):
        tag = "*" if self.mb == FULL else str(self.mb)
        return f"<{self.name}@{tag}>"


@dataclasses.dataclass(frozen=True)
class PlanStep:
    kind: str                       # 'exec' | 'merged' | 'fused'
    handles: tuple[OpHandle, ...]
    replace_name: str = ""          # fingerprint key for fused kernels
    replace_fn: Optional[Callable] = dataclasses.field(
        default=None, compare=False, hash=False)

    def __repr__(self):
        hs = ",".join(repr(h) for h in self.handles)
        extra = f" via {self.replace_name}" if self.replace_name else ""
        return f"{self.kind}({hs}){extra}"


@dataclasses.dataclass
class ExecutionPlan:
    steps: list[PlanStep]
    split_sizes: tuple[int, ...]    # () => no split; local micro-batch sizes
    graph_fingerprint: str = ""

    @property
    def num_mb(self) -> int:
        return len(self.split_sizes) if self.split_sizes else 1

    def fingerprint(self) -> str:
        # memoized, and hashed off one C-repr'd tuple rather than a
        # Python-level __repr__ walk per step: plans are immutable once
        # finalized, and this sits on the PlanStore's per-bucket warm-up
        # path (lower() alone needs it twice — directly and via Alg. 1)
        fp = self.__dict__.get("_fp")
        if fp is not None:
            return fp
        payload = (self.graph_fingerprint, self.split_sizes,
                   tuple((s.kind,
                          tuple((h.oid, h.mb, h.name) for h in s.handles),
                          s.replace_name)
                         for s in self.steps))
        fp = self._fp = hashlib.sha256(
            repr(payload).encode()).hexdigest()[:16]
        return fp

    def pretty(self) -> str:
        lines = [f"split={list(self.split_sizes) or 'off'}"]
        lines += [f"  {i:3d}: {s!r}" for i, s in enumerate(self.steps)]
        return "\n".join(lines)


def graph_fingerprint(graph: OpGraph) -> str:
    h = hashlib.sha256()
    for oid in graph.topo_order():
        n = graph.nodes[oid]
        h.update(f"{n.name}|{n.inputs}|{n.outputs}|{n.resource}".encode())
    for name, t in sorted(graph.inputs.items()):
        ref = graph.tensors[t]
        h.update(f"in:{name}:{ref.shape}:{ref.dtype}".encode())
    return h.hexdigest()[:16]


_PRIM = (str, int, float, bool, bytes, type(None))


def _is_prim(v) -> bool:
    return isinstance(v, _PRIM) or (
        isinstance(v, tuple) and all(isinstance(x, _PRIM) for x in v))


def fused_fn_identity(fn) -> tuple:
    """Stable identity of a fused replacement kernel for the structural
    key.

    ``replace_name`` alone cannot disambiguate two schedulers of the same
    class whose kernels close over different config (e.g.
    ``partial(comet_fused, axis='model')`` vs ``axis='data'``): the step
    streams are identical, so without this the PlanStore would replay the
    first scheduler's lowering — with its closure baked into ``Instr.fn``
    — for the second.  Resolution order:

      * ``functools.partial`` over primitive args/kwargs -> the inner
        fn's identity + those values (stable across builds: sharing
        keeps working, different configs stop aliasing),
      * plain function (no closure)                      -> module +
        qualname,
      * closure over primitive cells                     -> module +
        qualname + cell values,
      * anything opaque                                  -> ``id(fn)``:
        never aliases, at the cost of never sharing (each build's fresh
        closure is its own outer entry; the LRU reclaims them).
    """
    if isinstance(fn, functools.partial):
        kw = tuple(sorted(fn.keywords.items())) if fn.keywords else ()
        if all(_is_prim(v) for v in fn.args) and \
                all(_is_prim(v) for _, v in kw):
            return ("partial", fused_fn_identity(fn.func), fn.args, kw)
        return ("id", id(fn))
    qual = (getattr(fn, "__module__", ""), getattr(fn, "__qualname__", ""))
    closure = getattr(fn, "__closure__", None)
    if not closure:
        if qual[1] and "<locals>" not in qual[1] and \
                qual[1] != "<lambda>":
            return ("fn",) + qual
        return ("id", id(fn))
    cells = []
    for c in closure:
        v = c.cell_contents
        if not _is_prim(v):
            return ("id", id(fn))
        cells.append(v)
    return ("closure",) + qual + (tuple(cells),)


def scheduler_identity(obj) -> tuple:
    """Stable, hashable identity of a scheduler *or* strategy policy.

    The PlanStore's outer key must separate two strategies that record
    structurally different plans only under some contexts — a class name
    alone cannot (``DynamicScheduler(split_tokens=1024)`` vs ``=4096``
    agree on small buckets and diverge on large ones).  Resolution:

      * anything with an ``identity()`` method (``StrategyPolicy``
        combinators, ``DynamicScheduler``) -> that tuple verbatim, so a
        policy's whole combinator tree enters the key;
      * a plain scheduler instance -> class module + qualname + every
        primitive public attribute (the constructor knobs: thresholds,
        split counts, fusion axes).

    Non-primitive attributes (sub-scheduler instances, caches) are
    skipped — composites that matter must implement ``identity()``.
    """
    ident = getattr(obj, "identity", None)
    if callable(ident):
        return ident()
    cls = type(obj)
    attrs = tuple(sorted(
        (k, v) for k, v in vars(obj).items()
        if not k.startswith("_") and _is_prim(v)))
    return ("sched", cls.__module__, cls.__qualname__, attrs)


def strategy_salt(obj) -> str:
    """Strategy identity as a short printable salt for the PlanStore
    outer key (``build_forward`` composes it with arch/phase/segment).

    Two different policies therefore can never alias cached plans, even
    when they resolve to the same scheduler class for some context; the
    same policy reconstructed in a new process produces the same salt,
    so persisted artifacts stay redeemable (provided its predicates are
    named functions or frozen dataclasses, not lambdas — lambdas fall
    back to ``id()`` identity and simply never share)."""
    ident = scheduler_identity(obj)
    digest = hashlib.sha256(repr(ident).encode()).hexdigest()[:12]
    label = getattr(obj, "name", None) or type(obj).__name__
    return f"{label}:{digest}"


def structural_key(graph: OpGraph, plan: ExecutionPlan) -> tuple:
    """Shape-free structural identity of a (graph, plan) pair, as a
    hashable tuple.

    Covers everything ``specialize`` (core/lowering.py) relies on being
    identical between two lowerings — node wiring, param paths, batch-dim
    placement, step kinds/handles, fused-kernel closure identity
    (``fused_fn_identity``), micro-batch *count* — while excluding
    everything it re-derives per shape bucket: tensor shapes, dtypes and
    the concrete split sizes.  Two plans with equal structural keys lower
    to the same slots, liveness and instruction stream; only slice
    offsets and merge-buffer pads differ.

    A raw tuple rather than a digest: this is computed on the PlanStore's
    per-bucket warm-up path, where tuple construction + C-level hashing
    is ~3x cheaper than hashing a serialized form.  ``structural_fingerprint``
    wraps it into a printable digest for logs and docs.

    Memoized per (plan, graph) — a plan is recorded against exactly one
    graph, so store lookups that hit (the steady state) skip the walk;
    the weakref guard re-walks if a different graph object is ever
    passed with the same plan.
    """
    cached = plan.__dict__.get("_skey")
    if cached is not None and cached[0]() is graph:
        return cached[1]
    nodes = tuple(
        (n.name, n.inputs, n.outputs, n.resource, n.param_paths,
         len(n.members))
        for n in (graph.nodes[oid] for oid in graph.topo_order()))
    bds = tuple(sorted((t, r.batch_dim) for t, r in graph.tensors.items()))
    ins = tuple(sorted(graph.inputs.items()))
    outs = tuple(sorted(graph.outputs.items()))
    steps = tuple(
        (s.kind, tuple((h.oid, h.mb) for h in s.handles), s.replace_name,
         fused_fn_identity(s.replace_fn) if s.replace_fn is not None
         else None)
        for s in plan.steps)
    key = (nodes, bds, ins, outs, len(plan.split_sizes), steps)
    plan._skey = (weakref.ref(graph), key)
    return key


def structural_fingerprint(graph: OpGraph, plan: ExecutionPlan) -> str:
    """Printable digest of ``structural_key`` (logs, error messages)."""
    h = hashlib.sha256(repr(structural_key(graph, plan)).encode())
    return h.hexdigest()[:16]
