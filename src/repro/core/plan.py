"""Execution plans — the physical schedule produced by a scheduler.

A plan is the JAX analogue of the stream of ``execute()`` dispatches the
paper's backend consumes: an ordered list of steps, each executing one op
for one micro-batch, a merged execution of one op across all micro-batches,
or a fused group replaced by a custom kernel (``replace_func``).

Plans are recorded once per (graph, context) and then realized inside a
jitted step — the analogue of capturing CUDA graphs per micro-batch and
replaying them.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Optional, Sequence

from .graph import FULL, OpGraph


@dataclasses.dataclass(frozen=True)
class OpHandle:
    """An (operator, micro-batch) instance; ``mb == FULL`` when unsplit."""

    oid: int
    mb: int
    name: str = ""

    def __repr__(self):
        tag = "*" if self.mb == FULL else str(self.mb)
        return f"<{self.name}@{tag}>"


@dataclasses.dataclass(frozen=True)
class PlanStep:
    kind: str                       # 'exec' | 'merged' | 'fused'
    handles: tuple[OpHandle, ...]
    replace_name: str = ""          # fingerprint key for fused kernels
    replace_fn: Optional[Callable] = dataclasses.field(
        default=None, compare=False, hash=False)

    def __repr__(self):
        hs = ",".join(repr(h) for h in self.handles)
        extra = f" via {self.replace_name}" if self.replace_name else ""
        return f"{self.kind}({hs}){extra}"


@dataclasses.dataclass
class ExecutionPlan:
    steps: list[PlanStep]
    split_sizes: tuple[int, ...]    # () => no split; local micro-batch sizes
    graph_fingerprint: str = ""

    @property
    def num_mb(self) -> int:
        return len(self.split_sizes) if self.split_sizes else 1

    def fingerprint(self) -> str:
        h = hashlib.sha256()
        h.update(self.graph_fingerprint.encode())
        h.update(repr(self.split_sizes).encode())
        for s in self.steps:
            h.update(repr(s).encode())
        return h.hexdigest()[:16]

    def pretty(self) -> str:
        lines = [f"split={list(self.split_sizes) or 'off'}"]
        lines += [f"  {i:3d}: {s!r}" for i, s in enumerate(self.steps)]
        return "\n".join(lines)


def graph_fingerprint(graph: OpGraph) -> str:
    h = hashlib.sha256()
    for oid in graph.topo_order():
        n = graph.nodes[oid]
        h.update(f"{n.name}|{n.inputs}|{n.outputs}|{n.resource}".encode())
    for name, t in sorted(graph.inputs.items()):
        ref = graph.tensors[t]
        h.update(f"in:{name}:{ref.shape}:{ref.dtype}".encode())
    return h.hexdigest()[:16]
