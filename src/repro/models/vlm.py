"""Qwen2-VL backbone (M-RoPE dense LM).  The ViT frontend is a STUB per
the assignment: ``vis`` arrives as precomputed patch embeddings already
aligned to the token sequence (zero at pure-text positions) and is added
to the token embedding.  M-RoPE position streams (3, B, S) are a model
input (t/h/w positions computed by the preprocessing stub)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .base import EmbedSegment
from .layers import AddOp, MeshInfo
from .transformer import DenseLM


class VLMEmbedSegment(EmbedSegment):
    """Token embedding + precomputed patch embeddings (stub frontend).

    With SP the patch embeddings arrive sequence-sharded (the launch layer
    shards dim 1 over 'model'), matching the reduce-scattered token path.
    """

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool):
        super().__init__(cfg, mesh, sp)
        self.add_vis = AddOp("add_vis")

    def forward(self, *, ids, vis):
        return {"x": self.add_vis(self.finish(self.emb(ids)), vis)}


class VLM(DenseLM):
    family = "vlm"

    def make_embed(self, phase):
        sp = self.cfg.seq_parallel and phase != "decode"
        if phase == "decode":
            return EmbedSegment(self.cfg, self.mesh, sp)
        return VLMEmbedSegment(self.cfg, self.mesh, sp)

    def batch_inputs(self, phase, B_loc, S, s_max=0):
        out = super().batch_inputs(phase, B_loc, S, s_max)
        if phase != "decode":
            S_loc = self.seq_local(phase, S)
            out["vis"] = (jax.ShapeDtypeStruct(
                (B_loc, S_loc, self.cfg.d_model), jnp.bfloat16), 0)
        return out
