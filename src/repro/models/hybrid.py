"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` SSM layers.

The shared block (per the Zamba2 paper) runs at width 2·d_model on
``concat(hidden, original_embedding)`` and its weights are re-used at every
application (LoRA per-invocation adapters omitted — noted in DESIGN.md).
Each invocation still keeps its own KV cache at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.module import Module, Op
from .base import EmbedSegment, LMBase, LogitsHead, TrainHead
from .layers import (AddOp, AttentionOp, DecodeAttentionOp, HeadLayout,
                     MeshInfo, MLPBlock, OProj, PsumOp, QKVProj, RMSNormOp,
                     RopeOp, ShardedLinear)
from .mamba2 import Mamba2DecodeLayer, Mamba2Layer, ssm_dims


class ConcatOp(Op):
    resource = "memory"

    def __init__(self, name="concat_h_x0"):
        super().__init__()
        self.named(name)

    def kernel(self, p, a, b):
        return jnp.concatenate([a, b], axis=-1)


class SharedAttnBlock(Module):
    """Shared transformer block at width D2 = 2*d_model."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, decode: bool = False):
        super().__init__()
        d2 = 2 * cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.lay = lay
        self.decode = decode
        self.concat = ConcatOp()
        self.ln1 = RMSNormOp(d2, "ln_attn")
        self.qkv = QKVProj(d2, lay, mesh)
        self.rope = RopeOp(cfg.rope, cfg.rope_kwargs())
        self.attn = (DecodeAttentionOp(lay) if decode
                     else AttentionOp(lay, impl=mesh.attn_impl))
        self.oproj = OProj(d2, lay, mesh)
        self.ar1 = PsumOp(name="ar_attn")
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d2, "ln_mlp")
        self.mlp = MLPBlock(d2, cfg.d_ff, mesh, act=cfg.act)
        self.ar2 = PsumOp(name="ar_mlp")
        self.add2 = AddOp("add_mlp")
        self.down = ShardedLinear(d2, cfg.d_model, "down_proj", mesh,
                                  pspec=(("model",), ()))
        self.ar3 = PsumOp(name="ar_down")
        self.add3 = AddOp("add_shared")
        self.named("shared_attn")

    def forward(self, *, x, x0, positions, cache_len=None, k_cache=None,
                v_cache=None):
        h = self.concat(x, x0)
        a = self.ln1(h)
        q, k, v = self.qkv(a)
        q, k = self.rope(q, k, positions)
        out = {}
        if self.decode:
            a, kc, vc = self.attn(q, k, v, k_cache, v_cache, cache_len)
            out["k_cache"], out["v_cache"] = kc, vc
        else:
            a = self.attn(q, k, v)
        a = self.oproj(a)
        a = self.ar1(a)
        h = self.add1(h, a)
        m = self.ln2(h)
        m = self.mlp(m)
        m = self.ar2(m)
        h = self.add2(h, m)
        y = self.down(h)
        y = self.ar3(y)
        out["x"] = self.add3(x, y)
        return out


class HybridEmbed(EmbedSegment):
    def forward(self, *, ids):
        h = self.finish(self.emb(ids))
        return {"x": h, "x0": h}


class HybridLM(LMBase):
    family = "hybrid"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__(cfg, mesh)
        self.layout = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        k = cfg.ssm.attn_every
        self.n_groups = cfg.n_layers // k if k else 0
        self.per_group = k
        self.trailing = cfg.n_layers - self.n_groups * k

    def make_embed(self, phase):
        return HybridEmbed(self.cfg, self.mesh, sp=False)

    def layer_stacks(self, phase):
        cfg, mesh = self.cfg, self.mesh
        decode = phase == "decode"
        mcaches = (("conv_state", "ssm_state") if decode else ())
        stacks = []
        for gi in range(self.n_groups):
            mmod = (Mamba2DecodeLayer(cfg, mesh) if decode
                    else Mamba2Layer(cfg, mesh))
            mopts = {}
            if decode:
                mopts["input_map"] = {
                    "conv_state": f"mamba_g{gi}.conv_state",
                    "ssm_state": f"mamba_g{gi}.ssm_state"}
            stacks.append((f"mamba_g{gi}", mmod, self.per_group,
                           mcaches, mcaches, mopts))
            amod = SharedAttnBlock(cfg, mesh, decode=decode)
            opts = {"uid": f"shared_attn@{gi}"}
            if decode:
                opts["input_map"] = {"k_cache": f"attn{gi}_k_cache",
                                     "v_cache": f"attn{gi}_v_cache"}
                opts["output_map"] = {"k_cache": f"attn{gi}_k_cache",
                                      "v_cache": f"attn{gi}_v_cache"}
            stacks.append(("shared_attn", amod, 1, (), (), opts))
        if self.trailing:
            mmod = (Mamba2DecodeLayer(cfg, mesh) if decode
                    else Mamba2Layer(cfg, mesh))
            mopts = {}
            if decode:
                mopts["input_map"] = {"conv_state": "mamba_tail.conv_state",
                                      "ssm_state": "mamba_tail.ssm_state"}
            stacks.append(("mamba_tail", mmod, self.trailing,
                           mcaches, mcaches, mopts))
        return stacks

    def make_head(self, phase):
        if phase == "train":
            return TrainHead(self.cfg, self.mesh, sp=False)
        return LogitsHead(self.cfg, self.mesh, sp=False,
                          keep_last=(phase != "decode"))

    def cache_specs(self, stack_name, B_loc, s_max):
        cfg = self.cfg
        if stack_name.startswith("mamba"):
            s = cfg.ssm
            _, d_in_loc, _, H_loc, ch_loc = ssm_dims(cfg, self.mesh.tp)
            return {
                "conv_state": jax.ShapeDtypeStruct(
                    (B_loc, s.conv_width - 1, ch_loc), jnp.bfloat16),
                "ssm_state": jax.ShapeDtypeStruct(
                    (B_loc, H_loc, s.state, s.head_dim), jnp.bfloat16),
            }
        lay = self.layout
        sds = jax.ShapeDtypeStruct((B_loc, s_max, lay.kv_local, lay.head_dim),
                                   jnp.bfloat16)
        return {"k_cache": sds, "v_cache": sds}

    def seq_local(self, phase, S):
        return S  # sequence replicated (SSD scan)

    def decode_cache_layout(self):
        out = {}
        for gi in range(self.n_groups):
            out[f"mamba_g{gi}.conv_state"] = (1, -1)
            out[f"mamba_g{gi}.ssm_state"] = (1, -3)
            out[f"attn{gi}_k_cache"] = (0, -2)
            out[f"attn{gi}_v_cache"] = (0, -2)
        if self.trailing:
            out["mamba_tail.conv_state"] = (1, -1)
            out["mamba_tail.ssm_state"] = (1, -3)
        return out

    def decode_cache_env(self, B_loc, s_max):
        """env-key -> ShapeDtypeStruct for all decode caches (launch layer)."""
        out = {}
        m = self.cache_specs("mamba_g0", B_loc, s_max)
        for gi in range(self.n_groups):
            for k, v in m.items():
                out[f"mamba_g{gi}.{k}"] = jax.ShapeDtypeStruct(
                    (self.per_group,) + v.shape, v.dtype)
            a = self.cache_specs("shared_attn", B_loc, s_max)
            out[f"attn{gi}_k_cache"] = a["k_cache"]
            out[f"attn{gi}_v_cache"] = a["v_cache"]
        if self.trailing:
            for k, v in m.items():
                out[f"mamba_tail.{k}"] = jax.ShapeDtypeStruct(
                    (self.trailing,) + v.shape, v.dtype)
        return out
