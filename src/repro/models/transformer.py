"""Dense decoder-only LM (chatglm3 / deepseek-coder / smollm / minitron /
qwen2-vl backbone) over the DynaFlow segment machinery."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .base import (DenseDecodeLayer, DenseDecoderLayer, EmbedSegment, LMBase,
                   LogitsHead, TrainHead)
from .layers import HeadLayout, MeshInfo


class DenseLM(LMBase):
    family = "dense"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__(cfg, mesh)
        self.layout = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)

    def make_embed(self, phase):
        sp = self.cfg.seq_parallel and phase != "decode"
        return EmbedSegment(self.cfg, self.mesh, sp)

    def layer_stacks(self, phase):
        cfg, mesh = self.cfg, self.mesh
        if phase == "decode":
            mod = DenseDecodeLayer(cfg, mesh)
            return [("layers", mod, cfg.n_layers,
                     ("k_cache", "v_cache"), ("k_cache", "v_cache"))]
        sp = cfg.seq_parallel
        mod = DenseDecoderLayer(cfg, mesh, sp, collect_kv=(phase == "prefill"))
        sc_out = ("k", "v") if phase == "prefill" else ()
        return [("layers", mod, cfg.n_layers, (), sc_out)]

    def make_head(self, phase):
        sp = self.cfg.seq_parallel and phase != "decode"
        if phase == "train":
            return TrainHead(self.cfg, self.mesh, sp)
        return LogitsHead(self.cfg, self.mesh, sp,
                          keep_last=(phase != "decode"))

    def cache_specs(self, stack_name, B_loc, s_max):
        lay = self.layout
        sds = jax.ShapeDtypeStruct((B_loc, s_max, lay.kv_local, lay.head_dim),
                                   jnp.bfloat16)
        return {"k_cache": sds, "v_cache": sds}
