from .layers import *  # noqa
