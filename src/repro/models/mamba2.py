"""Mamba2 (SSD — state-space duality) layers; mamba2-2.7b / zamba2 blocks.

TP shards heads/channels over 'model'; the sequence is replicated across
the model axis (an SSD scan is sequential in L, so Megatron-style sequence
partition does not apply — noted in DESIGN.md §Arch-applicability).

Schedulable ops per layer:  norm (memory) → in_proj (compute) →
conv1d (memory) → ssd_scan (compute) → gated norm (memory) →
out_proj (compute) → all-reduce (network).

Decode keeps two caches per layer: conv_state (B, W-1, ch_loc) and
ssm_state (B, H_loc, P, N) — O(1) per token, which is what makes
``long_500k`` runnable for this family.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..core.module import Module, Op
from .layers import (AddOp, make_param, MeshInfo, PsumOp, RMSNormOp,
                     ShardedLinear)


def ssm_dims(cfg: ArchConfig, tp: int):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    assert H % tp == 0, (H, tp)
    H_loc = H // tp
    d_in_loc = H_loc * s.head_dim
    ch_loc = d_in_loc + 2 * s.n_groups * s.state  # conv channels (x,B,C)
    return d_in, d_in_loc, H, H_loc, ch_loc


class SSMInProj(Module):
    """d -> [z, xBC, dt] (column parallel)."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        d_in, d_in_loc, H, H_loc, ch_loc = ssm_dims(cfg, mesh.tp)
        out_loc = d_in_loc + ch_loc + H_loc  # z + xBC + dt
        self.proj = ShardedLinear(cfg.d_model, out_loc, "ssm_in", mesh)
        self.named("in_proj")

    def forward(self, x):
        return self.proj(x)


class Conv1dOp(Op):
    """Causal depthwise conv over [x;B;C] channels (width W, memory-bound)."""

    resource = "memory"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, name="conv1d"):
        super().__init__()
        s = cfg.ssm
        _, self.d_in_loc, _, self.H_loc, self.ch_loc = ssm_dims(cfg, mesh.tp)
        self.W = s.conv_width
        self.cw = make_param((self.ch_loc, s.conv_width), jnp.float32,
                             (("model",), ()), mesh,
                             init=lambda k, sh, dt: jax.random.normal(k, sh, dt) * 0.1)
        self.cb = make_param((self.ch_loc,), jnp.float32, (("model",),), mesh,
                             init=lambda k, sh, dt: jnp.zeros(sh, dt))
        self.named(name)

    def kernel(self, p, zxbcdt):
        # split z / xBC / dt
        z = zxbcdt[..., :self.d_in_loc]
        xbc = zxbcdt[..., self.d_in_loc:self.d_in_loc + self.ch_loc]
        dt = zxbcdt[..., self.d_in_loc + self.ch_loc:]
        B, L, ch = xbc.shape
        xf = xbc.astype(jnp.float32)
        pad = jnp.pad(xf, ((0, 0), (self.W - 1, 0), (0, 0)))
        out = jnp.zeros_like(xf)
        for w in range(self.W):  # width is 4: unrolled taps
            out = out + pad[:, w:w + L, :] * p["cw"][:, w]
        out = jax.nn.silu(out + p["cb"])
        return z, out.astype(zxbcdt.dtype), dt


class SSDScanOp(Op):
    """Chunked SSD (Mamba2) over the full sequence (train/prefill).

    Inputs: xbc (B,L,ch_loc) post-conv, dt (B,L,H_loc).
    Output: y (B,L,d_in_loc).  The Pallas ssd_scan kernel replaces the jnp
    reference on TPU.
    """

    resource = "compute"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, name="ssd_scan",
                 impl="xla"):
        super().__init__()
        self.s = cfg.ssm
        _, self.d_in_loc, _, self.H_loc, self.ch_loc = ssm_dims(cfg, mesh.tp)
        self.impl = impl
        H_loc, P = self.H_loc, self.s.head_dim
        self.A_log = make_param((H_loc,), jnp.float32, (("model",),), mesh,
                                init=lambda k, sh, dt: jnp.log(
                                    jax.random.uniform(k, sh, dt, 1.0, 16.0)))
        self.D = make_param((H_loc,), jnp.float32, (("model",),), mesh,
                            init=lambda k, sh, dt: jnp.ones(sh, dt))
        self.dt_bias = make_param((H_loc,), jnp.float32, (("model",),), mesh,
                                  init=lambda k, sh, dt: jnp.zeros(sh, dt))
        self.named(name)

    def _split(self, xbc):
        s = self.s
        B, L, _ = xbc.shape
        x = xbc[..., :self.d_in_loc]
        Bmat = xbc[..., self.d_in_loc:self.d_in_loc + s.n_groups * s.state]
        Cmat = xbc[..., self.d_in_loc + s.n_groups * s.state:]
        x = x.reshape(B, L, self.H_loc, s.head_dim)
        Bmat = Bmat.reshape(B, L, s.n_groups, s.state)
        Cmat = Cmat.reshape(B, L, s.n_groups, s.state)
        return x, Bmat, Cmat

    def kernel(self, p, xbc, dt):
        if self.impl == "pallas":
            from ..kernels import ops as kops
            x, Bm, Cm = self._split(xbc)
            dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
            A = -jnp.exp(p["A_log"])
            y = kops.ssd_scan(x, dtv, A, Bm, Cm, p["D"], chunk=self.s.chunk)
            return y.reshape(*y.shape[:2], -1).astype(xbc.dtype)
        return self._ref(p, xbc, dt)

    def _ref(self, p, xbc, dt):
        s = self.s
        x, Bm, Cm = self._split(xbc)
        Bsz, L, H, P = x.shape
        N = s.state
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
        A = -jnp.exp(p["A_log"])                                      # (H,)
        Q = min(s.chunk, L)
        assert L % Q == 0, (L, Q)
        nc = L // Q
        xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
        dtc = dtv.reshape(Bsz, nc, Q, H)
        # n_groups==1: broadcast B/C across heads
        Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, s.n_groups, N)
        Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, s.n_groups, N)
        Bc = jnp.repeat(Bc, H // s.n_groups, axis=3)
        Cc = jnp.repeat(Cc, H // s.n_groups, axis=3)
        dA = dtc * A[None, None, None, :]            # (B,nc,Q,H) log-decay
        cum = jnp.cumsum(dA, axis=2)                  # inclusive cumsum
        # intra-chunk: M[i,j] = C_i·B_j * exp(cum_i - cum_j) * dt_j, j <= i
        Lmask = jnp.tril(jnp.ones((Q, Q), bool))
        CB = jnp.einsum("bnihs,bnjhs->bnhij", Cc, Bc)
        cumT = cum.swapaxes(2, 3)                     # (B,nc,H,Q)
        # mask the EXPONENT (not the product): exp of the upper triangle
        # overflows and poisons the backward pass through jnp.where
        expo = cumT[..., :, None] - cumT[..., None, :]
        expo = jnp.where(Lmask[None, None, None], expo, -jnp.inf)
        decay = jnp.exp(expo)                         # (B,nc,H,Q,Q)
        dtT = dtc.swapaxes(2, 3)                      # (B,nc,H,Q)
        M = CB * decay * dtT[..., None, :]
        y_intra = jnp.einsum("bnhij,bnjhp->bnihp", M, xf)
        # chunk states: S_n = sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
        last = cum[:, :, -1:, :]                      # (B,nc,1,H)
        w = jnp.exp(last - cum) * dtc                 # (B,nc,Q,H)
        S = jnp.einsum("bnjh,bnjhs,bnjhp->bnhsp", w, Bc, xf)
        # inter-chunk recurrence over chunks
        gamma = jnp.exp(last[:, :, 0, :])             # (B,nc,H) chunk decay

        def step(h, inp):
            g, Sn = inp
            h_new = h * g[..., None, None] + Sn
            return h_new, h

        gT = jnp.moveaxis(gamma, 1, 0)                # (nc,B,H)
        ST = jnp.moveaxis(S, 1, 0)                    # (nc,B,H,N,P)
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
        _, hprev = lax.scan(step, h0, (gT, ST))       # h before each chunk
        hprev = jnp.moveaxis(hprev, 0, 1)             # (B,nc,H,N,P)
        y_inter = jnp.einsum("bnihs,bnih,bnhsp->bnihp",
                             Cc, jnp.exp(cum), hprev)
        y = y_intra + y_inter + xf * p["D"][None, None, None, :, None]
        return y.reshape(Bsz, L, H * P).astype(xbc.dtype)

    def infer_out(self, in_shapes):
        B, L, _ = in_shapes[0].shape
        return jax.ShapeDtypeStruct((B, L, self.d_in_loc), in_shapes[0].dtype)

    def flops_estimate(self, in_shapes):
        B, L, _ = in_shapes[0].shape
        s = self.s
        return 6.0 * B * L * self.H_loc * s.head_dim * s.state


class GatedNormOp(Op):
    """RMSNorm(y * silu(z)) — Mamba2's gated output norm (memory)."""

    resource = "memory"

    def __init__(self, d_loc, mesh: MeshInfo, name="gated_norm"):
        super().__init__()
        self.g = make_param((d_loc,), jnp.bfloat16, (("model",),), mesh,
                            init=lambda k, s, dt: jnp.ones(s, dt))
        self.named(name)

    def kernel(self, p, y, z):
        v = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
        var = jnp.mean(v * v, axis=-1, keepdims=True)
        return (v * lax.rsqrt(var + 1e-5)).astype(y.dtype) * p["g"]


class Mamba2Layer(Module):
    """Full-sequence Mamba2 block (train/prefill)."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, attn_impl="xla"):
        super().__init__()
        d = cfg.d_model
        _, d_in_loc, _, _, _ = ssm_dims(cfg, mesh.tp)
        self.ln = RMSNormOp(d, "ln_ssm")
        self.inp = SSMInProj(cfg, mesh)
        self.conv = Conv1dOp(cfg, mesh)
        self.ssd = SSDScanOp(cfg, mesh, impl=attn_impl)
        self.gate = GatedNormOp(d_in_loc, mesh)
        self.outp = ShardedLinear(d_in_loc, d, "ssm_out", mesh,
                                  pspec=(("model",), ()))
        self.ar = PsumOp(name="ar_ssm")
        self.add = AddOp("add_ssm")
        self.named("mamba")

    def forward(self, *, x, positions=None):
        h = self.ln(x)
        zxbcdt = self.inp(h)
        z, xbc, dt = self.conv(zxbcdt)
        y = self.ssd(xbc, dt)
        y = self.gate(y, z)
        y = self.outp(y)
        y = self.ar(y)
        return {"x": self.add(x, y)}


class SSDDecodeOp(Op):
    """One-token SSD state update (memory-bound decode step).

    Inputs: xbc (B,1,ch_loc), dt (B,1,H_loc), conv handled upstream;
            ssm_state (B,H_loc,N,P).
    Outputs: y (B,1,d_in_loc), new ssm_state."""

    resource = "memory"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, name="ssd_decode"):
        super().__init__()
        self.s = cfg.ssm
        _, self.d_in_loc, _, self.H_loc, self.ch_loc = ssm_dims(cfg, mesh.tp)
        self.A_log = make_param((self.H_loc,), jnp.float32, (("model",),), mesh,
                                init=lambda k, sh, dt: jnp.log(
                                    jax.random.uniform(k, sh, dt, 1.0, 16.0)))
        self.D = make_param((self.H_loc,), jnp.float32, (("model",),), mesh,
                            init=lambda k, sh, dt: jnp.ones(sh, dt))
        self.dt_bias = make_param((self.H_loc,), jnp.float32, (("model",),),
                                  mesh,
                                  init=lambda k, sh, dt: jnp.zeros(sh, dt))
        self.named(name)

    def kernel(self, p, xbc, dt, state):
        s = self.s
        Bsz = xbc.shape[0]
        H, P, N = self.H_loc, s.head_dim, s.state
        x = xbc[:, 0, :self.d_in_loc].astype(jnp.float32).reshape(Bsz, H, P)
        Bm = xbc[:, 0, self.d_in_loc:self.d_in_loc + s.n_groups * N]
        Cm = xbc[:, 0, self.d_in_loc + s.n_groups * N:]
        Bm = jnp.repeat(Bm.astype(jnp.float32).reshape(Bsz, s.n_groups, N),
                        H // s.n_groups, axis=1)
        Cm = jnp.repeat(Cm.astype(jnp.float32).reshape(Bsz, s.n_groups, N),
                        H // s.n_groups, axis=1)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
        a = jnp.exp(dtv * (-jnp.exp(p["A_log"])))      # (B,H)
        statef = state.astype(jnp.float32)
        new = statef * a[..., None, None] + \
            jnp.einsum("bh,bhs,bhp->bhsp", dtv, Bm, x)
        y = jnp.einsum("bhs,bhsp->bhp", Cm, new) + x * p["D"][None, :, None]
        return (y.reshape(Bsz, 1, H * P).astype(xbc.dtype),
                new.astype(state.dtype))

    def infer_out(self, in_shapes):
        xbc, dt, state = in_shapes
        B = xbc.shape[0]
        return (jax.ShapeDtypeStruct((B, 1, self.d_in_loc), xbc.dtype),
                jax.ShapeDtypeStruct(state.shape, state.dtype))


class ConvDecodeOp(Op):
    """One-token causal conv using the rolling conv_state cache."""

    resource = "memory"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, name="conv_decode"):
        super().__init__()
        s = cfg.ssm
        _, self.d_in_loc, _, self.H_loc, self.ch_loc = ssm_dims(cfg, mesh.tp)
        self.W = s.conv_width
        self.cw = make_param((self.ch_loc, s.conv_width), jnp.float32,
                             (("model",), ()), mesh,
                             init=lambda k, sh, dt: jax.random.normal(k, sh, dt) * 0.1)
        self.cb = make_param((self.ch_loc,), jnp.float32, (("model",),), mesh,
                             init=lambda k, sh, dt: jnp.zeros(sh, dt))
        self.named(name)

    def kernel(self, p, zxbcdt, conv_state):
        # conv_state (B, W-1, ch): previous raw xBC inputs
        z = zxbcdt[..., :self.d_in_loc]
        xbc = zxbcdt[:, 0, self.d_in_loc:self.d_in_loc + self.ch_loc]
        dt = zxbcdt[..., self.d_in_loc + self.ch_loc:]
        window = jnp.concatenate(
            [conv_state.astype(jnp.float32), xbc[:, None].astype(jnp.float32)], 1)
        out = jnp.einsum("bwc,cw->bc", window, p["cw"]) + p["cb"]
        out = jax.nn.silu(out)[:, None]
        new_state = window[:, 1:].astype(conv_state.dtype)
        return z, out.astype(zxbcdt.dtype), dt, new_state

    def infer_out(self, in_shapes):
        zx, cs = in_shapes
        B = zx.shape[0]
        return (jax.ShapeDtypeStruct((B, 1, self.d_in_loc), zx.dtype),
                jax.ShapeDtypeStruct((B, 1, self.ch_loc), zx.dtype),
                jax.ShapeDtypeStruct((B, 1, zx.shape[-1] - self.d_in_loc
                                      - self.ch_loc), zx.dtype),
                jax.ShapeDtypeStruct(cs.shape, cs.dtype))


class Mamba2DecodeLayer(Module):
    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        d = cfg.d_model
        _, d_in_loc, _, _, _ = ssm_dims(cfg, mesh.tp)
        self.ln = RMSNormOp(d, "ln_ssm")
        self.inp = SSMInProj(cfg, mesh)
        self.conv = ConvDecodeOp(cfg, mesh)
        self.ssd = SSDDecodeOp(cfg, mesh)
        self.gate = GatedNormOp(d_in_loc, mesh)
        self.outp = ShardedLinear(d_in_loc, d, "ssm_out", mesh,
                                  pspec=(("model",), ()))
        self.ar = PsumOp(name="ar_ssm")
        self.add = AddOp("add_ssm")
        self.named("mamba")

    def forward(self, *, x, conv_state, ssm_state, positions=None,
                cache_len=None):
        h = self.ln(x)
        zxbcdt = self.inp(h)
        z, xbc, dt, conv_state = self.conv(zxbcdt, conv_state)
        y, ssm_state = self.ssd(xbc, dt, ssm_state)
        y = self.gate(y, z)
        y = self.outp(y)
        y = self.ar(y)
        return {"x": self.add(x, y), "conv_state": conv_state,
                "ssm_state": ssm_state}


from .base import EmbedSegment, LMBase, LogitsHead, TrainHead  # noqa: E402


class Mamba2LM(LMBase):
    family = "ssm"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__(cfg, mesh)

    def make_embed(self, phase):
        return EmbedSegment(self.cfg, self.mesh, sp=False)

    def layer_stacks(self, phase):
        cfg, mesh = self.cfg, self.mesh
        if phase == "decode":
            mod = Mamba2DecodeLayer(cfg, mesh)
            return [("layers", mod, cfg.n_layers,
                     ("conv_state", "ssm_state"), ("conv_state", "ssm_state"))]
        mod = Mamba2Layer(cfg, mesh)
        return [("layers", mod, cfg.n_layers, (), ())]

    def make_head(self, phase):
        if phase == "train":
            return TrainHead(self.cfg, self.mesh, sp=False)
        return LogitsHead(self.cfg, self.mesh, sp=False,
                          keep_last=(phase != "decode"))

    def cache_specs(self, stack_name, B_loc, s_max):
        s = self.cfg.ssm
        _, d_in_loc, _, H_loc, ch_loc = ssm_dims(self.cfg, self.mesh.tp)
        return {
            "conv_state": jax.ShapeDtypeStruct(
                (B_loc, s.conv_width - 1, ch_loc), jnp.bfloat16),
            "ssm_state": jax.ShapeDtypeStruct(
                (B_loc, H_loc, s.state, s.head_dim), jnp.bfloat16),
        }

    def seq_local(self, phase, S):
        return S  # no SP for SSD (sequential scan)
