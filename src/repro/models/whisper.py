"""Whisper-tiny backbone: encoder-decoder transformer.

The conv/audio frontend is a STUB per the assignment: ``frames`` arrive as
precomputed (B, S, d_model) frame embeddings.  Encoder = bidirectional
self-attention stack; decoder = causal self-attention + cross-attention
over the encoder states + MLP.  Positions are sinusoidal (whisper uses
learned decoder positions; sinusoidal keeps params shape-independent —
noted in DESIGN.md).

Decode: self-attn uses a KV cache; cross-attn recomputes K/V from the
(static) encoder states each step — correct and static-shaped; the serve
engine holds ``enc`` and feeds it as a step input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core import trace
from ..core.module import Module, Op
from .base import LMBase, LogitsHead, Segment, TrainHead
from .layers import (AddOp, AttentionOp, DecodeAttentionOp, EmbedOp,
                     HeadLayout, MeshInfo, MLPBlock, OProj, PsumOp, QKVProj,
                     RMSNormOp, ShardedLinear)


def _sinusoid(positions, d):
    """Sinusoidal absolute position encoding: positions (B,S) -> (B,S,d)."""
    half = d // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class SinPosOp(Op):
    """x + sinusoidal(position) (memory-bound)."""

    resource = "memory"

    def __init__(self, name="sinpos"):
        super().__init__()
        self.named(name)

    def kernel(self, p, x, positions):
        return x + _sinusoid(positions, x.shape[-1]).astype(x.dtype)


class EncPosOp(Op):
    """x + sinusoidal(arange(S)) for the encoder (no positions input)."""

    resource = "memory"

    def __init__(self, name="enc_pos"):
        super().__init__()
        self.named(name)

    def kernel(self, p, x):
        B, S, d = x.shape
        pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        return x + _sinusoid(pos, d).astype(x.dtype)


class CrossKVProj(Module):
    """K/V projection of the encoder states for cross-attention."""

    def __init__(self, d, layout: HeadLayout, mesh: MeshInfo, name="cross_kv",
                 dtype=jnp.bfloat16):
        super().__init__()
        lay = layout
        out = 2 * lay.kv_local * lay.head_dim
        self.proj = ShardedLinear(d, out, "kv_proj", mesh, dtype=dtype)
        self.split = _KVSplit(lay).named("kv_split")
        self.named(name)

    def forward(self, enc):
        return self.split(self.proj(enc))


class _KVSplit(Op):
    resource = "memory"

    def __init__(self, lay: HeadLayout):
        super().__init__()
        self.lay = lay

    def kernel(self, p, kv):
        lay = self.lay
        hd = lay.head_dim
        B, S, _ = kv.shape
        nk = lay.kv_local * hd
        k = kv[..., :nk].reshape(B, S, lay.kv_local, hd)
        v = kv[..., nk:].reshape(B, S, lay.kv_local, hd)
        return k, v


class QOnlyProj(Module):
    """Q projection for cross-attention (decoder side)."""

    def __init__(self, d, layout: HeadLayout, mesh: MeshInfo, name="cross_q",
                 dtype=jnp.bfloat16):
        super().__init__()
        lay = layout
        self.lay = lay
        self.proj = ShardedLinear(d, lay.q_local * lay.head_dim, "q_proj",
                                  mesh, dtype=dtype)
        self.split = _QReshape(lay).named("q_reshape")
        self.named(name)

    def forward(self, x):
        return self.split(self.proj(x))


class _QReshape(Op):
    resource = "memory"

    def __init__(self, lay: HeadLayout):
        super().__init__()
        self.lay = lay

    def kernel(self, p, q):
        B, S, _ = q.shape
        return q.reshape(B, S, self.lay.q_local, self.lay.head_dim)


class WhisperEncoderLayer(Module):
    """Bidirectional self-attention + GELU MLP (pre-norm)."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, attn_impl="xla"):
        super().__init__()
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.ln1 = RMSNormOp(d, "ln_attn")
        self.qkv = QKVProj(d, lay, mesh)
        self.attn = AttentionOp(lay, causal=False, impl=mesh.attn_impl)
        self.oproj = OProj(d, lay, mesh)
        self.ar1 = PsumOp(name="ar_attn")
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d, "ln_mlp")
        self.mlp = MLPBlock(d, cfg.d_ff, mesh, act="gelu")
        self.ar2 = PsumOp(name="ar_mlp")
        self.add2 = AddOp("add_mlp")
        self.named("enc_layer")

    def forward(self, *, x):
        h = self.ln1(x)
        q, k, v = self.qkv(h)
        a = self.oproj(self.attn(q, k, v))
        x = self.add1(x, self.ar1(a))
        m = self.mlp(self.ln2(x))
        x = self.add2(x, self.ar2(m))
        return {"x": x}


class WhisperDecoderLayer(Module):
    """Causal self-attn + cross-attn(enc) + GELU MLP (train/prefill)."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, collect_kv=False):
        super().__init__()
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.collect_kv = collect_kv
        self.ln1 = RMSNormOp(d, "ln_self")
        self.qkv = QKVProj(d, lay, mesh)
        self.attn = AttentionOp(lay, causal=True, name="self_attention",
                                impl=mesh.attn_impl)
        self.oproj = OProj(d, lay, mesh)
        self.ar1 = PsumOp(name="ar_self")
        self.add1 = AddOp("add_self")
        self.ln2 = RMSNormOp(d, "ln_cross")
        self.q_proj = QOnlyProj(d, lay, mesh)
        self.kv_proj = CrossKVProj(d, lay, mesh)
        self.xattn = AttentionOp(lay, causal=False, name="cross_attention",
                                 impl=mesh.attn_impl)
        self.xoproj = OProj(d, lay, mesh, name="x_o_proj")
        self.ar2 = PsumOp(name="ar_cross")
        self.add2 = AddOp("add_cross")
        self.ln3 = RMSNormOp(d, "ln_mlp")
        self.mlp = MLPBlock(d, cfg.d_ff, mesh, act="gelu")
        self.ar3 = PsumOp(name="ar_mlp")
        self.add3 = AddOp("add_mlp")
        self.named("dec_layer")

    def forward(self, *, x, enc):
        h = self.ln1(x)
        q, k, v = self.qkv(h)
        a = self.oproj(self.attn(q, k, v))
        x = self.add1(x, self.ar1(a))
        h = self.ln2(x)
        qx = self.q_proj(h)
        kx, vx = self.kv_proj(enc)
        a = self.xoproj(self.xattn(qx, kx, vx))
        x = self.add2(x, self.ar2(a))
        m = self.mlp(self.ln3(x))
        x = self.add3(x, self.ar3(m))
        out = {"x": x}
        if self.collect_kv:
            out["k"], out["v"] = k, v
        return out


class WhisperDecodeLayer(Module):
    """Decode: self-attn against KV cache + cross-attn over static enc."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.ln1 = RMSNormOp(d, "ln_self")
        self.qkv = QKVProj(d, lay, mesh)
        self.attn = DecodeAttentionOp(lay)
        self.oproj = OProj(d, lay, mesh)
        self.ar1 = PsumOp(name="ar_self")
        self.add1 = AddOp("add_self")
        self.ln2 = RMSNormOp(d, "ln_cross")
        self.q_proj = QOnlyProj(d, lay, mesh)
        self.kv_proj = CrossKVProj(d, lay, mesh)
        self.xattn = AttentionOp(lay, causal=False, name="cross_attention",
                                 impl=mesh.attn_impl)
        self.xoproj = OProj(d, lay, mesh, name="x_o_proj")
        self.ar2 = PsumOp(name="ar_cross")
        self.add2 = AddOp("add_cross")
        self.ln3 = RMSNormOp(d, "ln_mlp")
        self.mlp = MLPBlock(d, cfg.d_ff, mesh, act="gelu")
        self.ar3 = PsumOp(name="ar_mlp")
        self.add3 = AddOp("add_mlp")
        self.named("dec_layer")

    def forward(self, *, x, enc, cache_len, k_cache, v_cache):
        h = self.ln1(x)
        q, k, v = self.qkv(h)
        a, kc, vc = self.attn(q, k, v, k_cache, v_cache, cache_len)
        a = self.oproj(a)
        x = self.add1(x, self.ar1(a))
        h = self.ln2(x)
        qx = self.q_proj(h)
        kx, vx = self.kv_proj(enc)
        a = self.xoproj(self.xattn(qx, kx, vx))
        x = self.add2(x, self.ar2(a))
        m = self.mlp(self.ln3(x))
        x = self.add3(x, self.ar3(m))
        return {"x": x, "k_cache": kc, "v_cache": vc}


class WhisperEncEmbed(Module):
    """Stub frontend output -> encoder input (adds sinusoidal positions)."""

    def __init__(self, cfg: ArchConfig):
        super().__init__()
        self.pos = EncPosOp()
        self.named("enc_embed")

    def forward(self, *, frames):
        return {"x": self.pos(frames)}


class WhisperDecEmbed(Module):
    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        self.emb = EmbedOp(cfg.vocab, cfg.d_model, mesh)
        self.finish = PsumOp(name="embed_ar")
        self.pos = SinPosOp()
        self.named("embed")

    def forward(self, *, ids, positions):
        return {"x": self.pos(self.finish(self.emb(ids)), positions)}


class WhisperLM(LMBase):
    family = "encdec"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__(cfg, mesh)
        self.layout = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)

    # -- inputs ---------------------------------------------------------------
    def batch_inputs(self, phase, B_loc, S, s_max=0):
        i32, bf16 = jnp.int32, jnp.bfloat16
        d = self.cfg.d_model
        if phase == "train":
            return {
                "frames": (jax.ShapeDtypeStruct((B_loc, S, d), bf16), 0),
                "ids": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "labels": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "positions": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
            }
        if phase == "prefill":
            return {
                "frames": (jax.ShapeDtypeStruct((B_loc, S, d), bf16), 0),
                "ids": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "positions": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
            }
        return {  # decode: enc states are a step input (held by the engine)
            "ids": (jax.ShapeDtypeStruct((B_loc, 1), i32), 0),
            "positions": (jax.ShapeDtypeStruct((B_loc, 1), i32), 0),
            "cache_len": (jax.ShapeDtypeStruct((B_loc,), i32), 0),
            "enc": (jax.ShapeDtypeStruct((B_loc, s_max, d), bf16), 0),
        }

    def cache_specs(self, stack_name, B_loc, s_max):
        lay = self.layout
        sds = jax.ShapeDtypeStruct((B_loc, s_max, lay.kv_local, lay.head_dim),
                                   jnp.bfloat16)
        return {"k_cache": sds, "v_cache": sds}

    def decode_cache_env(self, B_loc, s_max):
        n = self.cfg.n_layers
        return {k: jax.ShapeDtypeStruct((n,) + v.shape, v.dtype)
                for k, v in self.cache_specs("decoder", B_loc, s_max).items()}

    def decode_cache_layout(self):
        return {"k_cache": (1, -2), "v_cache": (1, -2)}

    # -- segments (override: encoder stack precedes decoder) -------------------
    def build_segments(self, phase, B_loc, S, s_max=0):
        cfg, mesh = self.cfg, self.mesh
        binputs = self.batch_inputs(phase, B_loc, S, s_max)
        bf16 = jnp.bfloat16
        segs = []
        if phase != "decode":
            ee = WhisperEncEmbed(cfg)
            g = trace(ee, {"frames": binputs["frames"][0]},
                      batch_dims={"frames": 0})
            segs.append(Segment("enc_embed", ee, g,
                                output_map={"x": "enc"}))
            enc_mod = WhisperEncoderLayer(cfg, mesh)
            x_enc = jax.ShapeDtypeStruct((B_loc, S, cfg.d_model), bf16)
            g = trace(enc_mod, {"x": x_enc}, batch_dims={"x": 0})
            segs.append(Segment("encoder", enc_mod, g, count=cfg.enc_layers,
                                input_map={"x": "enc"},
                                output_map={"x": "enc"}))
        de = WhisperDecEmbed(cfg, mesh)
        g = trace(de, {"ids": binputs["ids"][0],
                       "positions": binputs["positions"][0]},
                  batch_dims={"ids": 0, "positions": 0})
        segs.append(Segment("embed", de, g))
        S_dec = 1 if phase == "decode" else S
        S_enc = s_max if phase == "decode" else S
        x_sds = jax.ShapeDtypeStruct((B_loc, S_dec, cfg.d_model), bf16)
        enc_sds = jax.ShapeDtypeStruct((B_loc, S_enc, cfg.d_model), bf16)
        if phase == "decode":
            dmod = WhisperDecodeLayer(cfg, mesh)
            lay_in = {"x": x_sds, "enc": enc_sds,
                      "cache_len": binputs["cache_len"][0]}
            lay_in.update(self.cache_specs("decoder", B_loc, s_max))
            bd = {"x": 0, "enc": 0, "cache_len": 0,
                  "k_cache": 0, "v_cache": 0}
            g = trace(dmod, lay_in, batch_dims=bd)
            segs.append(Segment("decoder", dmod, g, count=cfg.n_layers,
                                scan_inputs=("k_cache", "v_cache"),
                                scan_outputs=("k_cache", "v_cache")))
        else:
            dmod = WhisperDecoderLayer(cfg, mesh,
                                       collect_kv=(phase == "prefill"))
            g = trace(dmod, {"x": x_sds, "enc": enc_sds},
                      batch_dims={"x": 0, "enc": 0})
            sc_out = ("k", "v") if phase == "prefill" else ()
            segs.append(Segment("decoder", dmod, g, count=cfg.n_layers,
                                scan_outputs=sc_out))
        head = (TrainHead(cfg, mesh, sp=False) if phase == "train"
                else LogitsHead(cfg, mesh, sp=False,
                                keep_last=(phase != "decode")))
        head_in = {"x": x_sds}
        hbd = {"x": 0}
        if phase == "train":
            head_in["labels"] = binputs["labels"][0]
            hbd["labels"] = 0
        g = trace(head, head_in, batch_dims=hbd)
        segs.append(Segment("head", head, g))
        return segs, binputs
