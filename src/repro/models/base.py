"""Model assembly: segments, scan-over-layers realization, LM base.

A model is a list of *segments* (embed → layer-stack(s) → head).  Each
segment is one traced OpGraph; layer stacks are realized with ``lax.scan``
over stacked params (compact HLO ⇒ tractable 512-device compiles) and the
DynaFlow plan programs the scan *body* — per-layer schedules are periodic,
which is exactly the paper's per-subgraph CUDA-graph reuse, transplanted.

Conventions
  * layer graphs:  inputs {x, positions, ...}, outputs {x, ...}
  * decode graphs: extra inputs  {cache_len, <name>_cache...} scanned per
    layer; matching outputs are collected as the updated cache stack.
  * prefill:       extra outputs (k, v) collected into a new cache stack.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..core import (OpGraph, Realizer, ScheduleContext, partition,
                    record_plan, trace)
from ..core.module import Module
from .layers import (AddOp, AllGatherOp, AttentionOp, DecodeAttentionOp,
                     EmbedOp, HeadLayout, HeadLossOp, LmHeadOp, MeshInfo,
                     MLPBlock, OProj, PsumOp, QKVProj, ReduceScatterOp,
                     RMSNormOp, RopeOp, TakeLastOp)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Segment:
    name: str                      # key into the params tree
    module: Module
    graph: OpGraph
    count: int = 1                 # scan length (stacked params when > 1)
    scan_inputs: tuple = ()        # graph inputs stacked per layer (caches)
    scan_outputs: tuple = ()       # graph outputs collected per layer
    carry: tuple = ("x",)          # outputs fed to the next segment
    input_map: dict = dataclasses.field(default_factory=dict)   # graph->env
    output_map: dict = dataclasses.field(default_factory=dict)  # graph->env
    uid: str = ""                  # unique id when name repeats (shared wts)

    @property
    def key(self):
        return self.uid or self.name

    def collect_key(self, k: str) -> str:
        """env key a collected scan output lands on.  Outputs that are
        also scan *inputs* (decode caches) round-trip onto the same env
        key so the updated cache replaces the stale one."""
        if k in self.output_map:
            return self.output_map[k]
        if self.count > 1 and k in self.scan_inputs:
            return self.input_map.get(k, k)
        return f"{self.key}.{k}" if self.count > 1 else k


@dataclasses.dataclass
class Forward:
    """A realized forward pass over segments with per-segment plans."""

    segments: list
    realizers: dict                # name -> Realizer
    remat: bool = False
    remat_policy: str = "full"     # full | dots | none

    def __call__(self, params, batch: dict) -> dict:
        env = dict(batch)
        collected = {}
        for seg in self.segments:
            rz = self.realizers[seg.key]
            g = seg.graph
            imap = seg.input_map

            def _env(k):
                return env[imap.get(k, k)]

            if seg.count == 1:
                ins = {k: _env(k) for k in g.inputs}
                # merge the global tree under the segment's own subtree so
                # cross-segment share paths (tied embeddings) resolve
                seg_params = dict(params.get(seg.name) or {})
                merged = {**{k: v for k, v in params.items()
                             if k not in seg_params}, **seg_params}
                out = rz(merged, ins)
                env.update({seg.output_map.get(k, k): v
                            for k, v in out.items()})
                continue
            # scan over stacked layer params (+ scanned cache inputs)
            static_ins = {k: _env(k) for k in g.inputs
                          if k not in seg.carry and k not in seg.scan_inputs}
            xs = (params.get(seg.name),
                  {k: _env(k) for k in seg.scan_inputs})

            def body(carry, x, _rz=rz, _g=g, _seg=seg, _static=static_ins):
                layer_params, scanned = x
                ins = dict(_static)
                ins.update(carry)
                ins.update(scanned)
                out = _rz(layer_params, ins)
                new_carry = {k: out[k] for k in _seg.carry}
                ys = {k: out[k] for k in _seg.scan_outputs}
                return new_carry, ys

            if self.remat:
                if self.remat_policy == "dots":
                    pol = jax.checkpoint_policies.checkpoint_dots
                    body = jax.checkpoint(body, policy=pol)
                else:
                    body = jax.checkpoint(body)
            carry0 = {k: env[imap.get(k, k)] for k in seg.carry}
            carry, ys = lax.scan(body, carry0, xs)
            env.update({seg.output_map.get(k, k): v for k, v in carry.items()})
            for k, v in ys.items():
                collected[seg.collect_key(k)] = v
        env.update(collected)
        return env


def build_forward(segments: Sequence[Segment],
                  scheduler,
                  info: ScheduleContext,
                  remat: bool = False,
                  remat_policy: str = "full",
                  lowered: bool = True,
                  plan_cache=None,
                  op_config=(),
                  verify: str = "off",
                  verify_sink: Optional[list] = None) -> Forward:
    """Partition + schedule every segment graph, returning the Forward.

    ``scheduler`` may be an ``OpSchedulerBase``, a ``StrategyPolicy``, or
    a strategy name: a policy is resolved per segment against the
    ScheduleContext (enriched with the segment's traced graph under
    ``extra['graph']`` so graph-conditional predicates can see op names).
    The *policy's* identity — not merely the resolved scheduler's class —
    enters the PlanStore salt, so two policies never alias cached plans.

    ``lowered=True`` (default) compiles each segment plan to the slot-based
    instruction stream.  Pass a ``PlanStore`` as ``plan_cache`` to share
    lowered plans across builds: the store's outer key is fingerprint v2
    (shape-free graph/plan structure + an (arch, phase, strategy-salt,
    segment) key + ``op_config``), the inner key is the shape bucket —
    so rebuilding a known bucket is a hit, and a *new* bucket of a known
    structure specializes the canonical lowering instead of re-running
    static analysis and lowering (the cross-prefill-bucket share path).

    ``op_config`` is the op-closure config (attention impl, shard layout,
    dtype policy — ``LMBase.op_closure_config()``): everything the op
    callables close over that neither the graph structure nor the shapes
    can see.  Pass it whenever one store serves more than one (model,
    mesh) so structurally identical graphs with different kernel or
    sharding choices cannot alias.

    ``verify`` runs the static verifier (``core.verify``) on every
    segment's recorded plan *and* its lowered IR (including plans
    redeemed from a persisted store): ``"off"`` skips, ``"warn"`` emits
    a Python warning on error-severity diagnostics, ``"strict"`` raises
    ``PlanVerificationError``.  ``verify_sink`` (a list) collects every
    ``(segment_key, VerifyReport)`` pair regardless of mode — the feed
    behind ``api.Program.verify()``.
    """
    from ..core.plan import strategy_salt
    from ..core.policy import as_policy, resolve_strategy
    policy = as_policy(scheduler)
    salt = f"{info.arch}|{info.phase}|{strategy_salt(policy)}"
    # partition with the policy's rule UNION, never the resolved branch's
    # rules: two shape buckets of one program must see the same graph, or
    # their structural keys diverge and cross-bucket PlanStore sharing
    # silently dies (the StrategyPolicy.partition_rules invariant)
    rules = policy.partition_rules()
    realizers = {}
    segs = []
    for seg in segments:
        g = seg.graph
        sched = resolve_strategy(policy, info, graph=g)
        if rules:
            g = partition(g, rules, default_depth=2)
        plan = record_plan(g, sched, info)
        seg = dataclasses.replace(seg, graph=g)
        rz = Realizer(g, plan, lowered=lowered,
                      plan_cache=plan_cache,
                      plan_salt=f"{salt}|{seg.key}",
                      op_config=op_config)
        if verify != "off" or verify_sink is not None:
            from ..core.verify import enforce, verify as run_verify
            report = run_verify(
                g, plan, lowered=getattr(rz, "lowered", None), lint=True)
            if verify_sink is not None:
                verify_sink.append((f"{info.phase}/{seg.key}", report))
            enforce(report, verify if verify != "off" else "report",
                    what=f"segment {seg.key!r} plan")
        realizers[seg.key] = rz
        segs.append(seg)
    return Forward(segs, realizers, remat=remat, remat_policy=remat_policy)


# ---------------------------------------------------------------------------
# dense-LM building blocks
# ---------------------------------------------------------------------------


class EmbedSegment(Module):
    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool):
        super().__init__()
        self.emb = EmbedOp(cfg.vocab, cfg.d_model, mesh)
        self.finish = (ReduceScatterOp(mesh, dim=1, name="embed_rs") if sp
                       else PsumOp(name="embed_ar"))
        self.named("embed")

    def forward(self, *, ids):
        return {"x": self.finish(self.emb(ids))}


class DenseDecoderLayer(Module):
    """Pre-norm decoder layer; SP collectives when ``sp`` else all-reduce."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool,
                 collect_kv: bool = False, attn_impl: str = None):
        super().__init__()
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.lay = lay
        self.sp = sp
        self.collect_kv = collect_kv
        self.ln1 = RMSNormOp(d, "ln_attn")
        if sp:
            self.ag1 = AllGatherOp(mesh, dim=1, name="ag_attn")
            self.ag2 = AllGatherOp(mesh, dim=1, name="ag_mlp")
            self.fin1 = ReduceScatterOp(mesh, dim=1, name="rs_attn")
            self.fin2 = ReduceScatterOp(mesh, dim=1, name="rs_mlp")
        else:
            self.fin1 = PsumOp(name="ar_attn")
            self.fin2 = PsumOp(name="ar_mlp")
        self.qkv = QKVProj(d, lay, mesh)
        self.rope = RopeOp(cfg.rope, cfg.rope_kwargs())
        self.attn = AttentionOp(lay, impl=attn_impl or mesh.attn_impl)
        self.oproj = OProj(d, lay, mesh)
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d, "ln_mlp")
        self.mlp = MLPBlock(d, cfg.d_ff, mesh, act=cfg.act)
        self.add2 = AddOp("add_mlp")
        self.named("layer")

    def forward(self, *, x, positions):
        h = self.ln1(x)
        if self.sp:
            h = self.ag1(h)
        q, k, v = self.qkv(h)
        q, k = self.rope(q, k, positions)
        a = self.attn(q, k, v)
        a = self.oproj(a)
        a = self.fin1(a)
        x = self.add1(x, a)
        h = self.ln2(x)
        if self.sp:
            h = self.ag2(h)
        m = self.mlp(h)
        m = self.fin2(m)
        x = self.add2(x, m)
        out = {"x": x}
        if self.collect_kv:
            out["k"], out["v"] = k, v
        return out


class DenseDecodeLayer(Module):
    """Decode layer: replicated activations, KV-cache update, all-reduce."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.lay = lay
        self.ln1 = RMSNormOp(d, "ln_attn")
        self.qkv = QKVProj(d, lay, mesh)
        self.rope = RopeOp(cfg.rope, cfg.rope_kwargs())
        self.attn = DecodeAttentionOp(lay)
        self.oproj = OProj(d, lay, mesh)
        self.fin1 = PsumOp(name="ar_attn")
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d, "ln_mlp")
        self.mlp = MLPBlock(d, cfg.d_ff, mesh, act=cfg.act)
        self.fin2 = PsumOp(name="ar_mlp")
        self.add2 = AddOp("add_mlp")
        self.named("layer")

    def forward(self, *, x, positions, cache_len, k_cache, v_cache):
        h = self.ln1(x)
        q, k, v = self.qkv(h)
        q, k = self.rope(q, k, positions)
        a, kc, vc = self.attn(q, k, v, k_cache, v_cache, cache_len)
        a = self.oproj(a)
        a = self.fin1(a)
        x = self.add1(x, a)
        h = self.ln2(x)
        m = self.mlp(h)
        m = self.fin2(m)
        x = self.add2(x, m)
        return {"x": x, "k_cache": kc, "v_cache": vc}


class TrainHead(Module):
    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool):
        super().__init__()
        d = cfg.d_model
        self.sp = sp
        self.ln = RMSNormOp(d, "ln_f")
        if sp:
            self.ag = AllGatherOp(mesh, dim=1, name="ag_head")
        tie = ("embed", "emb") if cfg.tie_embeddings else None
        self.out = HeadLossOp(d, cfg.vocab, mesh, tie_path=tie)
        self.named("head")

    def forward(self, *, x, labels):
        h = self.ln(x)
        if self.sp:
            h = self.ag(h)
        ls, cnt = self.out(h, labels)
        return {"loss_sum": ls, "token_count": cnt}


class LogitsHead(Module):
    """Prefill/decode head: vocab-sharded logits.

    ``keep_last=True`` (prefill) slices to the final position before the
    head matmul; ``keep_last=False`` (decode) keeps every position so a
    width-k verify step (speculative decode) sees all k+1 logits.  For
    the plain decode bucket (S=1) the two are the same computation —
    the slice is the identity — so decode tokens are bitwise unchanged.
    """

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool,
                 keep_last: bool = True):
        super().__init__()
        d = cfg.d_model
        self.sp = sp
        self.ln = RMSNormOp(d, "ln_f")
        if sp:
            self.ag = AllGatherOp(mesh, dim=1, name="ag_head")
        self.last = TakeLastOp() if keep_last else None
        tie = ("embed", "emb") if cfg.tie_embeddings else None
        self.out = LmHeadOp(d, cfg.vocab, mesh, tie_path=tie)
        self.named("head")

    def forward(self, *, x):
        h = self.ln(x)
        if self.sp:
            h = self.ag(h)
        if self.last is not None:
            h = self.last(h)
        return {"logits": self.out(h)}


# ---------------------------------------------------------------------------
# LM base class
# ---------------------------------------------------------------------------


class LMBase:
    """Shared machinery: build segments per phase, init params, shardings."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        self.cfg = cfg
        self.mesh = mesh

    def op_closure_config(self) -> tuple:
        """Canonical (name, value) pairs for the PlanStore fingerprint-v2
        outer key: everything this model's op callables close over that
        graph structure and shapes cannot see — attention impl, shard
        layout, dtype policy.  Two models whose graphs trace to the same
        structure but differ in any of these must not share lowerings."""
        m, c = self.mesh, self.cfg
        return (("arch", c.name),
                ("attn_impl", m.attn_impl),
                ("tp", m.tp), ("dp", m.dp), ("pods", m.pods),
                ("fsdp", m.fsdp), ("fsdp_resident", m.fsdp_resident),
                ("seq_parallel", bool(getattr(c, "seq_parallel", False))),
                ("act_dtype", "bfloat16"),
                ("rope", c.rope), ("act", c.act),
                ("tie_embeddings", bool(getattr(c, "tie_embeddings",
                                                False))))

    # subclasses define these ------------------------------------------------
    def make_embed(self, phase: str) -> Module:
        raise NotImplementedError

    def layer_stacks(self, phase: str) -> list[tuple[str, Module, int, tuple, tuple]]:
        """[(name, module, count, scan_inputs, scan_outputs)]"""
        raise NotImplementedError

    def make_head(self, phase: str) -> Module:
        raise NotImplementedError

    def batch_inputs(self, phase: str, B_loc: int, S: int,
                     s_max: int = 0) -> dict:
        """name -> (ShapeDtypeStruct, batch_dim) for non-cache inputs."""
        i32 = jnp.int32
        pos_shape = ((3, B_loc, S) if self.cfg.rope == "mrope"
                     else (B_loc, S))
        pos_bd = 1 if self.cfg.rope == "mrope" else 0
        if phase == "train":
            return {
                "ids": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "labels": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "positions": (jax.ShapeDtypeStruct(pos_shape, i32), pos_bd),
            }
        if phase == "prefill":
            return {
                "ids": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
                "positions": (jax.ShapeDtypeStruct(pos_shape, i32), pos_bd),
            }
        # decode: S tokens per step.  S == 1 is the classic single-token
        # decode; S > 1 runs the same cached-attention graph over a chunk
        # of S query positions (chunked prefill through the decode path).
        pos_shape = ((3, B_loc, S) if self.cfg.rope == "mrope"
                     else (B_loc, S))
        return {
            "ids": (jax.ShapeDtypeStruct((B_loc, S), i32), 0),
            "positions": (jax.ShapeDtypeStruct(pos_shape, i32), pos_bd),
            "cache_len": (jax.ShapeDtypeStruct((B_loc,), i32), 0),
        }

    def cache_specs(self, stack_name: str, B_loc: int, s_max: int) -> dict:
        """Per-layer cache ShapeDtypeStructs for decode (unstacked)."""
        return {}

    # shared ------------------------------------------------------------------
    def seq_local(self, phase: str, S: int) -> int:
        sp = self.cfg.seq_parallel and phase != "decode"
        return S // self.mesh.tp if sp else S

    def build_segments(self, phase: str, B_loc: int, S: int,
                       s_max: int = 0) -> tuple[list[Segment], dict]:
        """Trace all segment graphs.  Returns (segments, batch_input_specs)."""
        cfg = self.cfg
        binputs = self.batch_inputs(phase, B_loc, S, s_max)
        segs = []
        emb = self.make_embed(phase)
        import inspect
        esig = inspect.signature(emb.forward)
        emb_in = {k: v[0] for k, v in binputs.items()
                  if k in esig.parameters}
        g = trace(emb, emb_in, batch_dims={k: binputs[k][1] for k in emb_in})
        segs.append(Segment("embed", emb, g))
        # decode is never sequence-parallel, so its x keeps the full chunk
        # length S (1 for single-token decode, the chunk size for chunked
        # prefill through the decode graph)
        d_loc = self.seq_local(phase, S)
        x_sds = jax.ShapeDtypeStruct((B_loc, d_loc, cfg.d_model),
                                     jnp.bfloat16)
        for stack in self.layer_stacks(phase):
            name, mod, count, sc_in, sc_out = stack[:5]
            opts = stack[5] if len(stack) > 5 else {}
            lay_in = {"x": x_sds, "x0": x_sds}
            bd = {"x": 0, "x0": 0}
            for k, (sds, b) in binputs.items():
                if k in ("ids", "labels"):
                    continue
                lay_in[k] = sds
                bd[k] = b
            if phase == "decode":
                for cname, csds in self.cache_specs(name, B_loc, s_max).items():
                    lay_in[cname] = csds
                    bd[cname] = 0
            # drop inputs the module doesn't take
            sig = inspect.signature(mod.forward)
            lay_in = {k: v for k, v in lay_in.items() if k in sig.parameters}
            bd = {k: v for k, v in bd.items() if k in lay_in}
            g = trace(mod, lay_in, batch_dims=bd)
            segs.append(Segment(name, mod, g, count=count,
                                scan_inputs=sc_in, scan_outputs=sc_out,
                                **opts))
        head = self.make_head(phase)
        head_in = {"x": x_sds}
        hbd = {"x": 0}
        if phase == "train":
            head_in["labels"] = binputs["labels"][0]
            hbd["labels"] = 0
        g = trace(head, head_in, batch_dims=hbd)
        segs.append(Segment("head", head, g))
        return segs, binputs

    def decode_cache_env(self, B_loc: int, s_max: int) -> dict:
        """env-key -> ShapeDtypeStruct for all decode caches (launch layer).

        Generic: walks ``layer_stacks('decode')``; stacked (count,)+shape for
        scan segments.  Hybrid models override (aperiodic cache layout)."""
        out = {}
        for stack in self.layer_stacks("decode"):
            name, mod, count, sc_in = stack[0], stack[1], stack[2], stack[3]
            opts = stack[5] if len(stack) > 5 else {}
            imap = opts.get("input_map", {})
            for cn, sds in self.cache_specs(name, B_loc, s_max).items():
                if cn not in sc_in:
                    continue
                key = imap.get(cn, cn)
                shape = (count,) + sds.shape if count > 1 else sds.shape
                out[key] = jax.ShapeDtypeStruct(shape, sds.dtype)
        return out

    CACHE_MODEL_DIMS = {"k_cache": -2, "v_cache": -2,
                        "conv_state": -1, "ssm_state": -3}

    def decode_cache_layout(self) -> dict:
        """env-key -> (batch_dim, model_dim) for every decode cache: which
        dim is the request batch (sharded over data axes) and which dim is
        model-sharded (kv heads / SSM channels) — the launch layer derives
        global shapes + PartitionSpecs from this."""
        out = {}
        for stack in self.layer_stacks("decode"):
            name, _, count, sc_in = stack[0], stack[1], stack[2], stack[3]
            opts = stack[5] if len(stack) > 5 else {}
            imap = opts.get("input_map", {})
            for cn in self.cache_specs(name, 1, 2):
                if cn not in sc_in:
                    continue
                key = imap.get(cn, cn)
                base = next(k for k in self.CACHE_MODEL_DIMS if cn.endswith(k))
                out[key] = (1 if count > 1 else 0, self.CACHE_MODEL_DIMS[base])
        return out

    def decode_cache_page_env(self, num_pages: int, page_size: int) -> dict:
        """Paged decode-cache pool shapes: ``decode_cache_env`` with the
        request-batch dim reinterpreted as a physical-page dim and the
        sequence dim shrunk to one page — ``(P, page, kv, hd)`` per-layer,
        ``(L, P, page, kv, hd)`` stacked.  The serve layer gathers pages
        back into the contiguous ``(B, s_max, ...)`` view per step, so
        the decode graph itself never sees the paging.

        Raises for decode state with no sequence axis to page over (SSM
        conv/ssm states are constant-size per request): probe whether
        every cache's ``batch_dim + 1`` axis scales with ``s_max``."""
        a = self.decode_cache_env(1, page_size)
        b = self.decode_cache_env(1, 2 * page_size)
        layout = self.decode_cache_layout()
        for key, sa in a.items():
            bd = layout[key][0]
            want = list(sa.shape)
            want[bd + 1] *= 2
            if sa.shape[bd + 1] != page_size \
                    or tuple(want) != b[key].shape:
                from ..serve.kv_cache import UnpageableCache
                raise UnpageableCache(
                    f"decode cache {key!r} has no s_max-proportional "
                    f"sequence axis at dim {bd + 1} "
                    f"(shape {sa.shape} at s_max={page_size} vs "
                    f"{b[key].shape} at s_max={2 * page_size}); "
                    "serve this model with DenseCache")
        return self.decode_cache_env(num_pages, page_size)

    # params -------------------------------------------------------------------
    def init_params(self, key, phase="train", global_=False) -> dict:
        segs, _ = self.build_segments(phase, 2, 2 * self.mesh.tp
                                      if self.cfg.seq_parallel else 2,
                                      s_max=4)
        return self._init_from_segments(segs, key, global_)

    def _init_from_segments(self, segs, key, global_=False):
        import zlib
        out = {}
        for seg in segs:
            k = jax.random.fold_in(key, zlib.crc32(seg.name.encode()))
            if seg.name in out:  # shared-weight segment (same params reused)
                continue
            if seg.count == 1:
                p = seg.module.init(k, global_=global_)
                if p:
                    out[seg.name] = p
            else:
                ks = [jax.random.fold_in(k, i) for i in range(seg.count)]
                ps = [seg.module.init(kk, global_=global_) for kk in ks]
                out[seg.name] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *ps)
        return out

    def param_shapes(self, segs, global_=True) -> dict:
        """ShapeDtypeStruct tree (stacked for layer segments) — dry-run."""
        out = {}
        for seg in segs:
            if seg.name in out:
                continue
            shapes = (seg.module.global_param_shapes() if global_
                      else seg.module.param_shapes())
            if not shapes:
                continue
            if seg.count > 1:
                shapes = jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct((seg.count,) + s.shape,
                                                   s.dtype), shapes)
            out[seg.name] = shapes
        return out

    def param_pspecs(self, segs) -> dict:
        out = {}
        for seg in segs:
            if seg.name in out:
                continue
            ps = seg.module.param_pspecs()
            if not ps:
                continue
            if seg.count > 1:
                ps = jax.tree_util.tree_map(
                    lambda spec: (None,) + tuple(spec),
                    ps, is_leaf=lambda x: isinstance(x, tuple))
            out[seg.name] = ps
        return out
