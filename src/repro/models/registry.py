"""Model factory: ArchConfig.family -> LM implementation."""
from __future__ import annotations

from ..configs.base import ArchConfig
from .layers import MeshInfo


def build_model(cfg: ArchConfig, mesh: MeshInfo):
    from .hybrid import HybridLM
    from .mamba2 import Mamba2LM
    from .moe import MoELM
    from .transformer import DenseLM
    from .vlm import VLM
    from .whisper import WhisperLM

    fam = {
        "dense": DenseLM,
        "moe": MoELM,
        "ssm": Mamba2LM,
        "hybrid": HybridLM,
        "encdec": WhisperLM,
        "vlm": VLM,
    }
    if cfg.family not in fam:
        raise KeyError(f"unknown family {cfg.family!r}")
    return fam[cfg.family](cfg, mesh)
