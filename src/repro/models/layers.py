"""Schedulable model layers (manual SPMD) shared by all architectures.

Every layer is a DynaFlow ``Op``/``Module``: the traced graph exposes
logical operators (norm / projections / attention / collectives / MoE
stages) so the programmable scheduler can split, reorder, overlap and fuse
them.  Kernels are written against the *local shard*; mesh axis names
('data', 'model', optionally 'pod') are bound by the launch layer's
``shard_map``.

Sharding scheme
  * activations: batch over ('pod','data'); sequence over 'model' when
    sequence-parallel (SP) sections are active
  * attention: Q heads over 'model' (padded to a multiple of TP when
    needed), KV heads via a static per-shard slot map (GQA kv < TP is
    stored replicated per group — standard practice)
  * MLP: column-parallel in / row-parallel out + reduce-scatter (SP) or
    all-reduce
  * vocab: embedding + LM head sharded over 'model'
  * MoE: experts over 'model' (virtual-expert construction shards a single
    expert across multiple chips when n_experts < TP)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.module import Module, Op, Param
from ..dist import collectives as col

# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MeshInfo:
    """Static mesh-shape info modules need at construction time."""

    tp: int = 1        # 'model' axis size
    dp: int = 1        # 'data' axis size
    pods: int = 1      # 'pod' axis size (1 = single pod)
    fsdp: bool = False  # ZeRO-3: shard params over 'data' too
    fsdp_resident: bool = False  # decode: keep data-sharded weights
                                 # resident (partial matmul + tiny psum)
                                 # instead of per-step all-gathers
    attn_impl: str = "xla"   # xla | chunked | pallas (execution hint)

    @property
    def dp_axes(self):
        return ("pod", "data") if self.pods > 1 else ("data",)


def P(*names):
    return tuple(names)


def make_param(local_shape, dtype, pspec, mesh: MeshInfo, init=None,
               axis_sizes: Optional[dict] = None) -> Param:
    """Declare a param by LOCAL shape + partition spec; derive global.

    Axes of size 1 are dropped from the stored pspec: a module built with
    tp=1 (e.g. a replicated shared expert inside a TP mesh) must not claim
    'model' sharding the launch layer would then wrongly apply."""
    sizes = {"model": mesh.tp, "data": mesh.dp, "pod": mesh.pods}
    if axis_sizes:
        sizes.update(axis_sizes)
    gshape, eff_spec = [], []
    for d, names in zip(local_shape, tuple(pspec) + ((),) * (len(local_shape) - len(pspec))):
        if names is None or names == ():
            gshape.append(d)
            eff_spec.append(())
            continue
        if isinstance(names, str):
            names = (names,)
        names = tuple(n for n in names if sizes.get(n, 1) > 1)
        mult = 1
        for n in names:
            mult *= sizes[n]
        gshape.append(d * mult)
        eff_spec.append(names)
    return Param(tuple(local_shape), dtype, init=init, pspec=tuple(eff_spec),
                 global_shape=tuple(gshape))


# ---------------------------------------------------------------------------
# elementary ops
# ---------------------------------------------------------------------------


class LinearOp(Op):
    """Local matmul over the last dim.  Sharding is encoded in shapes.

    With ``owns_weight=False`` the weight arrives as a second *input*
    tensor (produced by a ``WeightGatherOp`` under FSDP) instead of a
    parameter — which is exactly what makes the weight gather schedulable.
    """

    resource = "compute"

    def __init__(self, d_in, d_out, name, mesh: MeshInfo,
                 pspec=((), ("model",)), dtype=jnp.bfloat16, owns_weight=True):
        super().__init__()
        self._shape = (d_in, d_out)
        if owns_weight:
            self.w = make_param((d_in, d_out), dtype, pspec, mesh)
        self.named(name)

    def kernel(self, p, x, *maybe_w):
        w = maybe_w[0] if maybe_w else p["w"]
        return jnp.einsum("...d,df->...f", x, w,
                          preferred_element_type=x.dtype)

    def flops_estimate(self, in_shapes):
        b = int(np.prod(in_shapes[0].shape[:-1]))
        return 2.0 * b * int(np.prod(self._shape))


class WeightGatherOp(Op):
    """FSDP: all-gather a data-axis-sharded weight before use (network).

    This is the paper's §2.1 'prefetch the next layer's weight shards in
    parallel with computation' made a first-class schedulable op.  The
    gather dim adapts to divisibility (row-parallel weights whose input
    dim is not a dp multiple shard the output dim instead).
    """

    resource = "network"
    out_batch_dim = None

    def __init__(self, local_shape, name, mesh: MeshInfo, pspec=((), ("model",)),
                 dtype=jnp.bfloat16):
        super().__init__()
        self.mesh = mesh
        self._full = tuple(local_shape)
        gdim = next(i for i in range(len(local_shape))
                    if local_shape[i] % mesh.dp == 0)
        self.gdim = gdim
        shape = list(local_shape)
        shape[gdim] //= mesh.dp
        spec = [tuple(e) for e in pspec]
        spec[gdim] = tuple(spec[gdim]) + ("data",)
        self.w = make_param(tuple(shape), dtype, tuple(spec), mesh)
        self.named(name)

    def kernel(self, p):
        return col.all_gather(p["w"], "data", dim=self.gdim)

    def infer_out(self, in_shapes):
        return jax.ShapeDtypeStruct(self._full, self.w.dtype)


class RMSNormOp(Op):
    resource = "memory"

    def __init__(self, d, name="rmsnorm", mesh: MeshInfo = None,
                 dtype=jnp.bfloat16, eps=1e-5):
        super().__init__()
        self.eps = eps
        self.g = Param((d,), dtype, init=lambda k, s, dt: jnp.ones(s, dt),
                       pspec=((),), global_shape=(d,))
        self.named(name)

    def kernel(self, p, x):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        return (xf * lax.rsqrt(var + self.eps)).astype(x.dtype) * p["g"]


class AddOp(Op):
    resource = "memory"

    def __init__(self, name="residual_add"):
        super().__init__()
        self.named(name)

    def kernel(self, p, a, b):
        return a + b


class SwiGLUOp(Op):
    """Fused gate activation: silu(gate) * up  (memory-bound)."""

    resource = "memory"

    def __init__(self, name="swiglu"):
        super().__init__()
        self.named(name)

    def kernel(self, p, gate_up):
        gate, up = jnp.split(gate_up, 2, axis=-1)
        return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


class GELUOp(Op):
    resource = "memory"

    def __init__(self, name="gelu"):
        super().__init__()
        self.named(name)

    def kernel(self, p, x):
        return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# collectives as schedulable network ops
# ---------------------------------------------------------------------------


class PsumOp(Op):
    resource = "network"

    def __init__(self, axis="model", name="allreduce"):
        super().__init__()
        self.axis = axis
        self.named(name)

    def kernel(self, p, x):
        return col.psum(x, self.axis)

    def infer_out(self, in_shapes):
        return in_shapes[0]


class ReduceScatterOp(Op):
    """psum_scatter over ``dim`` (SP entry: partial sums -> seq shards)."""

    resource = "network"

    def __init__(self, mesh: MeshInfo, axis="model", dim=1, name="reduce_scatter"):
        super().__init__()
        self.axis, self.dim, self.mesh = axis, dim, mesh
        self.named(name)

    def kernel(self, p, x):
        return col.reduce_scatter(x, self.axis, dim=self.dim)

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        n = self.mesh.tp if self.axis == "model" else self.mesh.dp
        assert s[self.dim] % n == 0, (s, self.dim, n)
        s[self.dim] //= n
        return jax.ShapeDtypeStruct(tuple(s), in_shapes[0].dtype)


class AllGatherOp(Op):
    """all-gather over ``dim`` (SP exit: seq shards -> full sequence)."""

    resource = "network"

    def __init__(self, mesh: MeshInfo, axis="model", dim=1, name="all_gather"):
        super().__init__()
        self.axis, self.dim, self.mesh = axis, dim, mesh
        self.named(name)

    def kernel(self, p, x):
        return col.all_gather(x, self.axis, dim=self.dim)

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        n = self.mesh.tp if self.axis == "model" else self.mesh.dp
        s[self.dim] *= n
        return jax.ShapeDtypeStruct(tuple(s), in_shapes[0].dtype)


class AllToAllOp(Op):
    resource = "network"

    def __init__(self, mesh: MeshInfo, axis="model", split_dim=0, concat_dim=0,
                 name="all_to_all"):
        super().__init__()
        self.axis, self.split_dim, self.concat_dim = axis, split_dim, concat_dim
        self.mesh = mesh
        self.named(name)

    def kernel(self, p, x):
        return col.all_to_all(x, self.axis, split_dim=self.split_dim,
                              concat_dim=self.concat_dim)

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        n = self.mesh.tp if self.axis == "model" else self.mesh.dp
        s[self.split_dim] //= n
        s[self.concat_dim] *= n
        return jax.ShapeDtypeStruct(tuple(s), in_shapes[0].dtype)


class DataShardedLinearOp(Op):
    """Decode-path ZeRO alternative: the weight's input dim stays sharded
    over 'data' (resident, never gathered); each chip multiplies its x
    slice and a psum over 'data' completes the contraction.  Trades
    d_in·d_out weight-gather bytes for d_out activation bytes — a huge
    win whenever tokens << d_in (single-token decode)."""

    resource = "compute"

    def __init__(self, d_in, d_out, name, mesh: MeshInfo,
                 pspec=((), ("model",)), dtype=jnp.bfloat16):
        super().__init__()
        assert d_in % mesh.dp == 0, (name, d_in, mesh.dp)
        self.d_loc = d_in // mesh.dp
        self._shape = (d_in, d_out)
        self.w = make_param((self.d_loc, d_out), dtype,
                            (tuple(pspec[0]) + ("data",), pspec[1]), mesh)
        self.named(name)

    def kernel(self, p, x):
        off = col.axis_index("data") * self.d_loc
        xs = lax.dynamic_slice_in_dim(x, off, self.d_loc, axis=x.ndim - 1)
        part = jnp.einsum("...d,df->...f", xs, p["w"],
                          preferred_element_type=x.dtype)
        return col.psum(part, "data")

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        s[-1] = self._shape[1]
        return jax.ShapeDtypeStruct(tuple(s), self.w.dtype)

    def flops_estimate(self, in_shapes):
        b = int(np.prod(in_shapes[0].shape[:-1]))
        return 2.0 * b * self.d_loc * self._shape[1]


class ShardedLinear(Module):
    """Linear with optional FSDP: when ``mesh.fsdp`` the weight is stored
    data-sharded and re-assembled by a schedulable WeightGather (network)
    op — the ZeRO-3 prefetch-overlap target.  ``mesh.fsdp_resident``
    (decode) keeps the shard resident and psums the partial output
    instead (see DataShardedLinearOp)."""

    def __init__(self, d_in, d_out, name, mesh: MeshInfo,
                 pspec=((), ("model",)), dtype=jnp.bfloat16, fsdp=None):
        super().__init__()
        self._fsdp = mesh.fsdp if fsdp is None else fsdp
        self._resident = self._fsdp and mesh.fsdp_resident             and d_in % mesh.dp == 0
        if self._resident:
            self.lin = DataShardedLinearOp(d_in, d_out, name, mesh,
                                           pspec=pspec, dtype=dtype)
        elif self._fsdp:
            self.gather = WeightGatherOp((d_in, d_out), f"{name}_wgather",
                                         mesh, pspec=pspec, dtype=dtype)
            self.lin = LinearOp(d_in, d_out, name, mesh, pspec=pspec,
                                dtype=dtype, owns_weight=False)
        else:
            self.lin = LinearOp(d_in, d_out, name, mesh, pspec=pspec,
                                dtype=dtype)
        self.named(name)

    def forward(self, x):
        if self._fsdp and not self._resident:
            return self.lin(x, self.gather())
        return self.lin(x)


# ---------------------------------------------------------------------------
# rotary position embeddings (3 variants)
# ---------------------------------------------------------------------------


def _rope_angles(positions, dim, base=10000.0, dtype=jnp.float32):
    """positions (...,) -> cos/sin (..., dim/2)."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (..., hd_rot) with hd_rot even; NeoX-style half rotation."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def rope_full(q, k, positions, base=10000.0):
    """Standard llama RoPE over the whole head dim.
    q (B,S,H,hd), positions (B,S)."""
    hd = q.shape[-1]
    cos, sin = _rope_angles(positions, hd, base, q.dtype)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def rope_partial(q, k, positions, fraction=0.5, base=10000.0):
    """ChatGLM-style 2d RoPE: rotate only the first ``fraction`` of hd."""
    hd = q.shape[-1]
    rot = int(hd * fraction)
    cos, sin = _rope_angles(positions, rot, base, q.dtype)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]

    def app(x):
        xr, xp = x[..., :rot], x[..., rot:]
        return jnp.concatenate([apply_rope(xr, cos, sin), xp], -1)

    return app(q), app(k)


def rope_mrope(q, k, positions3, sections=(16, 24, 24), base=10000.0):
    """Qwen2-VL M-RoPE: head dim halves partitioned into (t,h,w) sections,
    each rotated by its own position stream.  positions3: (3, B, S)."""
    hd = q.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    cos_parts, sin_parts = [], []
    offset = 0
    inv = 1.0 / (base ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    for sec, pos in zip(sections, positions3):
        ang = pos.astype(jnp.float32)[..., None] * inv[offset:offset + sec]
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        offset += sec
    cos = jnp.concatenate(cos_parts, -1).astype(q.dtype)[:, :, None, :]
    sin = jnp.concatenate(sin_parts, -1).astype(q.dtype)[:, :, None, :]
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


ROPE_FNS = {
    "full": rope_full,
    "partial2d": rope_partial,
    "mrope": rope_mrope,
    "none": lambda q, k, pos, **kw: (q, k),
}


# ---------------------------------------------------------------------------
# GQA head layout under TP
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HeadLayout:
    """Static mapping of (padded) Q heads / replicated KV heads to shards."""

    n_q: int                 # true q heads
    n_kv: int                # true kv heads
    tp: int
    head_dim: int

    @property
    def q_pad(self) -> int:  # padded q heads (multiple of tp)
        return ((self.n_q + self.tp - 1) // self.tp) * self.tp

    @property
    def q_local(self) -> int:
        return self.q_pad // self.tp

    def kv_ids_for_shard(self, s: int) -> list[int]:
        """Distinct true-KV head ids shard ``s`` needs (>=1)."""
        group = max(1, self.n_q // self.n_kv)
        ids = []
        for i in range(self.q_local):
            h = s * self.q_local + i
            kv = min(h // group, self.n_kv - 1)
            if kv not in ids:
                ids.append(kv)
        return ids

    @property
    def kv_local(self) -> int:
        return max(len(self.kv_ids_for_shard(s)) for s in range(self.tp))

    def kv_store_map(self) -> np.ndarray:
        """(tp, kv_local): true kv-head id stored in each local slot."""
        m = np.zeros((self.tp, self.kv_local), np.int32)
        for s in range(self.tp):
            ids = self.kv_ids_for_shard(s)
            ids = ids + [ids[-1]] * (self.kv_local - len(ids))
            m[s] = ids
        return m

    def q_slot_map(self) -> np.ndarray:
        """(tp, q_local): local KV slot each local q head attends to."""
        m = np.zeros((self.tp, self.q_local), np.int32)
        group = max(1, self.n_q // self.n_kv)
        for s in range(self.tp):
            ids = self.kv_ids_for_shard(s)
            for i in range(self.q_local):
                h = s * self.q_local + i
                kv = min(h // group, self.n_kv - 1)
                m[s, i] = ids.index(kv)
        return m

    def q_valid_map(self) -> np.ndarray:
        """(tp, q_local) 1.0 for true heads, 0.0 for padding heads."""
        m = np.zeros((self.tp, self.q_local), np.float32)
        for s in range(self.tp):
            for i in range(self.q_local):
                m[s, i] = 1.0 if s * self.q_local + i < self.n_q else 0.0
        return m


# ---------------------------------------------------------------------------
# attention ops
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, causal: bool, q_offset=0, valid_len=None):
    """Reference attention.  q (B,Sq,H,hd), k/v (B,Sk,H,hd).
    ``valid_len``: scalar, (B,) per-request cache lengths, or (B,Sq)
    per-query-position lengths (chunked decode: position j of the chunk
    sees ``cache_len + j + 1`` keys)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -1e30)
    if valid_len is not None:
        vl = jnp.asarray(valid_len)
        if vl.ndim == 2:                    # (B,Sq) -> (B,1,Sq,1)
            vl = vl[:, None, :, None]
        elif vl.ndim:                       # (B,)   -> (B,1,1,1)
            vl = vl.reshape(-1, 1, 1, 1)
        ki = jnp.arange(Sk)[None, None, None, :]
        logits = jnp.where(ki < vl, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _sdpa_chunked(q, k, v, causal: bool, chunk_q: int):
    """Exact attention, scanned over q blocks (bounded peak memory).

    custom_vjp with an explicit flash-style backward so BOTH directions
    sit inside named_scopes ("flashable_attention[_bwd]") — on TPU each
    scope is one Pallas kernel whose HBM traffic is q/k/v(/o/do) at the
    boundary; the roofline analyzer substitutes that cost (--attn-sub)."""
    B, Sq, H, hd = q.shape
    cq = _chunk_of(Sq, chunk_q)
    n = Sq // cq
    with jax.named_scope("flashable_attention"):
        qb = q.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            qi, off = inp
            o = _sdpa(qi, k, v, causal, q_offset=off)
            return None, o

        offs = jnp.arange(n, dtype=jnp.int32) * cq
        _, ob = lax.scan(body, None, (qb, offs))
        return ob.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _chunk_of(Sq, chunk_q):
    cq = min(chunk_q, Sq)
    while Sq % cq:
        cq //= 2
    return max(cq, 1)


def _sdpa_chunked_fwd(q, k, v, causal, chunk_q):
    return _sdpa_chunked(q, k, v, causal, chunk_q), (q, k, v)


def _sdpa_chunked_bwd(causal, chunk_q, res, do):
    """Flash-style backward: recompute per-chunk probabilities, accumulate
    dk/dv across q chunks, all inside the substitutable scope."""
    q, k, v = res
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    cq = _chunk_of(Sq, chunk_q)
    n = Sq // cq
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("flashable_attention_bwd"):
        qb = q.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4)
        dob = do.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4)
        offs = jnp.arange(n, dtype=jnp.int32) * cq

        def body(carry, inp):
            dk, dv = carry
            qi, doi, off = inp
            sl = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                            preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = jnp.arange(cq)[:, None] + off
                kpos = jnp.arange(Sk)[None, :]
                sl = jnp.where(kpos <= qpos, sl, -1e30)
            p = jax.nn.softmax(sl, axis=-1)                     # (B,H,cq,Sk)
            dof = doi.astype(jnp.float32)
            dvi = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dof,
                            v.astype(jnp.float32))
            ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
            dqi = jnp.einsum("bhqk,bkhd->bqhd", ds,
                             k.astype(jnp.float32)) * scale
            dki = jnp.einsum("bhqk,bqhd->bkhd", ds,
                             qi.astype(jnp.float32)) * scale
            return (dk + dki, dv + dvi), dqi.astype(q.dtype)

        zk = jnp.zeros(k.shape, jnp.float32)
        zv = jnp.zeros(v.shape, jnp.float32)
        (dk, dv), dqb = lax.scan(body, (zk, zv), (qb, dob, offs))
        dq = dqb.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_sdpa_chunked.defvjp(_sdpa_chunked_fwd, _sdpa_chunked_bwd)


class RopeOp(Op):
    """Apply rotary embeddings to q and k (its own schedulable memory op)."""

    resource = "memory"

    def __init__(self, rope: str = "full", rope_kw: Optional[dict] = None,
                 name="rope"):
        super().__init__()
        self.rope = rope
        self.rope_kw = rope_kw or {}
        self.named(name)

    def kernel(self, p, q, k, positions):
        return ROPE_FNS[self.rope](q, k, positions, **self.rope_kw)


class AttentionOp(Op):
    """Full (train/prefill) attention over roped q/k with GQA slot mapping.

    Inputs: q (B,S,q_local,hd), k,v (B,S,kv_local,hd).
    impl: 'pallas' (flash TPU kernel), 'chunked' (exact q-block scan, the
    XLA-level flash used for the large dry-run shapes — peak memory is one
    (B,H,cq,Sk) block instead of the full (B,H,S,S) score matrix), or
    'xla' (reference _sdpa).
    """

    resource = "compute"

    def __init__(self, layout: HeadLayout, causal=True,
                 name="attention", impl="xla", chunk_q=512):
        super().__init__()
        self.layout = layout
        self.causal = causal
        self.impl = impl
        self.chunk_q = chunk_q
        self.named(name)

    def kernel(self, p, q, k, v):
        lay = self.layout
        slot = jnp.asarray(lay.q_slot_map())[col.axis_index("model")]
        valid = jnp.asarray(lay.q_valid_map())[col.axis_index("model")]
        k_per_q = jnp.take(k, slot, axis=2)   # (B,S,q_local,hd)
        v_per_q = jnp.take(v, slot, axis=2)
        if self.impl == "pallas":
            from ..kernels import ops as kops
            out = kops.flash_attention(q, k_per_q, v_per_q, causal=self.causal)
        elif self.impl == "chunked" and q.shape[1] > self.chunk_q:
            out = _sdpa_chunked(q, k_per_q, v_per_q, self.causal,
                                self.chunk_q)
        else:
            out = _sdpa(q, k_per_q, v_per_q, self.causal)
        return out * valid[None, None, :, None].astype(out.dtype)

    def flops_estimate(self, in_shapes):
        B, S, H, hd = in_shapes[0].shape
        return 4.0 * B * S * S * H * hd * (0.5 if self.causal else 1.0)


class DecodeAttentionOp(Op):
    """Single-token decode attention against a KV cache (memory-bound).

    Inputs: q/k_new (roped) (B,1,·,hd), v_new,
            k_cache/v_cache (B,S_max,kv_local,hd),
            cache_len (B,) int32 per-request lengths (ragged batch).
    Outputs: attn (B,1,q_local,hd), updated k_cache, v_cache.
    ``impl='pallas'`` uses the flash-decode kernel for the cache read.
    """

    resource = "memory"

    def __init__(self, layout: HeadLayout, name="decode_attention",
                 window: Optional[int] = None, impl: str = "xla"):
        super().__init__()
        self.layout = layout
        self.window = window
        self.impl = impl
        self.named(name)

    def kernel(self, p, q, k_new, v_new, k_cache, v_cache, cache_len):
        lay = self.layout
        clen = (jnp.broadcast_to(cache_len, (q.shape[0],))
                if jnp.ndim(cache_len) == 0 else cache_len)
        k_cache = _dus_time(k_cache, k_new, clen)
        v_cache = _dus_time(v_cache, v_new, clen)
        slot = jnp.asarray(lay.q_slot_map())[col.axis_index("model")]
        valid = jnp.asarray(lay.q_valid_map())[col.axis_index("model")]
        k_per_q = jnp.take(k_cache, slot, axis=2)
        v_per_q = jnp.take(v_cache, slot, axis=2)
        Sq = q.shape[1]
        if self.impl == "pallas" and Sq == 1:
            from ..kernels import ops as kops
            out = kops.decode_attention(q, k_per_q, v_per_q, clen + 1)
        else:
            # chunked decode (Sq > 1): query position j attends the cache
            # prefix plus the chunk up to and including itself —
            # ``cache_len + j + 1`` keys (per-row, per-position lengths).
            vl = (clen + 1 if Sq == 1
                  else clen[:, None] + 1 + jnp.arange(Sq, dtype=clen.dtype))
            with jax.named_scope("flashable_decode"):
                out = _sdpa(q, k_per_q, v_per_q, causal=False, valid_len=vl)
        out = out * valid[None, None, :, None].astype(out.dtype)
        return out, k_cache, v_cache

    def infer_out(self, in_shapes):
        q, k_new, v_new, kc, vc, clen = in_shapes
        return (jax.ShapeDtypeStruct(q.shape, q.dtype),
                jax.ShapeDtypeStruct(kc.shape, kc.dtype),
                jax.ShapeDtypeStruct(vc.shape, vc.dtype))

    def bytes_estimate(self, in_shapes, out_shapes):
        kc = in_shapes[3]
        return 2.0 * 2 * int(np.prod(kc.shape))  # read K+V cache


def _dus_time(cache, new, t):
    """dynamic_update_slice at per-row time indices ``t`` (B,) along dim 1.
    ``new`` may carry one token (decode) or a whole chunk (chunked
    prefill); callers must keep ``t + new.shape[1] <= S_max`` or the
    clamped start would silently shift the write window."""
    t = jnp.asarray(t, jnp.int32)
    if t.ndim == 0:
        idx = (jnp.int32(0), t.reshape(()), jnp.int32(0), jnp.int32(0))
        return lax.dynamic_update_slice(cache, new.astype(cache.dtype), idx)

    def one(c, n, ti):   # c (S,kv,hd), n (Sq,kv,hd)
        return lax.dynamic_update_slice(
            c, n.astype(c.dtype), (ti, jnp.int32(0), jnp.int32(0)))

    return jax.vmap(one)(cache, new, t)


# ---------------------------------------------------------------------------
# embedding / head / loss (vocab-sharded)
# ---------------------------------------------------------------------------


class EmbedOp(Op):
    """Vocab-sharded embedding lookup; emits a *partial* value that a
    following Psum/ReduceScatter network op completes."""

    resource = "memory"

    def __init__(self, vocab, d, mesh: MeshInfo, name="embed",
                 dtype=jnp.bfloat16):
        super().__init__()
        vpad = -(-vocab // mesh.tp) * mesh.tp   # pad to a tp multiple
        self.vshard = vpad // mesh.tp
        self.mesh = mesh
        self.w = make_param((self.vshard, d), dtype, (("model",), ()), mesh,
                            init=lambda k, s, dt: jax.random.normal(k, s, jnp.float32).astype(dt) * 0.02)
        self.named(name)

    def kernel(self, p, ids):
        off = col.axis_index("model") * self.vshard
        local = ids - off
        ok = (local >= 0) & (local < self.vshard)
        local = jnp.clip(local, 0, self.vshard - 1)
        out = jnp.take(p["w"], local, axis=0)
        return out * ok[..., None].astype(out.dtype)


class LmHeadOp(Op):
    """x (B,S,d) -> logits (B,S,Vshard) vocab-sharded."""

    resource = "compute"

    def __init__(self, d, vocab, mesh: MeshInfo, name="lm_head",
                 dtype=jnp.bfloat16, tie_path: Optional[tuple] = None):
        super().__init__()
        self.vocab = vocab
        self.vshard = -(-vocab // mesh.tp)
        self.tied = tie_path is not None
        if tie_path is None:
            self.w = make_param((d, self.vshard), dtype, ((), ("model",)), mesh)
        else:
            self.share_params(tie_path)
        self.named(name)

    def kernel(self, p, x):
        w = p["w"]
        if self.tied:
            w = w.T  # embed table (Vshard, d) -> (d, Vshard)
        out = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=x.dtype)
        # mask vocab-padding logits so sampling can never pick them
        gid = col.axis_index("model") * self.vshard + jnp.arange(self.vshard)
        return jnp.where(gid < self.vocab, out, -1e30)

    def infer_out(self, in_shapes):
        B, S, d = in_shapes[0].shape
        return jax.ShapeDtypeStruct((B, S, self.vshard), in_shapes[0].dtype)

    def flops_estimate(self, in_shapes):
        B, S, d = in_shapes[0].shape
        return 2.0 * B * S * d * self.vshard


class ShardedXentOp(Op):
    """Cross-entropy over vocab-sharded logits (psum'd logsumexp),
    seq-chunked to bound the live logits buffer.  Emits per-device mean
    loss; the train step psum-means it over the data axis."""

    resource = "compute"

    def __init__(self, mesh: MeshInfo, vshard: int, vocab: int = 0,
                 name="xent"):
        super().__init__()
        self.mesh = mesh
        self.vshard = vshard
        self.vocab = vocab or vshard * mesh.tp
        self.named(name)
        self.out_batch_dim = None  # scalar loss

    def kernel(self, p, logits, labels):
        # logits (B,S,Vs) local shard; labels (B,S) global ids
        lf = logits.astype(jnp.float32)
        gid = col.axis_index("model") * self.vshard + jnp.arange(self.vshard)
        lf = jnp.where(gid < self.vocab, lf, -1e30)
        m_local = jnp.max(lf, axis=-1)
        # stability max carries no gradient (cancels in lse - tgt)
        m = col.pmax(lax.stop_gradient(m_local), "model")
        se = jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)
        se = col.psum(se, "model")
        lse = jnp.log(se) + m
        off = col.axis_index("model") * self.vshard
        loc = labels - off
        ok = (loc >= 0) & (loc < self.vshard)
        loc = jnp.clip(loc, 0, self.vshard - 1)
        tgt = jnp.take_along_axis(lf, loc[..., None], axis=-1)[..., 0]
        tgt = col.psum(tgt * ok.astype(jnp.float32), "model")
        return jnp.mean(lse - tgt)

    def infer_out(self, in_shapes):
        return jax.ShapeDtypeStruct((), jnp.float32)


class HeadLossOp(Op):
    """Fused LM head + cross entropy, seq-chunked so the (B,S,V/tp) logits
    never fully materialize (memory-term optimization for 256k vocabs).

    Inputs x (B,S,d), labels (B,S) int32 (-100 = ignore).
    Outputs per-sample (loss_sum (B,), token_count (B,)) f32 — merged and
    normalized by the step function with a data-axis psum.
    """

    resource = "compute"

    def __init__(self, d, vocab, mesh: MeshInfo, name="head_loss",
                 dtype=jnp.bfloat16, tie_path: Optional[tuple] = None,
                 chunk=512):
        super().__init__()
        self.vocab = vocab
        self.vshard = -(-vocab // mesh.tp)
        self.chunk = chunk
        self.tied = tie_path is not None
        self._d = d
        if tie_path is None:
            self.w = make_param((d, self.vshard), dtype, ((), ("model",)), mesh)
        else:
            self.share_params(tie_path)
        self.named(name)

    def kernel(self, p, x, labels):
        w = p["w"].T if self.tied else p["w"]
        B, S, d = x.shape
        c = min(self.chunk, S)
        n = -(-S // c)
        pad = n * c - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)),
                             constant_values=-100)
        xc = x.reshape(B, n, c, d).swapaxes(0, 1)        # (n,B,c,d)
        lc = labels.reshape(B, n, c).swapaxes(0, 1)      # (n,B,c)
        off = col.axis_index("model") * self.vshard

        def body(carry, inp):
            ls, cnt = carry
            xi, li = inp
            logits = jnp.einsum("bcd,dv->bcv", xi, w,
                                preferred_element_type=jnp.float32)
            gid = (col.axis_index("model") * self.vshard
                   + jnp.arange(self.vshard))
            logits = jnp.where(gid < self.vocab, logits, -1e30)
            m = col.pmax(lax.stop_gradient(jnp.max(logits, -1)), "model")
            se = col.psum(jnp.sum(jnp.exp(logits - m[..., None]), -1), "model")
            lse = jnp.log(se) + m
            loc = li - off
            ok = (loc >= 0) & (loc < self.vshard)
            locc = jnp.clip(loc, 0, self.vshard - 1)
            tgt = jnp.take_along_axis(logits, locc[..., None], -1)[..., 0]
            tgt = col.psum(jnp.where(ok, tgt, 0.0), "model")
            valid = (li != -100)
            tok = jnp.where(valid, lse - tgt, 0.0)
            return (ls + jnp.sum(tok, -1),
                    cnt + jnp.sum(valid, -1).astype(jnp.float32)), None

        (ls, cnt), _ = lax.scan(body, (jnp.zeros((B,), jnp.float32),
                                       jnp.zeros((B,), jnp.float32)),
                                (xc, lc))
        return ls, cnt

    def infer_out(self, in_shapes):
        B = in_shapes[0].shape[0]
        return (jax.ShapeDtypeStruct((B,), jnp.float32),
                jax.ShapeDtypeStruct((B,), jnp.float32))

    def flops_estimate(self, in_shapes):
        B, S, d = in_shapes[0].shape
        return 2.0 * B * S * d * self.vshard


class TakeLastOp(Op):
    """Keep only the final sequence position (prefill -> next-token logits)."""

    resource = "memory"

    def __init__(self, name="take_last"):
        super().__init__()
        self.named(name)

    def kernel(self, p, x):
        return x[:, -1:, :]


# ---------------------------------------------------------------------------
# composite blocks
# ---------------------------------------------------------------------------


class MLPBlock(Module):
    """SwiGLU MLP, column/row parallel (+SP reduce-scatter outside)."""

    def __init__(self, d, d_ff, mesh: MeshInfo, name="mlp",
                 dtype=jnp.bfloat16, act="swiglu"):
        super().__init__()
        assert d_ff % mesh.tp == 0, (d_ff, mesh.tp)
        ff_loc = d_ff // mesh.tp
        mult = 2 if act == "swiglu" else 1
        self.wi = ShardedLinear(d, mult * ff_loc, "mlp_in", mesh, dtype=dtype)
        self.act = SwiGLUOp() if act == "swiglu" else GELUOp()
        self.wo = ShardedLinear(ff_loc, d, "mlp_out", mesh,
                                pspec=(("model",), ()), dtype=dtype)
        self.named(name)

    def forward(self, x):
        return self.wo(self.act(self.wi(x)))


class QKVProj(Module):
    """Fused QKV projection, head-sharded; emits q/k/v split ops."""

    def __init__(self, d, layout: HeadLayout, mesh: MeshInfo, name="qkv",
                 dtype=jnp.bfloat16):
        super().__init__()
        lay = layout
        hd = lay.head_dim
        self.lay = lay
        out_dim = (lay.q_local + 2 * lay.kv_local) * hd
        self.proj = ShardedLinear(d, out_dim, "qkv_proj", mesh, dtype=dtype)
        self.splitter = _QKVSplit(lay).named("qkv_split")
        self.named(name)

    def forward(self, x):
        return self.splitter(self.proj(x))


class _QKVSplit(Op):
    resource = "memory"

    def __init__(self, lay: HeadLayout):
        super().__init__()
        self.lay = lay

    def kernel(self, p, qkv):
        lay = self.lay
        hd = lay.head_dim
        B, S, _ = qkv.shape
        nq, nk = lay.q_local * hd, lay.kv_local * hd
        q = qkv[..., :nq].reshape(B, S, lay.q_local, hd)
        k = qkv[..., nq:nq + nk].reshape(B, S, lay.kv_local, hd)
        v = qkv[..., nq + nk:].reshape(B, S, lay.kv_local, hd)
        return q, k, v


class OProj(Module):
    """Row-parallel attention output projection (emits partial sums)."""

    def __init__(self, d, layout: HeadLayout, mesh: MeshInfo, name="o_proj",
                 dtype=jnp.bfloat16):
        super().__init__()
        self.flat = _FlattenHeads().named("flatten_heads")
        self.proj = ShardedLinear(layout.q_local * layout.head_dim, d, "o_proj",
                                  mesh, pspec=(("model",), ()), dtype=dtype)
        self.named(name)

    def forward(self, attn):
        return self.proj(self.flat(attn))


class _FlattenHeads(Op):
    resource = "memory"

    def kernel(self, p, x):
        B, S, H, hd = x.shape
        return x.reshape(B, S, H * hd)
