"""Mixture-of-Experts layers (deepseek-moe-16b, grok-1-314b).

Expert parallelism over the 'model' mesh axis with explicit, *schedulable*
all-to-all dispatch/combine ops — the DBO / shared-expert-overlap targets
from the paper (Fig. 1a, §3.2.2 Example 1).

Virtual experts: when n_experts < TP, each expert is sharded across
``es = TP // n_experts`` chips (intra-expert FFN tensor parallelism); a
token is dispatched to all ``es`` shards of each selected expert and the
partial outputs sum in the combine.  When n_experts >= TP, each chip hosts
``e_loc = V // TP`` whole experts.  Capacity-based static shapes
(C = ceil(cf·n·k / E)); overflow tokens drop (standard).

Dispatch buffers scale with the micro-batch token count, so they are
VBATCH tensors: produced/consumed per micro-batch, never sliced/merged —
which statically enforces that a scheduler splitting the MoE section keeps
its whole dispatch→combine chain per-micro-batch (what DBO wants).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig, MoEConfig
from ..core.graph import VBATCH
from ..core.module import Module, Op, mark
from ..dist import collectives as col
from .layers import (AddOp, AllGatherOp, HeadLayout, make_param, MeshInfo,
                     MLPBlock, OProj, PsumOp, QKVProj, ReduceScatterOp,
                     RMSNormOp, RopeOp)


def moe_dims(m: MoEConfig, tp: int):
    """(virtual experts V, local experts e_loc, expert shards es, ff shard)."""
    if m.n_experts >= tp:
        assert m.n_experts % tp == 0, (m.n_experts, tp)
        return m.n_experts, m.n_experts // tp, 1, m.d_ff_expert
    assert tp % m.n_experts == 0, (m.n_experts, tp)
    es = tp // m.n_experts
    assert m.d_ff_expert % es == 0
    return tp, 1, es, m.d_ff_expert // es


class RouterOp(Op):
    """Top-k router.  Outputs combine weights + *virtual* expert ids."""

    resource = "compute"

    def __init__(self, d, m: MoEConfig, mesh: MeshInfo, name="router"):
        super().__init__()
        self.m = m
        V, e_loc, es, ffs = moe_dims(m, mesh.tp)
        self.es = es
        self.wr = make_param((d, m.n_experts), jnp.float32, ((), ()), mesh)
        self.out_batch_dims = (0, 0)
        self.named(name)

    def kernel(self, p, x):
        m = self.m
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["wr"])
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = lax.top_k(probs, m.top_k)           # (B,S,k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
        # expand to virtual experts: each selected expert -> its es shards
        r = jnp.arange(self.es, dtype=idx.dtype)
        ve = (idx[..., None] * self.es + r).reshape(*idx.shape[:-1], -1)
        wv = jnp.repeat(w, self.es, axis=-1).astype(jnp.float32)
        return wv, ve                                 # (B,S,k*es) each


class DispatchBuildOp(Op):
    """Pack tokens into per-virtual-expert capacity slots (zero-copy scatter).

    Outputs: buf (V, C, d) [VBATCH], slot (B,S,kv) int32 (-1 = dropped)."""

    resource = "memory"

    def __init__(self, m: MoEConfig, mesh: MeshInfo, name="moe_dispatch_build"):
        super().__init__()
        self.m = m
        self.V, self.e_loc, self.es, _ = moe_dims(m, mesh.tp)
        self.out_batch_dims = (VBATCH, 0)
        self.named(name)

    def _capacity(self, n_tokens: int) -> int:
        m = self.m
        per = n_tokens * m.top_k / m.n_experts
        return max(4, int(math.ceil(m.capacity_factor * per)))

    def kernel(self, p, x, ve):
        B, S, d = x.shape
        kv = ve.shape[-1]
        n, nk = B * S, B * S * kv
        C = self._capacity(n)
        vef = ve.reshape(nk)
        onehot = jax.nn.one_hot(vef, self.V, dtype=jnp.int32)
        slot = jnp.cumsum(onehot, axis=0) - 1         # (nk, V)
        slot = jnp.take_along_axis(slot, vef[:, None], 1)[:, 0]
        keep = slot < C
        flat_idx = jnp.where(keep, vef * C + slot, self.V * C)  # OOB drops
        tok = jnp.repeat(jnp.arange(n), kv)
        xf = x.reshape(n, d)
        buf = jnp.zeros((self.V * C, d), x.dtype)
        buf = buf.at[flat_idx].set(xf[tok], mode="drop")
        slot_out = jnp.where(keep, slot, -1).reshape(B, S, kv).astype(jnp.int32)
        return buf.reshape(self.V, C, d), slot_out

    def infer_out(self, in_shapes):
        x, ve = in_shapes
        B, S, d = x.shape
        C = self._capacity(B * S)
        return (jax.ShapeDtypeStruct((self.V, C, d), x.dtype),
                jax.ShapeDtypeStruct((B, S, ve.shape[-1]), jnp.int32))


class MoEAllToAllOp(Op):
    """Expert-parallel all-to-all (network).  direction='dispatch' sends
    (V,C,d) -> (e_loc, T*C, d); 'combine' is the inverse."""

    resource = "network"
    out_batch_dim = VBATCH

    def __init__(self, mesh: MeshInfo, direction: str, name=None):
        super().__init__()
        self.mesh = mesh
        self.direction = direction
        self.named(name or f"moe_a2a_{direction}")

    def kernel(self, p, buf):
        if self.direction == "dispatch":
            return col.all_to_all(buf, "model", split_dim=0, concat_dim=1)
        return col.all_to_all(buf, "model", split_dim=1, concat_dim=0)

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        t = self.mesh.tp
        if self.direction == "dispatch":
            s[0] //= t
            s[1] *= t
        else:
            s[1] //= t
            s[0] *= t
        return jax.ShapeDtypeStruct(tuple(s), in_shapes[0].dtype)


class ParamGatherOp(Op):
    """FSDP/ZeRO-3: all-gather a data-axis-sharded param along ``gdim``
    before use — a schedulable *network* op (the paper's §2.1 weight-shard
    prefetch made first-class; the SBO scheduler overlaps it)."""

    resource = "network"
    out_batch_dim = None

    def __init__(self, local_shape, gdim: int, name, mesh: MeshInfo,
                 pspec, dtype=jnp.bfloat16):
        super().__init__()
        self.gdim = gdim
        self.mesh = mesh
        shape = list(local_shape)
        assert shape[gdim] % mesh.dp == 0, (name, local_shape, gdim, mesh.dp)
        shape[gdim] //= mesh.dp
        spec = list(tuple(pspec) + ((),) * (len(shape) - len(pspec)))
        spec[gdim] = tuple(spec[gdim]) + ("data",)
        self.w = make_param(tuple(shape), dtype, tuple(spec), mesh)
        self._full = tuple(local_shape)
        self.named(name)

    def kernel(self, p):
        return col.all_gather(p["w"], "data", dim=self.gdim)

    def infer_out(self, in_shapes):
        return jax.ShapeDtypeStruct(self._full, self.w.dtype)


class ExpertGEMMOp(Op):
    """Grouped expert FFN: (e_loc, n, d) -> (e_loc, n, d).  The Pallas
    grouped-matmul kernel replaces this on TPU (Comet-style replace_func).
    With ``owns_weight=False`` the three weights arrive as inputs
    (produced by ParamGatherOps under FSDP)."""

    resource = "compute"
    out_batch_dim = VBATCH

    def __init__(self, d, m: MoEConfig, mesh: MeshInfo, name="expert_ffn",
                 dtype=jnp.bfloat16, impl="xla", owns_weight=True):
        super().__init__()
        V, e_loc, es, ffs = moe_dims(m, mesh.tp)
        self.impl = impl
        self._dims = (e_loc, d, ffs)
        if owns_weight:
            self.w1 = make_param((e_loc, d, ffs), dtype,
                                 (("model",), (), ()), mesh)
            self.w3 = make_param((e_loc, d, ffs), dtype,
                                 (("model",), (), ()), mesh)
            self.w2 = make_param((e_loc, ffs, d), dtype,
                                 (("model",), (), ()), mesh)
        self.named(name)

    def kernel(self, p, buf, *ws):
        w1, w3, w2 = ws if ws else (p["w1"], p["w3"], p["w2"])
        if self.impl == "pallas":
            from ..kernels import ops as kops
            return kops.grouped_ffn(buf, w1, w3, w2)
        h1 = jnp.einsum("end,edf->enf", buf, w1,
                        preferred_element_type=buf.dtype)
        h3 = jnp.einsum("end,edf->enf", buf, w3,
                        preferred_element_type=buf.dtype)
        h = jax.nn.silu(h1.astype(jnp.float32)).astype(buf.dtype) * h3
        return jnp.einsum("enf,efd->end", h, w2,
                          preferred_element_type=buf.dtype)

    def flops_estimate(self, in_shapes):
        e, n, d = in_shapes[0].shape
        _, _, ffs = self._dims
        return 6.0 * e * n * d * ffs

    def infer_out(self, in_shapes):
        return in_shapes[0]


class FFShardedExpertGEMM(Op):
    """Expert FFN with the hidden (ff) dim sharded over 'data': weights
    stay RESIDENT (no per-step ZeRO gather); each chip computes its ff
    slice's partial output, completed by the tiny activation psum after
    the combine.  SwiGLU is elementwise in ff, so the decomposition is
    exact.  This is the decode-path alternative to gather-based ZeRO:
    it trades 2·3·d·ff/layer of weight gather for B·d of activation psum
    — at decode batch sizes a ~10^4x collective-byte reduction."""

    resource = "compute"
    out_batch_dim = VBATCH

    def __init__(self, d, m: MoEConfig, mesh: MeshInfo,
                 name="expert_ffn_ffshard", dtype=jnp.bfloat16):
        super().__init__()
        V, e_loc, es, ffs = moe_dims(m, mesh.tp)
        assert ffs % mesh.dp == 0, (ffs, mesh.dp)
        ff_loc = ffs // mesh.dp
        self._dims = (e_loc, d, ff_loc)
        self.w1 = make_param((e_loc, d, ff_loc), dtype,
                             (("model",), (), ("data",)), mesh)
        self.w3 = make_param((e_loc, d, ff_loc), dtype,
                             (("model",), (), ("data",)), mesh)
        self.w2 = make_param((e_loc, ff_loc, d), dtype,
                             (("model",), ("data",), ()), mesh)
        self.named(name)

    def kernel(self, p, buf):
        h1 = jnp.einsum("end,edf->enf", buf, p["w1"],
                        preferred_element_type=buf.dtype)
        h3 = jnp.einsum("end,edf->enf", buf, p["w3"],
                        preferred_element_type=buf.dtype)
        h = jax.nn.silu(h1.astype(jnp.float32)).astype(buf.dtype) * h3
        return jnp.einsum("enf,efd->end", h, p["w2"],
                          preferred_element_type=buf.dtype)

    def flops_estimate(self, in_shapes):
        e, n, d = in_shapes[0].shape
        _, _, ff_loc = self._dims
        return 6.0 * e * n * d * ff_loc

    def infer_out(self, in_shapes):
        return in_shapes[0]


class ExpertFFN(Module):
    """Expert GEMM, three storage modes:
      resident        — weights TP-sharded only (fit on a pod row)
      zero3 (gather)  — data-sharded + per-use all-gather (train path;
                        the gathers are schedulable network ops)
      ff-sharded      — hidden dim sharded over 'data', partial outputs
                        (replicated/decode path; no gather at all)
    """

    def __init__(self, d, m: MoEConfig, mesh: MeshInfo, dtype=jnp.bfloat16,
                 ff_shard: bool = False):
        super().__init__()
        V, e_loc, es, ffs = moe_dims(m, mesh.tp)
        self._fsdp = mesh.fsdp and not ff_shard
        self.ff_shard = ff_shard and mesh.fsdp
        if self.ff_shard:
            self.gemm = FFShardedExpertGEMM(d, m, mesh, dtype=dtype)
        elif self._fsdp:
            spec_df = (("model",), (), ())
            self.g1 = ParamGatherOp((e_loc, d, ffs), 2, "w1_gather", mesh,
                                    spec_df, dtype)
            self.g3 = ParamGatherOp((e_loc, d, ffs), 2, "w3_gather", mesh,
                                    spec_df, dtype)
            self.g2 = ParamGatherOp((e_loc, ffs, d), 1, "w2_gather", mesh,
                                    spec_df, dtype)
            self.gemm = ExpertGEMMOp(d, m, mesh, dtype=dtype,
                                     owns_weight=False)
        else:
            self.gemm = ExpertGEMMOp(d, m, mesh, dtype=dtype)
        self.named("expert_ffn")

    def forward(self, buf):
        if self._fsdp:
            return self.gemm(buf, self.g1(), self.g3(), self.g2())
        return self.gemm(buf)


class CombineOp(Op):
    """Un-permute expert outputs back to tokens and weighted-sum top-k."""

    resource = "memory"

    def __init__(self, name="moe_combine"):
        super().__init__()
        self.named(name)

    def kernel(self, p, buf, ve, slot, w):
        # buf (V,C,d); ve/slot/w (B,S,kv)
        V, C, d = buf.shape
        B, S, kv = ve.shape
        keep = slot >= 0
        flat = jnp.where(keep, ve * C + jnp.maximum(slot, 0), 0)
        rows = jnp.take(buf.reshape(V * C, d), flat.reshape(-1), axis=0)
        rows = rows.reshape(B, S, kv, d)
        wgt = (w * keep.astype(w.dtype))[..., None].astype(rows.dtype)
        return jnp.sum(rows * wgt, axis=2)

    def infer_out(self, in_shapes):
        buf, ve, slot, w = in_shapes
        B, S, kv = ve.shape
        return jax.ShapeDtypeStruct((B, S, buf.shape[-1]), buf.dtype)


class ExpertSliceOp(Op):
    """Replicated mode: take this chip's local-expert rows of the
    (replicated) dispatch buffer — the zero-communication 'dispatch'."""

    resource = "memory"
    out_batch_dim = VBATCH

    def __init__(self, m: MoEConfig, mesh: MeshInfo, name="expert_slice"):
        super().__init__()
        self.V, self.e_loc, _, _ = moe_dims(m, mesh.tp)
        self.named(name)

    def kernel(self, p, buf):
        start = col.axis_index("model") * self.e_loc
        return lax.dynamic_slice_in_dim(buf, start, self.e_loc, axis=0)

    def infer_out(self, in_shapes):
        s = list(in_shapes[0].shape)
        s[0] = self.e_loc
        return jax.ShapeDtypeStruct(tuple(s), in_shapes[0].dtype)


class CombinePartialOp(Op):
    """Replicated mode: weighted-sum only this chip's local experts'
    outputs; the trailing psum (network op) completes the token sum."""

    resource = "memory"

    def __init__(self, m: MoEConfig, mesh: MeshInfo, name="moe_combine"):
        super().__init__()
        self.V, self.e_loc, _, _ = moe_dims(m, mesh.tp)
        self.named(name)

    def kernel(self, p, buf, ve, slot, w):
        # buf (e_loc,C,d) local experts; ve/slot/w (B,S,kv) with global ve
        e_loc, C, d = buf.shape
        B, S, kv = ve.shape
        start = col.axis_index("model") * e_loc
        local = ve - start
        mine = (local >= 0) & (local < e_loc) & (slot >= 0)
        flat = jnp.where(mine, jnp.clip(local, 0, e_loc - 1) * C
                         + jnp.maximum(slot, 0), 0)
        rows = jnp.take(buf.reshape(e_loc * C, d), flat.reshape(-1), axis=0)
        rows = rows.reshape(B, S, kv, d)
        wgt = (w * mine.astype(w.dtype))[..., None].astype(rows.dtype)
        return jnp.sum(rows * wgt, axis=2)

    def infer_out(self, in_shapes):
        buf, ve, slot, w = in_shapes
        B, S, kv = ve.shape
        return jax.ShapeDtypeStruct((B, S, buf.shape[-1]), buf.dtype)


class MoEBlock(Module):
    """Expert-parallel MoE over the 'model' axis, two layouts:

    * token_sharded (SP train/prefill): the block consumes the
      sequence-sharded activations directly — each chip routes and packs
      its OWN S/tp tokens, the dispatch/combine all-to-alls move real
      (distinct) tokens, and no collective follows the combine.
    * replicated (decode / non-SP): activations are replicated; dispatch
      is a local expert-slice (zero communication), each chip computes its
      e_loc experts over all tokens' capacity slots, the partial combine
      sums local experts only, and the trailing psum (a schedulable
      network op) completes it.

    Shared experts hold replicated weights and run on the block's local
    tokens (standard DeepSeek practice) — independent of the dispatch
    chain, which is what the paper's Fig. 1a overlap targets.
    """

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo,
                 token_sharded: bool, name="moe"):
        super().__init__()
        m = cfg.moe
        d = cfg.d_model
        self.token_sharded = token_sharded
        self.router = RouterOp(d, m, mesh)
        self.build = DispatchBuildOp(m, mesh)
        if token_sharded:
            self.a2a_in = MoEAllToAllOp(mesh, "dispatch")
            self.a2a_out = MoEAllToAllOp(mesh, "combine")
            self.combine = CombineOp()
        else:
            self.slice_local = ExpertSliceOp(m, mesh)
            self.combine = CombinePartialOp(m, mesh)
            self.ar = PsumOp(name="ar_moe")
            if mesh.fsdp:
                # resident ff-sharded experts: the partial-ff outputs
                # complete in the (tiny) activation psum below
                self.ar_dp = PsumOp(axis="data", name="ar_moe_dp")
        self.experts = ExpertFFN(d, m, mesh,
                                 ff_shard=not token_sharded)
        self.has_shared = m.n_shared > 0
        if self.has_shared:
            # replicated weights, local tokens: no collective, overlappable
            self.shared = MLPBlock(d, m.d_ff_expert * m.n_shared,
                                   MeshInfo(tp=1, dp=mesh.dp, pods=mesh.pods),
                                   name="shared_expert")
            self.add_shared = AddOp("add_shared")
        self.named(name)

    def forward(self, x):
        w, ve = self.router(x)
        if self.token_sharded:
            with mark("moe_dispatch"):
                buf, slot = self.build(x, ve)
                buf = self.a2a_in(buf)
            eout = self.experts(buf)
            with mark("moe_combine"):
                eout = self.a2a_out(eout)
                y = self.combine(eout, ve, slot, w)
        else:
            with mark("moe_dispatch"):
                buf, slot = self.build(x, ve)
                buf = self.slice_local(buf)
            eout = self.experts(buf)
            with mark("moe_combine"):
                y = self.combine(eout, ve, slot, w)
                y = self.ar(y)
                if hasattr(self, "ar_dp"):
                    y = self.ar_dp(y)
        if self.has_shared:
            with mark("moe_shared"):
                ys = self.shared(x)
            y = self.add_shared(y, ys)
        return y


class MoEDecoderLayer(Module):
    """Decoder layer with MoE FFN (train/prefill; SP collectives)."""

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo, sp: bool,
                 collect_kv=False, attn_impl=None):
        super().__init__()
        from .layers import AttentionOp
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.lay = lay
        self.sp = sp
        self.collect_kv = collect_kv
        self.ln1 = RMSNormOp(d, "ln_attn")
        if sp:
            self.ag1 = AllGatherOp(mesh, dim=1, name="ag_attn")
            self.fin1 = ReduceScatterOp(mesh, dim=1, name="rs_attn")
        else:
            self.fin1 = PsumOp(name="ar_attn")
        self.qkv = QKVProj(d, lay, mesh)
        self.rope = RopeOp(cfg.rope, cfg.rope_kwargs())
        self.attn = AttentionOp(lay, impl=attn_impl or mesh.attn_impl)
        self.oproj = OProj(d, lay, mesh)
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d, "ln_moe")
        # SP: the MoE consumes the sequence-sharded activations directly
        # (EP == DP over the model axis); no gather/reduce around the block.
        self.moe = MoEBlock(cfg, mesh, token_sharded=sp)
        self.add2 = AddOp("add_moe")
        self.named("moe_layer")

    def forward(self, *, x, positions):
        h = self.ln1(x)
        if self.sp:
            h = self.ag1(h)
        q, k, v = self.qkv(h)
        q, k = self.rope(q, k, positions)
        a = self.attn(q, k, v)
        a = self.oproj(a)
        a = self.fin1(a)
        x = self.add1(x, a)
        h = self.ln2(x)
        m = self.moe(h)
        x = self.add2(x, m)
        out = {"x": x}
        if self.collect_kv:
            out["k"], out["v"] = k, v
        return out


class MoEDecodeLayer(Module):
    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__()
        from .layers import DecodeAttentionOp
        d = cfg.d_model
        lay = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)
        self.lay = lay
        self.ln1 = RMSNormOp(d, "ln_attn")
        self.qkv = QKVProj(d, lay, mesh)
        self.rope = RopeOp(cfg.rope, cfg.rope_kwargs())
        self.attn = DecodeAttentionOp(lay)
        self.oproj = OProj(d, lay, mesh)
        self.fin1 = PsumOp(name="ar_attn")
        self.add1 = AddOp("add_attn")
        self.ln2 = RMSNormOp(d, "ln_moe")
        self.moe = MoEBlock(cfg, mesh, token_sharded=False)
        self.add2 = AddOp("add_moe")
        self.named("moe_layer")

    def forward(self, *, x, positions, cache_len, k_cache, v_cache):
        h = self.ln1(x)
        q, k, v = self.qkv(h)
        q, k = self.rope(q, k, positions)
        a, kc, vc = self.attn(q, k, v, k_cache, v_cache, cache_len)
        a = self.oproj(a)
        a = self.fin1(a)
        x = self.add1(x, a)
        h = self.ln2(x)
        m = self.moe(h)
        x = self.add2(x, m)
        return {"x": x, "k_cache": kc, "v_cache": vc}


from .base import (DenseDecodeLayer, DenseDecoderLayer, EmbedSegment,
                   LMBase, LogitsHead, TrainHead)


class MoELM(LMBase):
    """MoE LM over the shared segment machinery."""

    family = "moe"

    def __init__(self, cfg: ArchConfig, mesh: MeshInfo):
        super().__init__(cfg, mesh)
        self.layout = HeadLayout(cfg.n_heads, cfg.n_kv, mesh.tp, cfg.hd)

    def make_embed(self, phase):
        sp = self.cfg.seq_parallel and phase != "decode"
        return EmbedSegment(self.cfg, self.mesh, sp)

    def layer_stacks(self, phase):
        cfg, mesh = self.cfg, self.mesh
        stacks = []
        n_moe = cfg.n_layers
        if cfg.moe.first_layer_dense:
            n_moe -= 1
            if phase == "decode":
                dmod = DenseDecodeLayer(cfg, mesh)
                cmap = {"k_cache": "dense0_k_cache",
                        "v_cache": "dense0_v_cache"}
                stacks.append(("dense0", dmod, 1,
                               ("k_cache", "v_cache"), ("k_cache", "v_cache"),
                               {"input_map": dict(cmap),
                                "output_map": dict(cmap)}))
            else:
                dmod = DenseDecoderLayer(cfg, mesh, cfg.seq_parallel,
                                         collect_kv=(phase == "prefill"))
                omap = ({"k": "dense0.k", "v": "dense0.v"}
                        if phase == "prefill" else {})
                stacks.append(("dense0", dmod, 1, (),
                               ("k", "v") if phase == "prefill" else (),
                               {"output_map": omap}))
        if phase == "decode":
            mod = MoEDecodeLayer(cfg, mesh)
            stacks.append(("layers", mod, n_moe,
                           ("k_cache", "v_cache"), ("k_cache", "v_cache")))
        else:
            mod = MoEDecoderLayer(cfg, mesh, cfg.seq_parallel,
                                  collect_kv=(phase == "prefill"))
            stacks.append(("layers", mod, n_moe, (),
                           ("k", "v") if phase == "prefill" else ()))
        return stacks

    def make_head(self, phase):
        sp = self.cfg.seq_parallel and phase != "decode"
        if phase == "train":
            return TrainHead(self.cfg, self.mesh, sp)
        return LogitsHead(self.cfg, self.mesh, sp,
                          keep_last=(phase != "decode"))

    def cache_specs(self, stack_name, B_loc, s_max):
        lay = self.layout
        sds = jax.ShapeDtypeStruct((B_loc, s_max, lay.kv_local, lay.head_dim),
                                   jnp.bfloat16)
        return {"k_cache": sds, "v_cache": sds}
