"""Serving launcher: tiered async batched engine over a (smoke-sized)
model, built through the ``repro.api`` facade.

  python -m repro.launch.serve --arch chatglm3-6b --smoke \
      --requests 16 --max-new 16 --strategy dynamic

``--baseline`` reverts the engine to the synchronous fixed-batch shape
(single decode tier, one-request prefill, per-step host sync) for A/B
comparison against the tiered async default.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .. import api
from ..serve import Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    from repro.core.strategies.registry import strategy_names
    ap.add_argument("--strategy", default="dynamic",
                    choices=strategy_names(),
                    help="strategy registry name; 'dynamic' = built-in "
                         "pick table, 'auto' = cost-model autotuner "
                         "(verdicts persist via --plan-store)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-batch", type=int, default=4,
                    help="max requests packed into one prefill call")
    ap.add_argument("--baseline", action="store_true",
                    help="fixed-batch synchronous engine (no tiers, "
                         "batch-1 prefill, per-step host sync)")
    ap.add_argument("--plan-store", default=None,
                    help="persist lowered plans here (warm restarts)")
    args = ap.parse_args(argv)

    program = api.compile(args.arch, policy=args.strategy,
                          smoke=args.smoke,
                          plan_store_path=args.plan_store)
    params = program.init_params(0)
    scfg = ServeConfig(max_batch=args.max_batch, s_max=args.s_max,
                       prefill_buckets=(16, 32, 64),
                       prefill_batch=1 if args.baseline
                       else args.prefill_batch,
                       decode_tiers=(args.max_batch,) if args.baseline
                       else None,
                       async_host=not args.baseline)
    eng = program.serve(params, scfg)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for i in range(args.requests):
        n = int(rng.integers(4, 30))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, program.model.cfg.vocab,
                                               n, dtype=np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)  stats={eng.stats}")
    st = eng.stats
    tier_mix = {t: n for t, n in st["tier_steps"].items() if n}
    print(f"decode tier mix: {tier_mix}  "
          f"({st['host_syncs']} host syncs / {st['decode_steps']} decode "
          f"steps, {st['row_moves']} row moves, "
          f"{st['chunk_steps']} chunk steps)")
    ttfts = [r.first_token_s - r.submitted_s for r in done]
    print(f"TTFT p50={np.percentile(ttfts, 50)*1e3:.0f}ms "
          f"p99={np.percentile(ttfts, 99)*1e3:.0f}ms")
    eng.shutdown()
    program.close()
    return done


if __name__ == "__main__":
    main()
