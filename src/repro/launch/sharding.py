"""Global input/param sharding construction for the production mesh.

Everything the model knows locally (per-shard shapes from MeshInfo) is
lifted to global ShapeDtypeStructs + PartitionSpecs here:

  * params: ``model.param_pspecs(segs)`` tuples -> PartitionSpec
  * batch inputs: batch dim sharded over ('pod','data'); sequence dim of
    SP-sharded inputs ('vis') over 'model'
  * decode caches: batch dim over data axes, head/channel dim over
    'model' per ``model.decode_cache_layout()``
  * when global_batch < dp_total the batch is replicated over the data
    axes (the long_500k single-request case) — each data row redundantly
    computes the same step.
"""
from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _entry(e):
    if e is None or e == ():
        return None
    if isinstance(e, str):
        return e
    return e[0] if len(e) == 1 else tuple(e)


def spec_to_p(spec) -> P:
    if spec is None:
        return P()
    return P(*[_entry(e) for e in spec])


def param_pspec_tree(model, segs):
    """Tree of PartitionSpec matching the (stacked) param tree."""
    return jax.tree_util.tree_map(
        spec_to_p, model.param_pspecs(segs),
        is_leaf=lambda x: isinstance(x, tuple))


def global_param_specs(model, segs, mesh):
    """(ShapeDtypeStruct tree, NamedSharding tree) for the global params.
    ``Param.global_shape`` (declared at construction from the MeshInfo) is
    the global view; the pspec tree gives the matching PartitionSpecs."""
    shapes = model.param_shapes(segs, global_=True)
    pspecs = param_pspec_tree(model, segs)
    shds = jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    return shapes, shds


# special per-input extra sharding: name -> (dim, axis)
EXTRA_INPUT_SHARD = {"vis": (1, "model")}


def global_batch_specs(model, phase: str, seq_len: int, global_batch: int,
                       mesh, s_max: int = 0):
    """Global (sds, NamedSharding) dicts for the step's batch inputs
    (+ decode caches).  Returns (sds, shardings, B_loc, replicated)."""
    axis = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_total = axis.get("data", 1) * axis.get("pod", 1)
    tp = axis.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axis)
    replicated = global_batch < dp_total
    B_loc = max(1, global_batch // dp_total)

    # decode steps are single-token here (``seq_len`` is the cache depth
    # s_max, not the step width — chunked decode is a serve-engine path)
    step_len = 1 if phase == "decode" else seq_len
    binputs = model.batch_inputs(phase, B_loc, step_len, s_max=s_max)
    sdss, shds = {}, {}
    for name, (sds, bd) in binputs.items():
        gshape = list(sds.shape)
        dims = [None] * len(gshape)
        if bd is not None and not replicated:
            gshape[bd] *= dp_total
            dims[bd] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        if name in EXTRA_INPUT_SHARD:
            d, ax = EXTRA_INPUT_SHARD[name]
            gshape[d] *= axis.get(ax, 1)
            dims[d] = ax
        sdss[name] = jax.ShapeDtypeStruct(tuple(gshape), sds.dtype)
        shds[name] = NamedSharding(mesh, P(*dims))
    if phase == "decode":
        layout = model.decode_cache_layout()
        for name, sds in model.decode_cache_env(B_loc, s_max).items():
            bd, md = layout[name]
            gshape = list(sds.shape)
            dims = [None] * len(gshape)
            if not replicated:
                gshape[bd] *= dp_total
                dims[bd] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            gshape[md] = gshape[md] * tp
            dims[md] = "model"
            sdss[name] = jax.ShapeDtypeStruct(tuple(gshape), sds.dtype)
            shds[name] = NamedSharding(mesh, P(*dims))
    return sdss, shds, B_loc, replicated


def shard_specs_of(shardings):
    """NamedSharding tree -> PartitionSpec tree (for shard_map specs)."""
    return jax.tree_util.tree_map(
        lambda s: s.spec, shardings,
        is_leaf=lambda x: isinstance(x, NamedSharding))
