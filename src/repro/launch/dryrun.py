import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the step function (train_step / prefill_step / decode_step,
per the shape's kind) is shard_mapped over the production mesh, lowered
against global ShapeDtypeStructs (no allocation), compiled, and the
compiled artifact's memory_analysis / cost_analysis / HLO collective
bytes are recorded to results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch chatglm3-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--strategy dynamic]
"""
import argparse
import json
import time
import traceback

import jax

from .. import api
from ..configs import get_config, list_archs
from ..configs.base import SHAPES
from ..core.strategies import get_strategy
from ..roofline.hlo import analyze as hlo_analyze
from ..roofline.model import roofline_terms
from .mesh import make_mesh_info, make_production_mesh, mesh_shape_dict

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 512k dense-KV decode is not "
                "sub-quadratic-capable (DESIGN.md §Arch-applicability)")
    return None


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: str = "dynamic", verbose: bool = True,
             attn_sub: bool = False, remat_policy: str = "full",
             verify: str = "warn") -> dict:
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = cfg.fsdp_train if shape.kind == "train" else cfg.fsdp_serve
    minfo = make_mesh_info(mesh, fsdp=fsdp, attn_impl="chunked",
                           fsdp_resident=(shape.kind == "decode"))
    program = api.compile(cfg, policy=get_strategy(strategy), mesh=mesh,
                          mesh_info=minfo, verify=verify)

    t0 = time.perf_counter()
    if shape.kind == "train":
        step = program.train_step(global_batch=shape.global_batch,
                                  seq_len=shape.seq_len,
                                  remat_policy=remat_policy)
    elif shape.kind == "prefill":
        step = program.prefill(global_batch=shape.global_batch,
                               seq_len=shape.seq_len)
    else:
        step = program.decode_tiers(
            max_batch=shape.global_batch, s_max=shape.seq_len,
            tiers=(shape.global_batch,))[shape.global_batch]
    t_build = time.perf_counter() - t0

    jitted = jax.jit(step.fn, in_shardings=step.in_shardings,
                     donate_argnums=step.donate)
    with mesh:
        t0 = time.perf_counter()
        lowered = jitted.lower(*step.in_sdss)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):    # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    scopes = (("flashable_attention", "flashable_decode")
              if attn_sub else ())
    hstats = hlo_analyze(hlo, substitute_scopes=scopes)
    coll = hstats["collectives"]

    chips = mesh.devices.size
    n_total, n_active = cfg.param_count()
    rl = roofline_terms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=hstats["flops"],
        hlo_bytes=hstats["hbm_bytes"],
        coll_payload=coll, n_params=n_total, n_active=n_active,
        tokens=shape.tokens_per_step, train=(shape.kind == "train"),
        axis_size=mesh_shape_dict(mesh).get("model", 16))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "strategy": strategy, "chips": chips,
        "attn_sub": attn_sub,
        "substituted_bytes": hstats.get("substituted_bytes", {}),
        "phase": shape.kind,
        "build_s": round(t_build, 2), "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            # memory_analysis reports the per-device (partitioned) module
            "peak_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)),
        },
        "cost": {k: float(v) for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "collective_payload_bytes": coll,
        "roofline": rl.to_json(),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK  "
              f"compile={t_compile:.1f}s  "
              f"peak/dev={rec['memory']['peak_per_device']/2**30:.2f}GiB  "
              f"flops={rec['cost'].get('flops', 0):.3e}  "
              f"coll={coll.get('total', 0):.3e}B  "
              f"bottleneck={rl.bottleneck}")
    return rec


def save_record(rec: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "__pallas" if rec.get("attn_sub") else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="dynamic")
    ap.add_argument("--attn-sub", action="store_true",
                    help="substitute the Pallas attention kernels' cost "
                         "model for the tagged scopes")
    ap.add_argument("--remat-policy", default="full",
                    choices=("full", "dots"))
    ap.add_argument("--verify", default="warn",
                    choices=("off", "warn", "strict"),
                    help="static plan verification mode for every cell "
                         "(core.verify; strict fails the cell on "
                         "error-severity diagnostics)")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   strategy=args.strategy,
                                   attn_sub=args.attn_sub,
                                   remat_policy=args.remat_policy,
                                   verify=args.verify)
                    save_record(rec)
                    if rec["status"] == "skipped":
                        print(f"[{arch} × {shape} × "
                              f"{'pod2x16x16' if mp else 'pod16x16'}] "
                              f"SKIP: {rec['reason']}")
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells OK")


if __name__ == "__main__":
    main()
