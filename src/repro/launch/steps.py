"""Step-function builders shared by dryrun.py / train.py / serve.py.

Each builder returns ``(fn, in_sdss, in_shardings, arg_donate)`` where
``fn`` is the jit-able global function (shard_map already applied),
``in_sdss`` the global ShapeDtypeStructs to lower with, and
``in_shardings`` the matching NamedShardings.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .._deprecation import warn_once
from ..configs.base import ShapeConfig
from ..core.plan_store import checkpoint_plan_store, resolve_plan_store
from ..core.scheduler import ScheduleContext
from ..models.base import build_forward
from ..train.step import TrainStepConfig, _build_train_step
from .mesh import mesh_shape_dict
from .sharding import global_batch_specs, global_param_specs, shard_specs_of


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _sched_info(arch, shape: ShapeConfig, B_loc, mesh):
    return ScheduleContext(
        local_batch=B_loc, global_batch=shape.global_batch,
        seq_len=shape.seq_len, phase=shape.kind, arch=arch,
        mesh_shape=mesh_shape_dict(mesh))


def _shard_map(fn, mesh, in_specs, out_specs):
    return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _opt_specs(param_sdss, param_specs):
    """Mirror param sharding for AdamW m/v (f32) + replicated count."""
    def leafy(t, fn):
        return jax.tree_util.tree_map(fn, t)

    m_sdss = leafy(param_sdss, lambda s: jax.ShapeDtypeStruct(
        s.shape, jnp.float32))
    state_sdss = jax.tree_util.tree_map(
        lambda s: {"m": s, "v": s}, m_sdss,
        is_leaf=lambda x: hasattr(x, "shape"))
    state_specs = jax.tree_util.tree_map(
        lambda p: {"m": p, "v": p}, param_specs,
        is_leaf=lambda x: isinstance(x, P))
    return ({"state": state_sdss, "count": jax.ShapeDtypeStruct((), jnp.int32)},
            {"state": state_specs, "count": P()})


def build_global_train_step(model, scheduler, shape: ShapeConfig, mesh,
                            tcfg: TrainStepConfig = None,
                            remat_policy: str = "full",
                            lowered: bool = None,
                            plan_store=None,
                            plan_store_path: str = None):
    """Deprecated pre-facade entry point — use
    ``repro.api.compile(model, policy=..., mesh=mesh).train_step(...)``."""
    warn_once("repro.launch.steps.build_global_train_step",
              "repro.api.compile(..., mesh=mesh).train_step(...)")
    return _build_global_train_step(
        model, scheduler, shape, mesh, tcfg=tcfg,
        remat_policy=remat_policy, lowered=lowered, plan_store=plan_store,
        plan_store_path=plan_store_path)


def _build_global_train_step(model, scheduler, shape: ShapeConfig, mesh,
                             tcfg: TrainStepConfig = None,
                             remat_policy: str = "full",
                             lowered: bool = None,
                             plan_store=None,
                             plan_store_path: str = None):
    # lowered=None defers to tcfg (default True); an explicit bool wins
    tcfg = tcfg or TrainStepConfig(remat=True, remat_policy=remat_policy)
    if lowered is not None and lowered != tcfg.lowered:
        import dataclasses as _dc
        tcfg = _dc.replace(tcfg, lowered=lowered)
    batch_sdss, batch_shd, B_loc, _ = global_batch_specs(
        model, "train", shape.seq_len, shape.global_batch, mesh)
    info = _sched_info(model.cfg.name, shape, B_loc, mesh)
    step, segs, _, init_opt = _build_train_step(
        model, scheduler, B_loc, shape.seq_len, tcfg, info,
        plan_store=plan_store, plan_store_path=plan_store_path)
    p_sdss, p_shd = global_param_specs(model, segs, mesh)
    p_specs = shard_specs_of(p_shd)
    opt_sdss, opt_specs = _opt_specs(p_sdss, p_specs)
    batch_specs = shard_specs_of(batch_shd)
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(), "tokens": P()}
    fn = _shard_map(step, mesh,
                    in_specs=(p_specs, opt_specs, batch_specs, P()),
                    out_specs=(p_specs, opt_specs, metric_specs))
    in_sdss = (p_sdss, opt_sdss, batch_sdss,
               jax.ShapeDtypeStruct((), jnp.int32))
    opt_shd = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), opt_specs,
        is_leaf=lambda x: isinstance(x, P))
    in_shd = (p_shd, opt_shd, batch_shd, NamedSharding(mesh, P()))
    return fn, in_sdss, in_shd, (0, 1), init_opt, segs


def _logits_spec(mesh, replicated):
    dp = _dp_axes(mesh)
    b = None if replicated else (dp if len(dp) > 1 else dp[0])
    return P(b, None, "model")


def _kv_collect_specs(out_env, mesh, replicated):
    """PartitionSpecs for prefill-collected kv stacks by rank."""
    dp = _dp_axes(mesh)
    b = None if replicated else (dp if len(dp) > 1 else dp[0])
    specs = {}
    for k, v in out_env.items():
        if v.ndim == 5:
            specs[k] = P(None, b, None, "model", None)
        else:
            specs[k] = P(b, None, "model", None)
    return specs


def build_global_prefill_step(model, scheduler, shape: ShapeConfig, mesh,
                              lowered: bool = True,
                              plan_store=None,
                              plan_store_path: str = None):
    """Deprecated pre-facade entry point — use
    ``repro.api.compile(model, policy=..., mesh=mesh).prefill(...)``."""
    warn_once("repro.launch.steps.build_global_prefill_step",
              "repro.api.compile(..., mesh=mesh).prefill(...)")
    return _build_global_prefill_step(
        model, scheduler, shape, mesh, lowered=lowered,
        plan_store=plan_store, plan_store_path=plan_store_path)


def _build_global_prefill_step(model, scheduler, shape: ShapeConfig, mesh,
                               lowered: bool = True,
                               plan_store=None,
                               plan_store_path: str = None):
    """``plan_store``: a shared ``PlanStore`` — building several prefill
    bucket steps against one store lowers each segment once and
    specializes the rest (fingerprint v2 scopes entries by the model's
    op-closure config, so one store may serve several meshes).
    ``plan_store_path``: persist/warm-start that store on disk, so a
    server restart builds every known bucket from restored lowerings."""
    plan_store = resolve_plan_store(plan_store, plan_store_path)
    batch_sdss, batch_shd, B_loc, repl = global_batch_specs(
        model, "prefill", shape.seq_len, shape.global_batch, mesh,
        s_max=shape.seq_len)
    info = _sched_info(model.cfg.name, shape, B_loc, mesh)
    segs, binputs = model.build_segments("prefill", B_loc, shape.seq_len,
                                         s_max=shape.seq_len)
    fwd = build_forward(segs, scheduler, info, lowered=lowered,
                        plan_cache=plan_store,
                        op_config=model.op_closure_config())
    checkpoint_plan_store(plan_store)
    p_sdss, p_shd = global_param_specs(model, segs, mesh)
    p_specs = shard_specs_of(p_shd)
    batch_specs = shard_specs_of(batch_shd)

    # collected kv env keys + their local shapes (from the traced graphs)
    kv_shapes = {}
    for seg in segs:
        for k in seg.scan_outputs:
            ref = seg.graph.tensors[seg.graph.outputs[k]]
            shape = ((seg.count,) + ref.shape if seg.count > 1
                     else ref.shape)
            kv_shapes[seg.collect_key(k)] = jax.ShapeDtypeStruct(
                shape, ref.dtype)

    def prefill_step(params, batch):
        out = fwd(params, batch)
        res = {"logits": out["logits"]}
        for k in kv_shapes:
            res[k] = out[k]
        return res

    out_specs = {"logits": _logits_spec(mesh, repl)}
    out_specs.update(_kv_collect_specs(kv_shapes, mesh, repl))
    fn = _shard_map(prefill_step, mesh,
                    in_specs=(p_specs, batch_specs),
                    out_specs=out_specs)
    return fn, (p_sdss, batch_sdss), (p_shd, batch_shd), (), segs


def build_global_decode_tiers(model, scheduler, shape: ShapeConfig, mesh,
                              tiers=None,
                              lowered: bool = True,
                              plan_store=None,
                              plan_store_path: str = None) -> dict:
    """Deprecated pre-facade entry point — use
    ``repro.api.compile(model, policy=..., mesh=mesh).decode_tiers(...)``."""
    warn_once("repro.launch.steps.build_global_decode_tiers",
              "repro.api.compile(..., mesh=mesh).decode_tiers(...)")
    return _build_global_decode_tiers(
        model, scheduler, shape, mesh, tiers=tiers, lowered=lowered,
        plan_store=plan_store, plan_store_path=plan_store_path)


def _build_global_decode_tiers(model, scheduler, shape: ShapeConfig, mesh,
                               tiers=None,
                               lowered: bool = True,
                               plan_store=None,
                               plan_store_path: str = None) -> dict:
    """Decode steps at every batch tier against one shared PlanStore —
    the launch-layer analogue of the serve engine's tiered captures.

    ``tiers`` are *global* decode batch sizes (default: powers of two up
    to ``shape.global_batch``).  Decode graphs are structurally identical
    across batch sizes, so the first tier pays the lowering and every
    further tier derives from it via ``specialize()`` (PlanStore shares;
    the inner cache key carries the tier).  Returns
    ``{tier: (fn, in_sdss, in_shardings, donate, segs)}``.
    """
    import dataclasses as _dc

    from ..serve.engine import pow2_tiers
    plan_store = resolve_plan_store(plan_store, plan_store_path)
    tiers = tuple(tiers or pow2_tiers(shape.global_batch))
    out = {}
    for tier in tiers:
        tshape = _dc.replace(shape, name=f"{shape.name}@{tier}",
                             global_batch=tier)
        out[tier] = _build_global_decode_step(
            model, scheduler, tshape, mesh, lowered=lowered,
            plan_store=plan_store)
    checkpoint_plan_store(plan_store)
    return out


def build_global_decode_step(model, scheduler, shape: ShapeConfig, mesh,
                             lowered: bool = True,
                             plan_store=None,
                             plan_store_path: str = None):
    """Deprecated pre-facade entry point — use
    ``repro.api.compile(model, policy=..., mesh=mesh).decode_tiers(...)``."""
    warn_once("repro.launch.steps.build_global_decode_step",
              "repro.api.compile(..., mesh=mesh).decode_tiers(...)")
    return _build_global_decode_step(
        model, scheduler, shape, mesh, lowered=lowered,
        plan_store=plan_store, plan_store_path=plan_store_path)


def _build_global_decode_step(model, scheduler, shape: ShapeConfig, mesh,
                              lowered: bool = True,
                              plan_store=None,
                              plan_store_path: str = None):
    plan_store = resolve_plan_store(plan_store, plan_store_path)
    s_max = shape.seq_len
    batch_sdss, batch_shd, B_loc, repl = global_batch_specs(
        model, "decode", shape.seq_len, shape.global_batch, mesh,
        s_max=s_max)
    info = _sched_info(model.cfg.name, shape, B_loc, mesh)
    segs, binputs = model.build_segments("decode", B_loc, 1, s_max=s_max)
    fwd = build_forward(segs, scheduler, info, lowered=lowered,
                        plan_cache=plan_store,
                        op_config=model.op_closure_config())
    checkpoint_plan_store(plan_store)
    p_sdss, p_shd = global_param_specs(model, segs, mesh)
    p_specs = shard_specs_of(p_shd)
    batch_specs = shard_specs_of(batch_shd)
    cache_keys = sorted(model.decode_cache_env(B_loc, s_max))

    def decode_step(params, batch):
        out = fwd(params, batch)
        res = {"logits": out["logits"]}
        for k in cache_keys:
            res[k] = out[k]
        return res

    out_specs = {"logits": _logits_spec(mesh, repl)}
    for k in cache_keys:
        out_specs[k] = batch_specs[k]
    fn = _shard_map(decode_step, mesh,
                    in_specs=(p_specs, batch_specs),
                    out_specs=out_specs)
    return fn, (p_sdss, batch_sdss), (p_shd, batch_shd), (1,), segs
