"""Training launcher.

Single-host execution path (the multi-device production path is exercised
by dryrun.py; this entry point actually *runs* steps, so it sizes the
model to the local device set — CPU here, a real pod on TPU):

  python -m repro.launch.train --arch smollm-135m --steps 200 \
      --batch 8 --seq 256 --strategy dynamic --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import api
from ..data import DataConfig, SyntheticBackend, TokenPipeline
from ..ft.elastic import FailureSimulator
from ..optim import AdamWConfig
from ..train import TrainLoopConfig, TrainStepConfig, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="dynamic")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a simulated failure at this step")
    args = ap.parse_args(argv)

    program = api.compile(args.arch, policy=args.strategy,
                          smoke=args.smoke)
    cfg = program.model.cfg
    tcfg = TrainStepConfig(
        optimizer=AdamWConfig(lr=args.lr, quantized=args.quantized_opt),
        remat=args.remat, compress_grads=args.grad_compress,
        warmup=max(args.steps // 20, 1), total_steps=args.steps)
    step = program.train_step(args.batch, args.seq, cfg=tcfg)
    params = program.init_params(0, phase="train")
    opt = step.init_opt(params)
    jit_step = jax.jit(step.fn, donate_argnums=(0, 1))

    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"strategy={args.strategy}")

    pipe = TokenPipeline(SyntheticBackend(cfg.vocab),
                         DataConfig(seq_len=args.seq,
                                    global_batch=args.batch))

    def to_device(b):
        pos = np.broadcast_to(np.arange(args.seq, dtype=np.int32),
                              (args.batch, args.seq))
        if cfg.rope == "mrope":
            pos = np.broadcast_to(pos, (3, args.batch, args.seq))
        out = {"ids": jnp.asarray(b["ids"]), "labels": jnp.asarray(b["labels"]),
               "positions": jnp.asarray(pos)}
        if cfg.family == "vlm":
            out["vis"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                   jnp.bfloat16)
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros((args.batch, args.seq, cfg.d_model),
                                      jnp.bfloat16)
        return out

    sim = (FailureSimulator(crash_steps=(args.crash_at,))
           if args.crash_at >= 0 else None)
    t0 = time.perf_counter()
    params, opt, hist = train_loop(
        jit_step, params, opt, pipe,
        TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, log_every=10),
        failure_sim=sim, to_device=to_device, log=print)
    dt = time.perf_counter() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({toks/dt:.0f} tok/s), final loss "
          f"{hist[-1]['loss']:.4f} (first {hist[0]['loss']:.4f})")
    return hist


if __name__ == "__main__":
    main()
