"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls ``make_production_mesh``.

Axes:
  pod    — data parallelism across pods (pure DP; also hosts the optional
           pipeline driver in dist/pipeline.py)
  data   — data parallelism within a pod (+ FSDP param sharding)
  model  — tensor/sequence/expert parallelism within a pod row
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_mesh_info(mesh, *, fsdp: bool = False, attn_impl: str = "chunked",
                   fsdp_resident: bool = False):
    from ..models.layers import MeshInfo
    d = mesh_shape_dict(mesh)
    return MeshInfo(tp=d.get("model", 1), dp=d.get("data", 1),
                    pods=d.get("pod", 1), fsdp=fsdp,
                    fsdp_resident=fsdp_resident, attn_impl=attn_impl)
