"""DynaFlow quickstart: decouple a model's execution schedule from its code.

1. Write a model as plain sequential Modules/Ops (no scheduling logic).
2. Trace it into an OpGraph; partition with annotations (Fig. 5 APIs).
3. Write a scheduler in ~15 lines of Python (Fig. 6 APIs).
4. Compile: ``repro.api.compile`` turns (model, policy) into a Program —
   any valid schedule computes exactly the same result, and the Program
   owns plan recording, lowering and caching behind one call.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import Mark, OpSchedulerBase, partition, trace
from repro.core.module import Module, Op, Param, mark


# ---- 1. a plain sequential model -----------------------------------------


class Linear(Op):
    resource = "compute"

    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class FakeCollective(Op):
    """Stands in for an all-reduce (network-bound) in this 1-chip demo."""

    resource = "network"

    def __init__(self, name):
        super().__init__()
        self.named(name)

    def kernel(self, p, x):
        return x  # lax.psum(x, 'model') inside shard_map


class Concat(Op):
    resource = "memory"

    def kernel(self, p, a, b):
        return jnp.concatenate([a, b], -1)


class TwoBranchModel(Module):
    def __init__(self, d=32):
        super().__init__()
        self.stem = Linear(d, d, "stem")
        self.heavy = Linear(d, d, "heavy_gemm")
        self.comm = FakeCollective("allreduce")
        self.cat = Concat().named("concat")
        self.out = Linear(2 * d, 8, "out")

    def forward(self, x):
        h = self.stem(x)
        with mark("overlap_me"):     # Fig. 5: annotate a region
            a = self.comm(h)         # network-bound branch
            b = self.heavy(h)        # compute-bound branch (independent!)
        return self.out(self.cat(a, b))


# ---- 2. trace + partition --------------------------------------------------

model = TwoBranchModel()
example = {"x": jax.ShapeDtypeStruct((8, 32), jnp.float32)}
graph = trace(model, example)
print("captured operator graph:")
print(graph.pretty())

coarse = partition(graph, [Mark("overlap_me")])
print("\nafter partition([Mark('overlap_me')]):")
print(coarse.pretty())


# ---- 3. a custom scheduler (Fig. 6): issue network first, overlap ---------


class OverlapFirst(OpSchedulerBase):
    def schedule(self, ctx):
        while True:
            ready = ctx.get_ready_ops()
            if not ready:
                break
            nets = [h for h in ready if ctx.resource_of(h) == "network"]
            for h in nets:
                ctx.execute(h)          # collective issued first...
            for h in ctx.get_ready_ops():
                ctx.execute(h)          # ...compute fills its window


class SplitBatch(OpSchedulerBase):
    def schedule(self, ctx):
        ctx.split([4, 4])               # two micro-batches
        ctx.run_rest_sequential()


# ---- 4. every schedule computes the same function --------------------------
# repro.api.compile is the whole integration: model (or traced graph) +
# policy in, a Program out — plan recording, lowering and the PlanStore
# are its problem, not the user's.

params = model.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
baseline = repro.api.compile(model, policy="sequential",
                             example_inputs=example)
want = baseline(params, {"x": x})["out"]

for sched in (OverlapFirst(), SplitBatch()):
    program = repro.api.compile(model, policy=sched,
                                example_inputs=example)
    print(f"\n{type(sched).__name__} plan:")
    print(program.plan(local_batch=8).pretty())
    got = program(params, {"x": x})["out"]
    np.testing.assert_allclose(got, want, atol=1e-5)
    print("=> output identical to sequential execution")

print("\nquickstart OK")
