"""Fault-tolerant training driver: a ~135M-class architecture (smoke-sized
for CPU), synthetic data pipeline, async checkpoints, a simulated node
crash mid-run, exact-resume, and gradient compression — the full
large-scale training substrate exercised end to end.

Run:  PYTHONPATH=src python examples/train_ft.py [--steps 120]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.data import DataConfig, SyntheticBackend, TokenPipeline
from repro.ft.elastic import FailureSimulator
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, TrainStepConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--crash-at", type=int, default=60)
    args = ap.parse_args()

    tcfg = TrainStepConfig(
        optimizer=AdamWConfig(lr=1e-3, quantized=True),
        remat=False, compress_grads=True,
        warmup=10, total_steps=args.steps)
    # the whole integration: arch + policy in, a trainable Program out
    program = repro.api.compile(args.arch, policy="dynamic", smoke=True)
    cfg = program.model.cfg
    step = program.train_step(args.batch, args.seq, cfg=tcfg)
    params = program.init_params(0, phase="train")
    opt = step.init_opt(params)
    n = sum(int(np.prod(p.shape))
            for p in jax.tree_util.tree_leaves(params))
    print(f"training {cfg.name}: {n/1e6:.2f}M params, "
          f"int8 AdamW second moment, int8-compressed DP grads")

    class PatternBackend(SyntheticBackend):
        """Learnable synthetic stream: next token = (id + 7) mod vocab
        with a small amount of noise — loss can actually fall."""

        def batch(self, dcfg, step):
            b = super().batch(dcfg, step)
            ids = b["ids"]
            labels = (ids + 7) % self.vocab
            flip = (ids % 17) == 0
            labels = np.where(flip, ids, labels)
            return {"ids": ids, "labels": labels.astype(np.int32)}

    pipe = TokenPipeline(PatternBackend(cfg.vocab),
                         DataConfig(seq_len=args.seq,
                                    global_batch=args.batch))

    def to_dev(b):
        return {"ids": jnp.asarray(b["ids"]),
                "labels": jnp.asarray(b["labels"]),
                "positions": jnp.broadcast_to(
                    jnp.arange(args.seq, dtype=jnp.int32),
                    (args.batch, args.seq))}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        sim = FailureSimulator(crash_steps=(args.crash_at,))
        t0 = time.perf_counter()
        params, opt, hist = train_loop(
            jax.jit(step.fn, donate_argnums=(0, 1)), params, opt, pipe,
            TrainLoopConfig(steps=args.steps, ckpt_dir=ckpt_dir,
                            ckpt_every=25, log_every=20),
            failure_sim=sim, to_device=to_dev, log=print)
        dt = time.perf_counter() - t0
    losses = [h["loss"] for h in hist]
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"injected failures: {sim.injected}")
    assert losses[-1] < losses[0]
    assert sim.injected == [("crash", args.crash_at)]
    print("train_ft OK (crashed, restored, converged)")


if __name__ == "__main__":
    main()
