"""Write-your-own intra-device parallelism strategy (paper Fig. 7).

Implements a DBO-style scheduler from scratch in ~20 lines against the
real deepseek-moe layer graph, then scores it with the plan-level overlap
model against the built-in strategies — the paper's rapid-prototyping
workflow (§5.3.5: Flux was validated and REJECTED the same way).

Since PR 5 the selection is programmable too: the last section wraps
MyDBO in a ``StrategyPolicy`` (~8 lines) so it only fires on large MoE
prefill buckets and every other context falls through to cheap built-ins
— the paper's "dynamic" headline as user code.

Run:  PYTHONPATH=src python examples/custom_strategy.py
"""
from repro.configs import get_config
from repro.core import (Mark, OpSchedulerBase, by_phase,
                        by_token_threshold, first_viable, has_ops,
                        partition, record_plan, resolve_strategy, when)
from repro.core.plan import OpHandle
from repro.core.scheduler import ScheduleContext
from repro.core.strategies import (get_strategy, register_strategy,
                                   tunable_candidates)
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.roofline.overlap import plan_overlap, split_weight_penalty


# ---- the paper's Fig. 7(a-c) example, written by a "user" -----------------


class MyDBO(OpSchedulerBase):
    """Attention merged, MoE split in two, a2a's interleaved."""

    def partition_rules(self):
        return [Mark("moe_dispatch"), Mark("moe_combine"),
                Mark("moe_shared")]

    def schedule(self, ctx):
        if ctx.info.local_batch < 2:          # dynamic context check
            ctx.run_rest_sequential()
            return
        ctx.split([ctx.info.local_batch // 2,
                   ctx.info.local_batch - ctx.info.local_batch // 2])
        g = ctx.graph
        moe = {h.oid for h in ctx.find(r"moe_dispatch|moe_combine|"
                                       r"expert_ffn|moe_shared")}
        for oid in g.topo_order():
            n = g.nodes[oid]
            if oid in moe:
                continue                       # interleaved below
            hs = tuple(OpHandle(oid, i, n.name) for i in (0, 1))
            ctx.execute(hs if g.splittable(oid) else hs[:1])
            if oid + 1 in moe:                 # entering the MoE region
                while True:
                    ready = [h for i in (0, 1)
                             for h in ctx.get_ready_ops(i)
                             if h.oid in moe]
                    if not ready:
                        break
                    nets = [h for h in ready
                            if ctx.resource_of(h) == "network"]
                    ctx.execute(nets[0] if nets else ready[0])


def main():
    cfg = get_config("deepseek-moe-16b")
    model = build_model(cfg, MeshInfo(tp=16, dp=16, attn_impl="chunked"))
    segs, _ = model.build_segments("prefill", 8, 2048, s_max=2048)
    seg = max((s for s in segs if s.count > 1),
              key=lambda s: len(s.graph.nodes))
    info = ScheduleContext(local_batch=8, seq_len=2048, phase="prefill",
                           arch=cfg.name)

    for fabric, bw in (("pod ICI", 1.0), ("multi-node DCN (~1/8)", 0.125)):
        print(f"\n--- fabric: {fabric} ---")
        print(f"{'strategy':14s}{'t_modeled':>12s}{'coll exposed':>14s}")
        results = {}
        for name in ("sequential", "sbo", "dbo", "mine"):
            strat = (MyDBO() if name == "mine"
                     else get_strategy(name, **({"min_tokens": 1}
                                                if name == "dbo" else {})))
            g = seg.graph
            if strat.partition_rules():
                g = partition(g, strat.partition_rules(), default_depth=2)
            plan = record_plan(g, strat, info)
            pen = split_weight_penalty(g, plan.num_mb)
            rep = plan_overlap(g, plan, tp=16, extra_weight_read_bytes=pen,
                               bw_scale=bw)
            results[name] = rep
            print(f"{name:14s}{rep.t_overlapped*1e3:11.3f}ms"
                  f"{rep.coll_exposed*1e3:13.3f}ms")
        speed = (results["sequential"].t_overlapped
                 / results["mine"].t_overlapped)
        print(f"MyDBO modeled speedup vs sequential: {speed:.3f}x")

    # ---- static verification: catch schedule bugs before any TPU -------
    # The verifier replays the plan's data flow and reports *every*
    # violation as a typed diagnostic (repro.core.verify.CODES) instead
    # of an opaque first-error crash.  A clean MyDBO plan:
    from repro.core import ExecutionPlan, verify
    g = partition(seg.graph, MyDBO().partition_rules(), default_depth=2)
    info = ScheduleContext(local_batch=8, seq_len=2048, phase="prefill",
                           arch=cfg.name)
    plan = record_plan(g, MyDBO(), info)
    report = verify(g, plan, lint=True)
    assert report.ok
    print(f"\nMyDBO plan verified: {report.pretty()}")
    # ...and the same plan with one step dropped — every downstream
    # consequence reported with op + micro-batch provenance:
    broken = ExecutionPlan(plan.steps[:-1], plan.split_sizes,
                           plan.graph_fingerprint)
    bad = verify(g, broken)
    assert not bad.ok
    print(f"one step dropped -> {len(bad.errors)} typed diagnostic(s), "
          f"e.g.\n  {bad.errors[0]}")

    # ---- context-conditional selection: MyDBO as a StrategyPolicy ------
    # 8 lines turn the scheduler into a policy: large MoE prefill buckets
    # get MyDBO, small ones SBO, decode always sequential.  The policy
    # drops straight into repro.api.compile(..., policy=my_policy) and
    # its identity salts the PlanStore, so swapping it never replays a
    # stale plan.
    my_policy = by_phase(
        decode=get_strategy("sequential"),
        default=by_token_threshold(
            [(2048, get_strategy("sbo"))],
            above=first_viable(when(has_ops(r"moe_a2a|expert_ffn"),
                                    MyDBO()),
                               default=get_strategy("nanoflow"))))
    print("\npolicy resolution per context:")
    for phase, b, s in (("prefill", 8, 2048), ("prefill", 2, 128),
                        ("decode", 8, 1)):
        ctx = ScheduleContext(local_batch=b, seq_len=s, phase=phase,
                              arch=cfg.name)
        sched = resolve_strategy(my_policy, ctx, graph=seg.graph)
        print(f"  {phase:8s} B={b:2d} S={s:5d} -> "
              f"{type(sched).__name__}")
    assert isinstance(resolve_strategy(
        my_policy, ScheduleContext(local_batch=8, seq_len=2048,
                                   phase="prefill", arch=cfg.name),
        graph=seg.graph), MyDBO)
    # ---- one registration makes MyDBO a first-class name ---------------
    # ``policy="my_dbo"`` now works through repro.api.compile and the
    # launch --strategy flags, and ``policy="auto"`` ranks it against
    # every built-in with the same cost model used above.
    register_strategy("my_dbo", MyDBO)
    assert isinstance(get_strategy("my_dbo"), MyDBO)
    assert ("my_dbo", {}) in list(tunable_candidates())
    print('registered "my_dbo": usable as policy="my_dbo" and swept by '
          'policy="auto"')
    print("custom_strategy OK — 20 lines of user Python + an 8-line "
          "policy, validated before touching a TPU")


if __name__ == "__main__":
    main()
