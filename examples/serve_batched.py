"""End-to-end serving driver: batched requests through the DynaFlow engine.

Serves a (smoke-sized) chatglm3 with bucketed prefill, continuous-batching
decode, and the dynamic scheduler choosing per-bucket plans — the paper's
deployment story in miniature.  Afterwards the server is "restarted": a
second engine warm-starts from the persisted PlanStore and serves its
first request without re-lowering a single plan (restore hits + shares
only — the cross-process half of the capture/replay story).

Run:  PYTHONPATH=src python examples/serve_batched.py [--requests 24]
"""
import argparse
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import Request, ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--strategy", default="dynamic")
    ap.add_argument("--plan-store", default=None,
                    help="persist lowered plans here (default: a temp file)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=128)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))

    store_path = args.plan_store or os.path.join(
        tempfile.mkdtemp(prefix="dynaflow-"), "plan_store.dfps")
    serve_cfg = ServeConfig(max_batch=8, s_max=128,
                            prefill_buckets=(16, 32, 64),
                            plan_store_path=store_path)
    eng = ServeEngine(model, params, get_strategy(args.strategy), serve_cfg)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        n = int(rng.integers(4, 50))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    ttft = [r.first_token_s - r.submitted_s for r in done]
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"TTFT p50={np.percentile(ttft, 50)*1e3:.0f}ms "
          f"p99={np.percentile(ttft, 99)*1e3:.0f}ms")
    st = eng.stats
    print(f"decode tier mix: "
          f"{ {t: n for t, n in st['tier_steps'].items() if n} } "
          f"({st['host_syncs']} host syncs / {st['decode_steps']} decode "
          f"steps, {st['chunk_steps']} chunk steps)")
    print(f"engine stats: {st}")
    ps = eng.store.snapshot()
    print(f"plan store: {ps['exec_misses']} builds, {ps['exec_hits']} "
          f"replays (the CUDA-graph-capture analogue); "
          f"{ps['misses']} lowered, {ps['shares']} shared across buckets "
          f"(share rate {ps['share_rate']:.0%})")
    assert all(len(r.output) == args.max_new for r in done)
    eng.shutdown()

    # -- "restart" the server: warm-start from the persisted PlanStore ----
    # A fresh engine (fresh process in production) restores the canonical
    # lowerings and serves its first request with zero lower() calls.
    print(f"\nrestarting from {store_path} "
          f"({os.path.getsize(store_path)} bytes)...")
    eng2 = ServeEngine(model, params, get_strategy(args.strategy),
                       serve_cfg)
    t0 = time.perf_counter()
    eng2.submit(Request(rid=10_000,
                        prompt=rng.integers(0, cfg.vocab, 20,
                                            dtype=np.int32),
                        max_new_tokens=4))
    eng2.run()
    dt = time.perf_counter() - t0
    ps2 = eng2.store.snapshot()
    print(f"first request after restart: {dt*1e3:.0f}ms; "
          f"{ps2['restore_hits']} restored lowerings, {ps2['shares']} "
          f"shared, {ps2['misses']} cold lowers")
    assert ps2["misses"] == 0, (
        f"warm-started engine re-lowered {ps2['misses']} plans: {ps2}")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
