"""End-to-end serving driver: batched requests through the DynaFlow engine.

The whole integration is one ``repro.api.compile`` call: arch + strategy
policy + KV cache backend in, a Program out whose ``serve()`` owns the
engine, the schedule contexts and the PlanStore lifecycle.  Serves a
(smoke-sized) chatglm3 with bucketed prefill, continuous-batching decode
on the paged KV backend, and the dynamic policy choosing per-bucket
plans — the paper's deployment story in miniature.  Afterwards the whole
program is packed into ONE file with ``program.save``: arch + policy
spec + cache backend + every lowered plan.  The "restarted" server is a
single ``Program.load`` — it serves its first request without
re-lowering a single plan (restore hits + shares only — the
cross-process half of the capture/replay story).

Run:  PYTHONPATH=src python examples/serve_batched.py [--requests 24]
"""
import argparse
import os
import tempfile
import time

import numpy as np

import repro
from repro.serve import Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--strategy", default="dynamic")
    ap.add_argument("--bundle", default=None,
                    help="save the program bundle here (default: a temp "
                         "file)")
    args = ap.parse_args()

    bundle = args.bundle or os.path.join(
        tempfile.mkdtemp(prefix="dynaflow-"), "program.dfpb")
    serve_cfg = ServeConfig(max_batch=8, s_max=128,
                            prefill_buckets=(16, 32, 64))

    program = repro.api.compile(args.arch, policy=args.strategy,
                                smoke=True, cache="paged")
    params = program.init_params(0)
    eng = program.serve(params, serve_cfg)
    vocab = program.model.cfg.vocab
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        n = int(rng.integers(4, 50))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, vocab, n,
                                               dtype=np.int32),
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    ttft = [r.first_token_s - r.submitted_s for r in done]
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"TTFT p50={np.percentile(ttft, 50)*1e3:.0f}ms "
          f"p99={np.percentile(ttft, 99)*1e3:.0f}ms")
    st = eng.stats
    print(f"decode tier mix: "
          f"{ {t: n for t, n in st['tier_steps'].items() if n} } "
          f"({st['host_syncs']} host syncs / {st['decode_steps']} decode "
          f"steps, {st['chunk_steps']} chunk steps)")
    print(f"kv backend: {st['kv']}")
    ps = program.stats
    print(f"plan store: {ps['exec_misses']} builds, {ps['exec_hits']} "
          f"replays (the CUDA-graph-capture analogue); "
          f"{ps['misses']} lowered, {ps['shares']} shared across buckets "
          f"(share rate {ps['share_rate']:.0%})")
    assert all(len(r.output) == args.max_new for r in done)
    eng.shutdown()
    n_plans = program.save(bundle)
    program.close()

    # -- "restart" the server: one file holds the whole deployment --------
    # Program.load rebuilds arch + policy + paged cache backend from the
    # bundle header and restores every lowered plan, so a fresh process
    # serves its first request with zero lower() calls.
    print(f"\nrestarting from {bundle} "
          f"({n_plans} plans, {os.path.getsize(bundle)} bytes)...")
    program2 = repro.api.Program.load(bundle)
    print(f"restored backend: {program2.cache_backend}")
    eng2 = program2.serve(params, serve_cfg)
    t0 = time.perf_counter()
    eng2.submit(Request(rid=10_000,
                        prompt=rng.integers(0, vocab, 20,
                                            dtype=np.int32),
                        max_new_tokens=4))
    eng2.run()
    dt = time.perf_counter() - t0
    ps2 = program2.stats
    print(f"first request after restart: {dt*1e3:.0f}ms; "
          f"{ps2['restore_hits']} restored lowerings, {ps2['shares']} "
          f"shared, {ps2['misses']} cold lowers")
    assert ps2["misses"] == 0, (
        f"warm-started engine re-lowered {ps2['misses']} plans: {ps2}")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
