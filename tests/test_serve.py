"""Serving engine tests: continuous batching, determinism, cache reuse."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import Request, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    cfg = ServeConfig(max_batch=4, s_max=64, prefill_buckets=(16, 32), **kw)
    return ServeEngine(model, params, get_strategy("sequential"), cfg)


def test_serves_more_requests_than_slots(engine_setup):
    cfg, model, params = engine_setup
    eng = make_engine(model, params)
    rng = np.random.default_rng(0)
    for i in range(9):                      # > max_batch: rows recycle
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, 100, int(rng.integers(4, 14))).astype(np.int32),
            max_new_tokens=6))
    done = eng.run()
    assert len(done) == 9
    assert all(len(r.output) == 6 for r in done)
    assert len(eng.cache.free_rows) == 4    # all rows released


def test_same_prompt_same_output(engine_setup):
    cfg, model, params = engine_setup
    eng = make_engine(model, params)
    pr = np.arange(7, dtype=np.int32)
    eng.submit(Request(rid=0, prompt=pr, max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=pr.copy(), max_new_tokens=6))
    done = eng.run()
    assert done[0].output == done[1].output


def test_engine_matches_offline_greedy(engine_setup):
    """Engine output == running prefill(n+i) argmax step by step."""
    import jax.numpy as jnp
    from repro.core.scheduler import OpSchedulerBase, ScheduleContext
    from repro.models.base import build_forward
    cfg, model, params = engine_setup
    pr = np.arange(5, dtype=np.int32) + 3
    eng = make_engine(model, params)
    eng.submit(Request(rid=0, prompt=pr, max_new_tokens=3))
    got = eng.run()[0].output

    ids = list(pr)
    want = []
    for _ in range(3):
        n = len(ids)
        segs, _ = model.build_segments("prefill", 1, n, s_max=64)
        fwd = build_forward(segs, OpSchedulerBase(),
                            ScheduleContext(local_batch=1, seq_len=n,
                                            phase="prefill",
                                            arch=cfg.name))
        out = fwd(params, {
            "ids": jnp.asarray(ids, jnp.int32)[None],
            "positions": jnp.arange(n, dtype=jnp.int32)[None]})
        nxt = int(jnp.argmax(out["logits"][0, -1]))
        want.append(nxt)
        ids.append(nxt)
    assert got == want


def test_executable_cache_reuse(engine_setup):
    cfg, model, params = engine_setup
    eng = make_engine(model, params)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, 100, 10).astype(np.int32), max_new_tokens=4))
    eng.run()
    st = eng.store.stats
    # every executable build is one (phase, tier/bucket) capture; the
    # steady state replays them: a run of 6 requests over 2 admission
    # waves must hit far more often than it builds
    assert st["exec_misses"] <= 1 + len(eng.prefill_tiers) + len(eng.tiers)
    assert st["exec_hits"] >= st["exec_misses"]
    # and every non-canonical plan bucket came from specialize, not lower
    assert st["misses"] <= 3 * 2, st    # 3 segments x (prefill, decode)


def test_cross_bucket_plan_share(engine_setup):
    """Later prefill buckets and smaller decode tiers must not re-lower:
    their segment plans are structurally identical to the first bucket's
    / the first tier's, so the PlanStore serves them via fingerprint-v2
    specialization (counted as shares)."""
    cfg, model, params = engine_setup
    eng = make_engine(model, params, prefill_batch=1)
    rng = np.random.default_rng(2)
    eng.submit(Request(rid=0, prompt=rng.integers(0, 100, 10)
                       .astype(np.int32), max_new_tokens=3))   # bucket 16
    eng.submit(Request(rid=1, prompt=rng.integers(0, 100, 20)
                       .astype(np.int32), max_new_tokens=3))   # bucket 32
    done = eng.run()
    assert len(done) == 2
    st = eng.store.stats
    # the second prefill bucket and every decode tier after the first
    # share their segment plans off the canonical lowerings
    assert st["shares"] >= 3, st
    assert eng.store.share_rate > 0
    # eviction stats surface through engine metrics
    assert "evictions" in eng.stats["plan_store"]


def test_engine_warm_starts_from_persisted_store(engine_setup, tmp_path,
                                                 monkeypatch):
    """A restarted engine bound to the same plan_store_path serves its
    requests with zero lower() calls (restore hits + shares only) and
    produces identical tokens."""
    cfg, model, params = engine_setup
    path = str(tmp_path / "plans.dfps")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, n).astype(np.int32) for n in (10, 20)]

    eng = make_engine(model, params, plan_store_path=path)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=3))
    want = [r.output for r in sorted(eng.run(), key=lambda r: r.rid)]
    eng.shutdown()
    assert path and eng.store.stats["restore_saved"] >= 1

    # "restart": fresh engine, same path; any lower() call is a failure
    from repro.core import plan_store as plan_store_mod

    def bomb(*a, **k):
        raise AssertionError("warm-started engine re-lowered a plan")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)
    eng2 = make_engine(model, params, plan_store_path=path)
    for i, pr in enumerate(prompts):
        eng2.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=3))
    got = [r.output for r in sorted(eng2.run(), key=lambda r: r.rid)]
    assert got == want
    st = eng2.store.snapshot()
    assert st["misses"] == 0, st
    assert st["restore_hits"] + st["shares"] > 0, st


def test_train_step_builder_warm_starts(engine_setup, tmp_path,
                                        monkeypatch):
    """build_train_step(plan_store_path=...) persists the lowerings and a
    relaunch restores them without re-lowering (trainer preemption)."""
    from repro.core.strategies import get_strategy
    from repro.train.step import TrainStepConfig, build_train_step
    cfg, model, params = engine_setup
    path = str(tmp_path / "train-plans.dfps")
    tcfg = TrainStepConfig(remat=False)
    build_train_step(model, get_strategy("sequential"), 2, 16, tcfg,
                     plan_store_path=path)
    assert (tmp_path / "train-plans.dfps").exists()

    from repro.core import plan_store as plan_store_mod

    def bomb(*a, **k):
        raise AssertionError("relaunched trainer re-lowered a plan")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)
    build_train_step(model, get_strategy("sequential"), 2, 16, tcfg,
                     plan_store_path=path)
