import os
import sys

# Smoke tests and benches must see ONE device; only dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # container doesn't ship hypothesis — install the deterministic stub
    from repro._compat import hypothesis_stub as _hyp
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_batch(binputs, seed=1, fill=3):
    """Random batch matching a model's input specs."""
    import jax.numpy as jnp
    batch = {}
    for k, (sds, bd) in binputs.items():
        if np.issubdtype(sds.dtype, np.integer):
            if k in ("ids", "labels"):
                batch[k] = jax.random.randint(
                    jax.random.PRNGKey(seed), sds.shape, 0, 100
                ).astype(sds.dtype)
            elif k == "cache_len":
                batch[k] = jnp.full(sds.shape, 4, sds.dtype)
            else:
                batch[k] = jnp.zeros(sds.shape, sds.dtype) + fill
        else:
            batch[k] = jax.random.normal(
                jax.random.PRNGKey(seed), sds.shape).astype(sds.dtype)
    return batch
