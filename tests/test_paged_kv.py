"""Paged KV cache tests: CacheBackend resolution, page-bookkeeping
invariants under fuzzed op interleavings, dense-vs-paged bitwise
equivalence, page-capacity admission, and backend-salted plan keys."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke_config
from repro.core import PlanStore
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import (
    DenseCache,
    PagedCache,
    PagedKVCacheManager,
    PagePressure,
    PromptOverflow,
    Request,
    ServeConfig,
    ServeEngine,
    Shed,
    UnpageableCache,
    resolve_cache_backend,
)
from repro.serve.admission import AdmissionContext
from repro.serve.kv_cache import backend_from_identity, cache_backend_salt


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("s_max", 64)
    kw.setdefault("prefill_buckets", (16, 32))
    return ServeEngine(model, params, get_strategy("sequential"),
                       ServeConfig(**kw))


def _trace(cfg, rng, n_reqs, max_new=8, chunk_last=True):
    out = []
    for i in range(n_reqs):
        n = 40 if (chunk_last and i == n_reqs - 1) \
            else int(rng.integers(4, 30))
        out.append(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, n,
                                               dtype=np.int32),
                           max_new_tokens=max_new))
    return out


# -- backend resolution ------------------------------------------------------

def test_backend_resolution():
    assert isinstance(resolve_cache_backend(None), DenseCache)
    assert isinstance(resolve_cache_backend("dense"), DenseCache)
    paged = resolve_cache_backend("paged")
    assert isinstance(paged, PagedCache)
    custom = PagedCache(page_size=8, num_pages=7)
    assert resolve_cache_backend(custom) is custom
    with pytest.raises(ValueError, match="unknown cache backend"):
        resolve_cache_backend("ring")


def test_backend_identity_round_trip():
    for b in (DenseCache(), PagedCache(), PagedCache(page_size=8),
              PagedCache(page_size=16, num_pages=5)):
        again = backend_from_identity(b.identity())
        assert again == b
        assert cache_backend_salt(again) == cache_backend_salt(b)
    salts = {cache_backend_salt(b) for b in
             (DenseCache(), PagedCache(), PagedCache(page_size=8))}
    assert len(salts) == 3, "backend salts must be distinct"


def test_page_size_validation(setup):
    _, model, _ = setup
    scfg = ServeConfig(max_batch=4, s_max=64, prefill_buckets=(16, 32))
    with pytest.raises(ValueError, match="divide s_max"):
        PagedCache(page_size=24).build(model, scfg)
    with pytest.raises(ValueError, match="prefill bucket"):
        PagedCache(page_size=16).build(
            model, ServeConfig(max_batch=4, s_max=64,
                               prefill_buckets=(24,)))
    with pytest.raises(ValueError, match="page_size"):
        PagedCache(page_size=0).build(model, scfg)


def test_unpageable_arch_rejected():
    cfg = get_smoke_config("mamba2-2.7b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    scfg = ServeConfig(max_batch=2, s_max=64, prefill_buckets=(16, 32))
    with pytest.raises(UnpageableCache, match="DenseCache"):
        PagedCache(page_size=16).build(model, scfg)


# -- page-bookkeeping invariants (property fuzz) -----------------------------

def _check_invariants(mgr: PagedKVCacheManager):
    mapped = [int(p) for p in mgr.page_table.ravel() if p]
    assert len(mapped) == len(set(mapped)), "a page is aliased by 2 rows"
    assert 0 not in mapped, "trash page 0 leaked into a page table"
    assert len(mgr.free_pages) + len(mapped) == mgr.num_pages, \
        "pages leaked or double-freed"
    for row in range(mgr.max_batch):
        used = int(mgr.blocks_used[row])
        assert all(mgr.page_table[row, :used] > 0), "hole in mapped run"
        assert not mgr.page_table[row, used:].any(), \
            "mapped block beyond blocks_used"
        if row in mgr.row_owner:
            assert used >= mgr.pages_needed(int(mgr.lengths[row]))
        else:
            assert used == 0
    assert set(mgr.free_rows) | set(mgr.row_owner) == set(
        range(mgr.max_batch))
    assert not set(mgr.free_rows) & set(mgr.row_owner)


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=15, deadline=None)
def test_page_bookkeeping_fuzz(setup, seed):
    """Random allocate/reserve/release/move_row interleavings never
    alias a page between rows, leak a page, or map the trash page."""
    _, model, _ = setup
    mgr = PagedCache(page_size=16, num_pages=10).build(
        model, ServeConfig(max_batch=4, s_max=64,
                           prefill_buckets=(16, 32)))
    rng = np.random.default_rng(seed)
    for step in range(120):
        op = int(rng.integers(4))
        active = sorted(mgr.row_owner)
        if op == 0 and mgr.free_rows:
            row = mgr.allocate(step)
            assert row is not None
        elif op == 1 and active:
            row = active[int(rng.integers(len(active)))]
            new_len = int(rng.integers(1, mgr.s_max + 8))
            before = len(mgr.free_pages)
            ok = mgr.reserve(row, new_len)
            if ok:
                mgr.lengths[row] = max(int(mgr.lengths[row]), new_len)
            else:   # denial must not leak partial allocations
                assert len(mgr.free_pages) == before
        elif op == 2 and active:
            mgr.release(active[int(rng.integers(len(active)))])
        elif op == 3 and active and mgr.free_rows:
            src = active[int(rng.integers(len(active)))]
            dst = mgr.free_rows[int(rng.integers(len(mgr.free_rows)))]
            pages_before = sorted(
                int(p) for p in mgr.page_table[src] if p)
            mgr.move_row(src, dst)
            # handoff: the SAME physical pages, now under dst
            assert sorted(int(p) for p in mgr.page_table[dst]
                          if p) == pages_before
        _check_invariants(mgr)
    for row in sorted(mgr.row_owner):
        mgr.release(row)
    assert len(mgr.free_pages) == mgr.num_pages
    assert not mgr.page_table.any()


# -- dense vs paged equivalence ----------------------------------------------

def test_dense_paged_bitwise(setup):
    """Greedy decode on the paged backend is bitwise-identical to the
    dense backend across a mixed trace that exercises batched prefill,
    chunked prefill, decode tiers, and compaction."""
    cfg, model, params = setup

    def run(cache):
        eng = make_engine(model, params, cache=cache)
        for r in _trace(cfg, np.random.default_rng(0), 6):
            eng.submit(r)
        done = eng.run()
        assert all(r.ok for r in done), [r.result for r in done]
        assert eng.cache.row_owner == {}
        return {r.rid: r.output for r in done}, eng

    dense, _ = run(None)
    paged, pe = run(PagedCache(page_size=16))
    assert dense == paged
    assert len(pe.cache.free_pages) == pe.cache.num_pages
    assert not pe.cache.page_table.any()
    assert pe.stats["chunk_steps"] > 0, "trace must exercise chunking"


# -- capacity and admission --------------------------------------------------

def test_oversubscribed_pool_drains(setup):
    """More rows than pages-worth of tokens: the engine degrades via
    page denials and preemption but every request still terminates and
    no page leaks."""
    cfg, model, params = setup
    eng = make_engine(model, params, max_batch=8,
                      cache=PagedCache(page_size=16, num_pages=6))
    for r in _trace(cfg, np.random.default_rng(3), 10, max_new=12,
                    chunk_last=False):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 10
    assert all(r.ok for r in done), [r.result for r in done]
    st = eng.stats
    assert st["page_denied"] > 0, "pool was never under pressure"
    assert eng.cache.row_owner == {}
    assert len(eng.cache.free_pages) == eng.cache.num_pages


def test_prompt_overflow_on_page_capacity(setup):
    cfg, model, params = setup
    eng = make_engine(model, params,
                      cache=PagedCache(page_size=16, num_pages=2))
    with pytest.raises(PromptOverflow):
        eng.submit(Request(rid=0,
                           prompt=np.arange(40, dtype=np.int32) % cfg.vocab,
                           max_new_tokens=4))


def test_page_pressure_policy():
    def ctx(free, cap, prompt):
        return AdmissionContext(queue_depth=0, active=1, chunking=0,
                                free_rows=4, max_batch=8,
                                prompt_len=prompt, priority=0,
                                waited_s=0.0, deadline_left_s=None,
                                ttft_left_s=None, free_tokens=free,
                                capacity_tokens=cap)
    pol = PagePressure(max_util=0.75)
    assert isinstance(pol(ctx(free=8, cap=64, prompt=16)), Shed)
    assert pol(ctx(free=48, cap=64, prompt=16)) is None
    # backend reported nothing (pre-paging construction): decline
    assert pol(ctx(free=-1, cap=-1, prompt=16)) is None
    assert pol.identity() == ("page_pressure", 0.75)


# -- plan persistence --------------------------------------------------------

def test_backend_salts_plan_keys(setup):
    """Dense and paged engines sharing one PlanStore must never collide
    on exec captures: a dense engine after a paged run pays its own
    misses, and a second paged engine replays for free."""
    cfg, model, params = setup
    store = PlanStore()
    reqs = lambda: _trace(cfg, np.random.default_rng(1), 4,  # noqa: E731
                          chunk_last=False)

    def run(cache):
        eng = ServeEngine(model, params, get_strategy("sequential"),
                          ServeConfig(max_batch=4, s_max=64,
                                      prefill_buckets=(16, 32),
                                      cache=cache),
                          plan_store=store)
        for r in reqs():
            eng.submit(r)
        assert all(r.ok for r in eng.run())
        return store.stats["exec_misses"]

    paged_misses = run(PagedCache(page_size=16))
    assert paged_misses > 0
    dense_misses = run(None) - paged_misses
    assert dense_misses > 0, \
        "dense engine replayed paged captures: backend salt missing"
    again = run(PagedCache(page_size=16))
    assert again == paged_misses + dense_misses, \
        "same-backend engine should hit every exec capture"
