"""Unit tests: OpGraph IR, tracing, partition rules (paper Fig. 5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FULL, Mark, OpGraph, SplitEveryOp, SplitFunc,
                        SplitModule, partition, sequential_plan, trace)
from repro.core.module import FnOp, Module, Op, Param, mark


class Lin(Op):
    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return x @ p["w"]


class Block(Module):
    def __init__(self, d, name="block"):
        super().__init__()
        self.a = Lin(d, d, "a")
        self.b = Lin(d, d, "b")
        self.named(name)

    def forward(self, x):
        return self.b(self.a(x))


class Net(Module):
    def __init__(self, d=8):
        super().__init__()
        self.blk1 = Block(d).named("blk1")
        self.blk2 = Block(d).named("blk2")
        self.head = Lin(d, 4, "head")

    def forward(self, x):
        h = self.blk1(x)
        with mark("mid"):
            h = self.blk2(h)
        return self.head(h)


@pytest.fixture
def net_and_graph():
    net = Net()
    g = trace(net, {"x": jax.ShapeDtypeStruct((4, 8), jnp.float32)})
    return net, g


def test_trace_records_all_ops(net_and_graph):
    _, g = net_and_graph
    assert len(g.nodes) == 5
    names = [n.name for n in g.nodes.values()]
    assert any("blk1/a" in n for n in names)
    assert any("#mid" in n for n in names)


def test_graph_validates(net_and_graph):
    _, g = net_and_graph
    g.validate()
    assert g.topo_order() == sorted(g.nodes)


def test_trace_vs_direct_equivalence(net_and_graph):
    net, g = net_and_graph
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    from repro.core import realize
    out = realize(g, sequential_plan(g), params, {"x": x})
    np.testing.assert_allclose(out["out"], net.apply(params, x), atol=1e-5)


def test_partition_split_module(net_and_graph):
    _, g = net_and_graph
    coarse = partition(g, [SplitModule(Block)])
    # blk1 (2 ops) and blk2 (2 ops) each coalesce; head stays alone
    assert len(coarse.nodes) == 3


def test_partition_split_func(net_and_graph):
    _, g = net_and_graph
    coarse = partition(g, [SplitFunc(r"head")], default_depth=1)
    names = [n.name for n in coarse.nodes.values()]
    assert any("head" in n for n in names)


def test_partition_mark(net_and_graph):
    _, g = net_and_graph
    coarse = partition(g, [Mark("mid")], default_depth=1)
    # the marked region is one unit
    marked = [n for n in coarse.nodes.values() if "#mid" in n.name]
    assert len(marked) == 1
    assert len(marked[0].members) == 2


def test_partition_preserves_semantics(net_and_graph):
    net, g = net_and_graph
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    from repro.core import realize
    want = net.apply(params, x)
    for rules in ([SplitModule(Block)], [Mark("mid")], [SplitEveryOp()]):
        coarse = partition(g, rules)
        out = realize(coarse, sequential_plan(coarse), params, {"x": x})
        np.testing.assert_allclose(out["out"], want, atol=1e-5)


def test_fnop_wraps_pure_fn():
    f = FnOp(lambda x: x * 2, "double", resource="memory")
    g = trace(f, {"x": jax.ShapeDtypeStruct((3,), jnp.float32)})
    assert list(g.nodes.values())[0].resource == "memory"
