"""Persistent PlanStore tests: the cross-process half of capture/replay.

  * round-trip — a store saved in one process and loaded in another
    serves every previously-seen bucket with zero ``lower`` calls
    (restore hits + shares only) and agrees bitwise with the reference
    interpreter,
  * rejection — corrupt entries, corrupt/garbage headers, and
    format/fingerprint version mismatches all degrade to cold lowering
    (counted in the ``restore_*`` stats family), never crash or serve
    a wrong plan,
  * admission policy — a bucket evicted before its second touch is
    recorded one-shot and never re-admitted to the artifact, even
    after being re-lowered,
  * format — atomic writes, deterministic bytes, unpersistable
    (process-local closure) entries excluded.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FINGERPRINT_VERSION, PlanStore, Realizer,
                        ScheduleContext, record_plan, trace)
from repro.core import plan_store as plan_store_mod
from repro.core.plan_serde import (FORMAT_VERSION, key_digest,
                                   persistable_key)
from test_plan_store import Chain, D, SplitThenMerge, _assert_same, _bucket


def _bomb_lower(monkeypatch):
    """Make any further ``lower`` call inside the store an immediate
    failure — the acceptance contract for a warm-started store."""
    def bomb(*a, **k):
        raise AssertionError("lower() called on a warm-started store")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)


def _populate(net, buckets, salt="t"):
    store = PlanStore()
    pairs = [_bucket(net, B, sizes) for B, sizes in buckets]
    for g, plan, _, _ in pairs:
        store.get_or_lower(g, plan, salt=salt)
    return store, pairs


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------


def test_round_trip_serves_all_buckets_without_lowering(tmp_path,
                                                        monkeypatch):
    net = Chain()
    store, pairs = _populate(net, [(8, (4, 4)), (16, (8, 8)), (12, (4, 8))])
    path = str(tmp_path / "store.dfps")
    assert store.save(path) == 1          # one outer entry (canonical only)

    _bomb_lower(monkeypatch)
    warm = PlanStore.open(path)
    for g, plan, params, x in pairs:
        lowered = warm.get_or_lower(g, plan, salt="t")
        _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                     lowered(params, {"x": x}))
    s = warm.snapshot()
    assert s["misses"] == 0
    assert s["restore_hits"] + s["shares"] == len(pairs)
    assert s["restore_entries"] == 1


def test_unseen_bucket_specializes_restored_canonical(tmp_path,
                                                      monkeypatch):
    """A bucket never seen before the restart must still avoid lowering:
    the restored canonical is rehydrated as a skeleton and specialized."""
    net = Chain()
    store, _ = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.save(path)

    _bomb_lower(monkeypatch)
    warm = PlanStore.open(path)
    g, plan, params, x = _bucket(net, 20, (10, 10))     # unseen shape
    lowered = warm.get_or_lower(g, plan, salt="t")
    _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))
    assert warm.stats["restore_canonicals"] == 1
    assert warm.stats["shares"] == 1 and warm.stats["misses"] == 0


def test_restored_plans_capture_and_replay(tmp_path):
    """Jaxpr captures are rebuilt on load, not deserialized: a redeemed
    plan captures on first traced call and replays afterwards."""
    net = Chain()
    store, pairs = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.save(path)
    warm = PlanStore.open(path)
    g, plan, params, x = pairs[0]
    lowered = warm.get_or_lower(g, plan, salt="t")
    assert lowered.stats.get("captures") is None
    jax.make_jaxpr(lambda p, v: lowered(p, {"x": v}))(params, x)
    jax.make_jaxpr(lambda p, v: lowered(p, {"x": v}))(params, x)
    assert lowered.stats["captures"] == 1
    assert lowered.stats["replays"] >= 1


def test_redeemed_then_evicted_entry_survives_checkpoint(tmp_path):
    """LRU churn after a redeem must not shrink the artifact: the
    restored record backs the entry even when the live plan is gone,
    and it can be redeemed again instead of cold-lowering."""
    net = Chain()
    store, pairs = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.save(path)

    warm = PlanStore.open(path, plan_capacity=1)
    g, plan, *_ = pairs[0]
    warm.get_or_lower(g, plan, salt="t")            # redeem
    g2, p2, *_ = _bucket(Chain(2), 8, (4, 4))       # different structure:
    warm.get_or_lower(g2, p2, salt="t")             # evicts the redeem
    assert warm.stats["evictions"] == 1
    warm.get_or_lower(g, plan, salt="t")            # redeems again, no miss
    assert warm.stats["restore_hits"] == 2
    assert warm.stats["misses"] == 1                # only the g2 structure
    path2 = str(tmp_path / "store2.dfps")
    warm.get_or_lower(g2, p2, salt="t")             # evict the redeem again
    assert warm.save(path2) >= 1
    warm2 = PlanStore.open(path2)
    warm2.get_or_lower(g, plan, salt="t")
    assert warm2.stats["restore_hits"] == 1 and warm2.stats["misses"] == 0


def test_checkpoint_skips_clean_store(tmp_path):
    net = Chain()
    store, pairs = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.path = path
    assert store.dirty
    store.save()
    assert not store.dirty                          # bound-path save cleans
    g, plan, *_ = pairs[0]
    store.get_or_lower(g, plan, salt="t")           # pure hit: still clean
    assert not store.dirty
    g2, p2, *_ = _bucket(net, 24, (12, 12))
    store.get_or_lower(g2, p2, salt="t")            # new bucket: dirty
    assert store.dirty


def test_save_load_passthrough_preserves_unredeemed_entries(tmp_path):
    """A short-lived process that never touches a restored entry must not
    shrink the artifact when it checkpoints."""
    net = Chain()
    store, pairs = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.save(path)

    relay = PlanStore.open(path)          # loads, redeems nothing
    path2 = str(tmp_path / "store2.dfps")
    assert relay.save(path2) == 1
    warm = PlanStore.open(path2)
    g, plan, *_ = pairs[0]
    warm.get_or_lower(g, plan, salt="t")
    assert warm.stats["restore_hits"] == 1 and warm.stats["misses"] == 0


# ---------------------------------------------------------------------------
# rejection: corruption + versioning
# ---------------------------------------------------------------------------


def _saved_lines(tmp_path, net=None):
    net = net or Chain()
    store, pairs = _populate(net, [(8, (4, 4))])
    path = str(tmp_path / "store.dfps")
    store.save(path)
    with open(path, encoding="utf-8") as f:
        return path, f.read().splitlines(), pairs


def test_corrupt_entry_rejected_then_cold_lower(tmp_path):
    path, lines, pairs = _saved_lines(tmp_path)
    bad = str(tmp_path / "bad.dfps")
    with open(bad, "w", encoding="utf-8") as f:
        f.write(lines[0] + "\n" + lines[1].replace("reads", "rEAds", 1)
                + "\n")
    store = PlanStore.open(bad)
    assert store.stats["restore_rejected"] == 1   # checksum catches it
    g, plan, params, x = pairs[0]
    lowered = store.get_or_lower(g, plan, salt="t")
    assert store.stats["misses"] == 1             # graceful cold fallback
    _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))


def test_header_version_mismatch_rejects_file(tmp_path):
    path, lines, pairs = _saved_lines(tmp_path)
    for mutation in ({"format_version": FORMAT_VERSION + 1},
                     {"fingerprint_version": FINGERPRINT_VERSION + 1},
                     {"magic": "not-a-planstore"}):
        hdr = json.loads(lines[0])
        hdr.update(mutation)
        bad = str(tmp_path / "bad.dfps")
        with open(bad, "w", encoding="utf-8") as f:
            f.write(json.dumps(hdr) + "\n" + lines[1] + "\n")
        store = PlanStore.open(bad)
        assert store.stats["restore_errors"] == 1, mutation
        assert store.n_restorable == 0


def test_garbage_and_empty_files_rejected(tmp_path):
    for body in ("", "complete garbage\n", "{}\n", '{"magic": 3}\n'):
        bad = str(tmp_path / "bad.dfps")
        with open(bad, "w", encoding="utf-8") as f:
            f.write(body)
        store = PlanStore.open(bad)
        assert store.stats["restore_errors"] == 1
        g, plan, *_ = _bucket(Chain(), 8, (4, 4))
        store.get_or_lower(g, plan, salt="t")
        assert store.stats["misses"] == 1


def test_schema_malformed_entry_degrades_to_cold_lower(tmp_path):
    """A checksum-valid payload missing a record field must reject at
    redeem time (RestoreError net), not crash the serving request."""
    import hashlib

    path, lines, pairs = _saved_lines(tmp_path)
    parts = lines[1].split(" ", 4)
    obj = json.loads(parts[4])
    del obj["buckets"][0]["instrs"]
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    check = hashlib.sha256(payload.encode()).hexdigest()[:16]
    bad = str(tmp_path / "bad.dfps")
    with open(bad, "w", encoding="utf-8") as f:
        f.write(lines[0] + "\n")
        f.write(f"{parts[0]} {parts[1]} {parts[2]} {check} {payload}\n")
    store = PlanStore.open(bad)
    assert store.stats["restore_rejected"] == 0    # checksum passes
    g, plan, params, x = pairs[0]
    lowered = store.get_or_lower(g, plan, salt="t")
    assert store.stats["restore_rejected"] >= 1
    assert store.stats["misses"] == 1
    _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))


def test_entry_version_mismatch_rejects_entry(tmp_path):
    path, lines, _ = _saved_lines(tmp_path)
    parts = lines[1].split(" ", 2)
    tampered = f"{parts[0]} {FORMAT_VERSION + 1} {parts[2]}"
    bad = str(tmp_path / "bad.dfps")
    with open(bad, "w", encoding="utf-8") as f:
        f.write(lines[0] + "\n" + tampered + "\n")
    store = PlanStore.open(bad)
    assert store.stats["restore_rejected"] == 1
    assert store.n_restorable == 0


def test_missing_file_is_empty_store_not_error(tmp_path):
    store = PlanStore.open(str(tmp_path / "never-written.dfps"))
    assert store.stats["restore_errors"] == 0
    assert store.n_restorable == 0


# ---------------------------------------------------------------------------
# format: determinism, atomicity, unpersistable keys
# ---------------------------------------------------------------------------


def test_save_is_deterministic_and_atomic(tmp_path):
    net = Chain()
    store, _ = _populate(net, [(8, (4, 4)), (16, (8, 8))])
    a, b = str(tmp_path / "a.dfps"), str(tmp_path / "b.dfps")
    store.save(a)
    store.save(b)
    with open(a, encoding="utf-8") as fa, open(b, encoding="utf-8") as fb:
        assert fa.read() == fb.read()
    # atomic replace: no tempfile litter next to the artifact
    assert [f for f in os.listdir(tmp_path) if f.startswith(".planstore")] \
        == []
    # saving over an existing file keeps it loadable
    store.save(a)
    assert PlanStore.open(a).n_restorable == 1


def test_opaque_closure_entries_not_persisted(tmp_path):
    """Fused kernels closing over non-primitives key as ("id", id(fn)) —
    meaningless in another process, so save() must skip them."""
    from repro.core import FULL, OpSchedulerBase
    from repro.core.plan import OpHandle

    box = {"factor": 2.0}                  # non-primitive closure cell

    def scaled(info, x):
        p = info.params_of(0)
        return jnp.tanh(x @ p["w"]) * box["factor"]

    class FuseFirst(OpSchedulerBase):
        def schedule(self, ctx):
            oids = ctx.graph.topo_order()
            ctx.execute((OpHandle(oids[0], FULL, ""),),
                        replace_func=scaled, replace_name="scaled")
            ctx.run_rest_sequential()

    net = Chain(3)
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    plan = record_plan(g, FuseFirst(), ScheduleContext(local_batch=8))
    store = PlanStore()
    store.get_or_lower(g, plan, salt="fuse")
    path = str(tmp_path / "store.dfps")
    assert store.save(path) == 0
    assert store.stats["restore_skipped"] == 1


def test_persistable_key_marks_id_fallbacks():
    assert persistable_key(("fn", "mod", "qual"))
    assert persistable_key((("closure", "m", "q", (1, b"x")), "s", ()))
    assert not persistable_key(("id", 140234))
    assert not persistable_key((("deep", ("id", 7)), "s"))


# ---------------------------------------------------------------------------
# admission policy: one-shot buckets stay out of the artifact
# ---------------------------------------------------------------------------


def test_one_shot_eviction_not_readmitted(tmp_path):
    from repro.core import OpSchedulerBase

    class Seq(OpSchedulerBase):
        pass

    def pair(n):
        g = trace(Chain(n), {"x": jax.ShapeDtypeStruct((8, D),
                                                       jnp.float32)})
        return g, record_plan(g, Seq(), ScheduleContext(local_batch=8))

    store = PlanStore(plan_capacity=2)
    p1, p2, p3 = pair(2), pair(3), pair(4)
    store.get_or_lower(*p1)
    store.get_or_lower(*p2)
    store.get_or_lower(*p3)               # evicts p1 before a 2nd touch
    assert store.stats["one_shot_evictions"] >= 1
    store.get_or_lower(*p1)               # re-lowered, live again
    path = str(tmp_path / "store.dfps")
    store.save(path)
    # the one-shot record is part of the artifact's header...
    hdr = json.loads(open(path, encoding="utf-8").readline())
    assert len(hdr["one_shot"]) >= 1
    # ...and p1, despite being live at save time, was not re-admitted
    warm = PlanStore.open(path)
    warm.get_or_lower(*pair(2))
    assert warm.stats["restore_hits"] == 0 and warm.stats["misses"] == 1


def test_touched_entries_are_persisted_under_churn():
    """A hit or a share marks the entry as reused — not one-shot."""
    net = Chain()
    store = PlanStore(plan_capacity=1)
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    g2, p2, *_ = _bucket(net, 16, (8, 8))
    store.get_or_lower(g1, p1)
    store.get_or_lower(g2, p2)            # share touches the canonical,
    assert store.stats["evictions"] == 1  # then evicts it
    assert store.stats["one_shot_evictions"] == 0


# ---------------------------------------------------------------------------
# exec level: tightened key_for + byte budget
# ---------------------------------------------------------------------------


def test_key_for_accepts_arrays_and_scalars_only():
    store = PlanStore()
    key = store.key_for("fp", {"x": np.zeros((2, 3), np.float32),
                               "n": 7, "flag": True, "name": "bucket"})
    assert key == ("fp", (("flag", "py", "bool", True),
                          ("n", "py", "int", 7),
                          ("name", "py", "str", "bucket"),
                          ("x", (2, 3), "float32")))
    with pytest.raises(TypeError, match="neither an array"):
        store.key_for("fp", {"bad": [1, 2, 3]})
    with pytest.raises(TypeError, match="neither an array"):
        store.key_for("fp", {"bad": object()})


def test_exec_byte_budget_evicts_lru():
    store = PlanStore(exec_capacity=100, exec_budget_bytes=3 * 4096)
    for i in range(5):
        store.get_or_build(("k", i), lambda i=i: (lambda: i))
    assert store.n_execs <= 3
    assert store.stats["exec_evictions"] >= 2
    assert store.stats["exec_bytes"] <= 3 * 4096
    # byte accounting survives eviction churn
    assert store.stats["exec_bytes"] == sum(
        nb for _, nb in store._execs.values())
    # LRU: the newest keys survive
    assert ("k", 4) in store._execs and ("k", 0) not in store._execs


def test_snapshot_exec_symmetry():
    store = PlanStore()
    store.get_or_build(("a",), lambda: (lambda: 1))
    store.get_or_build(("a",), lambda: (lambda: 1))
    snap = store.snapshot()
    for k in ("exec_hits", "exec_misses", "exec_evictions", "exec_bytes",
              "exec_hit_rate", "n_execs", "share_rate", "n_plans",
              "n_restorable"):
        assert k in snap, k
    assert snap["exec_hit_rate"] == 0.5


# ---------------------------------------------------------------------------
# stack threading: train-step builder persistence
# ---------------------------------------------------------------------------


def test_digest_is_stable_across_key_copies():
    k = (("a", (1, 2)), "s", ())
    assert key_digest(k) == key_digest((("a", (1, 2)), "s", ()))
