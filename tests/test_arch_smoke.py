"""Per-architecture smoke tests (assignment requirement): reduced
same-family config, one forward/train step on CPU, output shapes + no
NaNs — for all 10 assigned architectures × {train, prefill, decode}."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.scheduler import OpSchedulerBase, ScheduleContext
from repro.models.base import build_forward
from repro.models.layers import MeshInfo
from repro.models.registry import build_model

ARCHS = list_archs()
B, S, S_MAX = 2, 16, 32


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "chatglm3-6b", "deepseek-coder-33b", "smollm-135m", "minitron-8b",
        "deepseek-moe-16b", "grok-1-314b", "mamba2-2.7b", "whisper-tiny",
        "qwen2-vl-7b", "zamba2-1.2b"}


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_config_matches_assignment(arch):
    cfg = get_config(arch)
    expect = {
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    dff = (cfg.moe.d_ff_expert if cfg.family == "moe" and arch ==
           "deepseek-moe-16b" else cfg.d_ff)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, dff,
            cfg.vocab) == expect


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_plausible(arch):
    cfg = get_config(arch)
    total, active = cfg.param_count()
    expect = {"chatglm3-6b": 6e9, "deepseek-coder-33b": 33e9,
              "smollm-135m": 135e6, "minitron-8b": 8e9,
              "deepseek-moe-16b": 16e9, "grok-1-314b": 314e9,
              "mamba2-2.7b": 2.7e9, "whisper-tiny": 37e6,
              "qwen2-vl-7b": 7e9, "zamba2-1.2b": 1.2e9}[arch]
    assert 0.55 * expect < total < 1.45 * expect, (total, expect)
    assert active <= total


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, binputs = model.build_segments("train", B, S)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    fwd = build_forward(segs, OpSchedulerBase(),
                        ScheduleContext(local_batch=B, seq_len=S,
                                        phase="train", arch=arch))
    out = fwd(params, make_batch(binputs))
    assert out["loss_sum"].shape == (B,)
    assert out["token_count"].shape == (B,)
    loss = float(jnp.sum(out["loss_sum"]) / jnp.sum(out["token_count"]))
    assert np.isfinite(loss) and 0.0 < loss < 20.0
    # gradient step sanity: loss decreases on repeated identical batch
    from repro.train import TrainStepConfig, build_train_step
    from repro.optim import AdamWConfig
    step, segs2, binputs2, init_opt = build_train_step(
        model, OpSchedulerBase(), B, S,
        TrainStepConfig(optimizer=AdamWConfig(lr=2e-3), remat=False,
                        warmup=1, total_steps=10))
    p2 = model._init_from_segments(segs2, jax.random.PRNGKey(0))
    opt = init_opt(p2)
    batch = make_batch(binputs2)
    js = jax.jit(step)
    losses = []
    for i in range(3):
        p2, opt, m = js(p2, opt, batch, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    for phase in ("prefill", "decode"):
        segs, binputs = model.build_segments(phase, B, S, s_max=S_MAX)
        params = model._init_from_segments(segs, jax.random.PRNGKey(0))
        fwd = build_forward(segs, OpSchedulerBase(),
                            ScheduleContext(local_batch=B, seq_len=S,
                                            phase=phase, arch=arch))
        batch = make_batch(binputs)
        if phase == "decode":
            for k, sds in model.decode_cache_env(B, S_MAX).items():
                batch[k] = jnp.zeros(sds.shape, sds.dtype)
        out = fwd(params, batch)
        logits = out["logits"]
        # prefill collapses to the last position; decode keeps every
        # input position so speculative verify can consume all k+1
        # logits (whisper's decode is fixed at width 1)
        want_s = 1 if phase == "prefill" else batch["ids"].shape[1]
        assert logits.shape[0] == B and logits.shape[1] == want_s
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["chatglm3-6b", "mamba2-2.7b",
                                  "zamba2-1.2b", "deepseek-moe-16b"])
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must equal a longer prefill's argmax
    (cache correctness end-to-end)."""
    cfg = get_smoke_config(arch)
    if cfg.family in ("ssm", "hybrid"):
        pytest.skip("prefill->decode state handoff is a serve-layer "
                    "feature for attention archs; SSM handoff is "
                    "documented future work")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    n = 8
    ids = jax.random.randint(jax.random.PRNGKey(3), (1, n + 1), 0, 100)

    def prefill_logits(length):
        segs, binputs = model.build_segments("prefill", 1, length,
                                             s_max=S_MAX)
        params = model._init_from_segments(segs, jax.random.PRNGKey(0))
        fwd = build_forward(segs, OpSchedulerBase(),
                            ScheduleContext(local_batch=1, seq_len=length,
                                            phase="prefill", arch=arch))
        batch = {"ids": ids[:, :length],
                 "positions": jnp.arange(length, dtype=jnp.int32)[None]}
        return fwd(params, batch)

    out_n1 = prefill_logits(n + 1)
    want = int(jnp.argmax(out_n1["logits"][0, -1]))

    # prefill n tokens, write cache, decode token n
    out_n = prefill_logits(n)
    segs, binputs = model.build_segments("decode", 1, 1, s_max=S_MAX)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    fwd = build_forward(segs, OpSchedulerBase(),
                        ScheduleContext(local_batch=1, seq_len=1,
                                        phase="decode", arch=arch))
    batch = {"ids": ids[:, n:n + 1],
             "positions": jnp.full((1, 1), n, jnp.int32),
             "cache_len": jnp.full((1,), n, jnp.int32)}
    for k, sds in model.decode_cache_env(1, S_MAX).items():
        cache = jnp.zeros(sds.shape, sds.dtype)
        if k in ("k_cache", "v_cache"):
            kk = "k" if k.startswith("k") else "v"
            src = out_n.get(f"layers.{kk}", out_n.get(kk))
            if cache.ndim == 5:    # stacked (L, B, S, kv, hd)
                if src.ndim == 4:
                    src = src[None]
                cache = cache.at[:, :, :n].set(src.astype(cache.dtype))
            else:                  # count-1 stack (B, S, kv, hd)
                if src.ndim == 5:
                    src = src[0]
                cache = cache.at[:, :n].set(src.astype(cache.dtype))
        batch[k] = cache
    if "dense0_k_cache" in batch:
        batch["dense0_k_cache"] = batch["dense0_k_cache"].at[:, :n].set(
            out_n["dense0.k"].astype(batch["dense0_k_cache"].dtype))
        batch["dense0_v_cache"] = batch["dense0_v_cache"].at[:, :n].set(
            out_n["dense0.v"].astype(batch["dense0_v_cache"].dtype))
    out_d = fwd(params, batch)
    got = int(jnp.argmax(out_d["logits"][0, -1]))
    assert got == want
