"""Tiered serve runtime tests: batch-tier capture sharing, chunked
prefill, compaction, and the async host loop's sync discipline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import PlanStore
from repro.core.scheduler import OpSchedulerBase, ScheduleContext
from repro.core.strategies import get_strategy
from repro.models.base import build_forward
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import Request, ServeConfig, ServeEngine
from repro.serve.engine import pow2_tiers


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("s_max", 64)
    kw.setdefault("prefill_buckets", (16, 32))
    return ServeEngine(model, params, get_strategy("sequential"),
                       ServeConfig(**kw))


def _decode_fwd(model, cfg, tier, store, s_max=64):
    segs, _ = model.build_segments("decode", tier, 1, s_max=s_max)
    info = ScheduleContext(local_batch=tier, seq_len=s_max, phase="decode",
                           arch=cfg.name)
    return build_forward(segs, OpSchedulerBase(), info, lowered=True,
                        plan_cache=store,
                        op_config=model.op_closure_config())


def test_pow2_tiers():
    assert pow2_tiers(8) == (1, 2, 4, 8)
    assert pow2_tiers(6) == (1, 2, 4, 6)
    assert pow2_tiers(1) == (1,)


# -- tier specialization ----------------------------------------------------

def test_tier_specialization_differential(setup):
    """Decode at tier t is bitwise-identical to the fixed max_batch
    decode restricted to the same rows — the specialized lowering only
    rewrites the batch dimension, never the per-row math."""
    cfg, model, params = setup
    store = PlanStore()
    rng = np.random.default_rng(0)
    caches8 = {k: jnp.asarray(
        rng.standard_normal(v.shape).astype(np.float32), v.dtype)
        for k, v in model.decode_cache_env(8, 64).items()}
    ids = jnp.asarray(rng.integers(0, cfg.vocab, (8, 1)), jnp.int32)
    clen = jnp.asarray(rng.integers(1, 10, (8,)), jnp.int32)
    layout = model.decode_cache_layout()

    fwd8 = _decode_fwd(model, cfg, 8, store)     # canonical
    out8 = fwd8(params, {"ids": ids, "positions": clen[:, None],
                         "cache_len": clen, **caches8})
    for tier in (1, 2, 4):
        fwdt = _decode_fwd(model, cfg, tier, store)   # specialized
        tcaches = {k: jax.lax.slice_in_dim(v, 0, tier, axis=layout[k][0])
                   for k, v in caches8.items()}
        outt = fwdt(params, {"ids": ids[:tier],
                             "positions": clen[:tier, None],
                             "cache_len": clen[:tier], **tcaches})
        np.testing.assert_array_equal(
            np.asarray(out8["logits"])[:tier], np.asarray(outt["logits"]))
    st = store.stats
    assert st["misses"] == 3, st      # only the canonical tier lowered
    assert st["shares"] == 9, st      # 3 segments x 3 derived tiers


def test_tiers_share_one_canonical_capture(setup, monkeypatch):
    """Tiers 2..N must count as PlanStore shares — zero extra lower()
    calls beyond the canonical tier's."""
    cfg, model, params = setup
    store = PlanStore()
    _decode_fwd(model, cfg, 4, store)
    lowered_canonical = store.stats["misses"]
    from repro.core import plan_store as plan_store_mod

    def bomb(*a, **k):
        raise AssertionError("a non-canonical tier re-lowered")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)
    for tier in (1, 2):
        _decode_fwd(model, cfg, tier, store)
    st = store.stats
    assert st["misses"] == lowered_canonical
    assert st["shares"] == 2 * lowered_canonical, st


def test_tiers_round_trip_persistent_artifact(setup, tmp_path, monkeypatch):
    """A persisted canonical decode capture serves every tier after a
    restart: the seen tier redeems (restore hit), unseen tiers
    specialize the rehydrated skeleton — never a cold lower."""
    cfg, model, params = setup
    path = str(tmp_path / "tiers.dfps")
    store = PlanStore(path=path)
    _decode_fwd(model, cfg, 4, store)
    assert store.save() >= 1

    from repro.core import plan_store as plan_store_mod

    def bomb(*a, **k):
        raise AssertionError("restarted process re-lowered a tier")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)
    store2 = PlanStore.open(path)
    _decode_fwd(model, cfg, 4, store2)           # seen tier: restore hits
    assert store2.stats["restore_hits"] == 3, store2.stats
    _decode_fwd(model, cfg, 2, store2)           # unseen tier: shares
    st = store2.stats
    assert st["misses"] == 0, st
    assert st["shares"] == 3, st


def test_engine_tier_selection_and_compaction(setup):
    """Mixed-lifetime batch: the engine shrinks tiers as requests finish,
    compacts surviving rows into the tier prefix, and still produces the
    exact tokens each request would get running alone."""
    cfg, model, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 100, n).astype(np.int32)
               for n in (6, 9, 12, 7)]
    max_new = [2, 2, 8, 8]

    eng = make_engine(model, params)
    for i, (pr, mn) in enumerate(zip(prompts, max_new)):
        eng.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=mn))
    done = {r.rid: r.output for r in eng.run()}
    st = eng.stats
    # all four started at tier 4; the two short requests finished and the
    # survivors (rows 2, 3) were compacted down into a smaller tier
    assert st["tier_steps"][4] > 0
    assert sum(v for t, v in st["tier_steps"].items() if t < 4) > 0, st
    assert st["row_moves"] > 0, st

    for i, (pr, mn) in enumerate(zip(prompts, max_new)):
        solo = make_engine(model, params)
        solo.submit(Request(rid=0, prompt=pr.copy(), max_new_tokens=mn))
        want = solo.run()[0].output
        assert done[i] == want, f"request {i} diverged under tiering"


# -- batched + chunked prefill ----------------------------------------------

def test_batched_prefill_packs_requests(setup):
    cfg, model, params = setup
    eng = make_engine(model, params, prefill_batch=4)
    rng = np.random.default_rng(4)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, 10)
                           .astype(np.int32), max_new_tokens=3))
    done = eng.run()
    assert len(done) == 4
    st = eng.stats
    assert st["prefill_steps"] == 1, st     # one call admits all four
    assert st["prefill_reqs"] == 4, st


def test_old_prefill_failure_shape_is_pinned():
    """The pre-tiered engine crashed on prompts longer than the largest
    bucket with a raw numpy broadcast error at ``ids[0, :n] = prompt``;
    chunked prefill makes that a supported path, and with chunking
    disabled the engine now rejects at submit() with a typed error."""
    prompt = np.arange(40, dtype=np.int32)
    ids = np.zeros((1, 32), np.int32)
    with pytest.raises(ValueError):         # the old failure shape
        ids[0, :40] = prompt[:40]


def test_chunked_prefill_disabled_rejects(setup):
    cfg, model, params = setup
    eng = make_engine(model, params, chunked_prefill=False)
    with pytest.raises(ValueError, match="largest prefill bucket"):
        eng.submit(Request(rid=0, prompt=np.arange(40, dtype=np.int32),
                           max_new_tokens=2))


def test_chunked_prefill_matches_offline(setup):
    """A prompt longer than every bucket runs as chunked prefill through
    the decode graph and must match the offline greedy reference."""
    cfg, model, params = setup
    pr = (np.arange(40, dtype=np.int32) * 7 + 3) % 100
    eng = make_engine(model, params)
    eng.submit(Request(rid=0, prompt=pr.copy(), max_new_tokens=3))
    got = eng.run()[0].output
    assert eng.stats["chunk_steps"] >= 2, eng.stats

    ids = list(pr)
    want = []
    for _ in range(3):
        n = len(ids)
        segs, _ = model.build_segments("prefill", 1, n, s_max=64)
        fwd = build_forward(segs, OpSchedulerBase(),
                            ScheduleContext(local_batch=1, seq_len=n,
                                            phase="prefill", arch=cfg.name))
        out = fwd(params, {
            "ids": jnp.asarray(ids, jnp.int32)[None],
            "positions": jnp.arange(n, dtype=jnp.int32)[None]})
        nxt = int(jnp.argmax(out["logits"][0, -1]))
        want.append(nxt)
        ids.append(nxt)
    assert got == want


def test_chunk_coverage_exactly_one_short_of_prompt(setup):
    """n-1 an exact sum of chunk buckets (n=33 with buckets (16,32)):
    the chunks cover one token fewer than the prompt, so the staging
    buffer must be sized to the prompt, not the coverage."""
    cfg, model, params = setup
    pr = (np.arange(33, dtype=np.int32) * 5 + 1) % 100
    eng = make_engine(model, params)
    eng.submit(Request(rid=0, prompt=pr.copy(), max_new_tokens=2))
    got = eng.run()[0].output
    assert len(got) == 2 and all(t >= 0 for t in got)


def test_injected_store_path_and_budget_contracts(setup, tmp_path):
    """An injected PlanStore must reject a conflicting config path
    (silent rebinding would redirect the owner's checkpoints) and honor
    explicitly-set config budgets."""
    cfg, model, params = setup
    store = PlanStore(path=str(tmp_path / "a.dfps"))
    with pytest.raises(ValueError, match="conflicting persistence"):
        ServeEngine(model, params, get_strategy("sequential"),
                    ServeConfig(max_batch=2, s_max=64,
                                prefill_buckets=(16, 32),
                                plan_store_path=str(tmp_path / "b.dfps")),
                    plan_store=store)
    shared = PlanStore()
    eng = ServeEngine(model, params, get_strategy("sequential"),
                      ServeConfig(max_batch=2, s_max=64,
                                  prefill_buckets=(16, 32),
                                  exec_capacity=7),
                      plan_store=shared)
    assert eng.store is shared and shared.exec_capacity == 7


def test_chunked_prefill_fairness_ttft_ordering(setup):
    """A long chunked prompt submitted first must not monopolize
    dispatch for len/chunk consecutive steps: short prompts behind it
    prefill before its chunks finish (round-robin admission) and reach
    their first token strictly earlier."""
    cfg, model, params = setup
    eng = make_engine(model, params)
    long_pr = (np.arange(40, dtype=np.int32) * 7 + 3) % 100
    eng.submit(Request(rid=0, prompt=long_pr.copy(), max_new_tokens=3))
    rng = np.random.default_rng(8)
    shorts = [rng.integers(0, 100, 8).astype(np.int32) for _ in range(3)]
    for i, pr in enumerate(shorts, start=1):
        eng.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=3))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 4
    # dispatch interleaving: the shorts' prefill went out before the
    # long prompt's last chunk (the old engine dispatched every chunk
    # back-to-back ahead of any waiting admit)
    log = eng.dispatch_log
    last_chunk = max(i for i, e in enumerate(log) if e[0] == "chunk")
    first_prefill = min(i for i, e in enumerate(log) if e[0] == "prefill")
    assert first_prefill < last_chunk, log
    # TTFT ordering: every short request saw its first token strictly
    # before the long one that was submitted ahead of them
    for i in (1, 2, 3):
        assert done[i].first_token_s < done[0].first_token_s, i
    # fairness must not change the long prompt's tokens (vs a solo run)
    solo = make_engine(model, params)
    solo.submit(Request(rid=0, prompt=long_pr.copy(), max_new_tokens=3))
    assert solo.run()[0].output == done[0].output


def test_oversized_prompt_rejected(setup):
    cfg, model, params = setup
    eng = make_engine(model, params)
    with pytest.raises(ValueError, match="s_max"):
        eng.submit(Request(rid=0, prompt=np.zeros(64, np.int32),
                           max_new_tokens=2))


# -- async host loop --------------------------------------------------------

def test_async_loop_one_sync_per_decode_iteration(setup):
    """The double-buffered loop must fetch at most one small vector per
    decode iteration — never a per-token np.asarray sync."""
    cfg, model, params = setup
    eng = make_engine(model, params)
    rng = np.random.default_rng(5)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, 100, int(rng.integers(4, 14))).astype(np.int32),
            max_new_tokens=5))
    done = eng.run()
    assert len(done) == 6
    st = eng.stats
    assert st["host_syncs"] <= st["decode_steps"] + 2, st


def test_async_and_sync_loops_agree(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, 100, int(rng.integers(4, 30)))
               .astype(np.int32) for _ in range(5)]

    outs = []
    for async_host in (True, False):
        eng = make_engine(model, params, async_host=async_host)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=4))
        outs.append({r.rid: r.output for r in eng.run()})
    assert outs[0] == outs[1]


def test_baseline_config_recovers_fixed_batch(setup):
    """decode_tiers=(max_batch,) + prefill_batch=1 + async_host=False is
    the synchronous fixed-batch baseline; it must agree with the tiered
    async engine token-for-token."""
    cfg, model, params = setup
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 100, int(rng.integers(4, 14)))
               .astype(np.int32) for _ in range(4)]

    base = make_engine(model, params, decode_tiers=(4,), prefill_batch=1,
                       async_host=False)
    tier = make_engine(model, params)
    for eng in (base, tier):
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr.copy(), max_new_tokens=4))
    b = {r.rid: r.output for r in base.run()}
    t = {r.rid: r.output for r in tier.run()}
    assert b == t
    assert base.stats["tier_steps"] == {4: base.stats["decode_steps"]}
