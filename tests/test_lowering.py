"""Lowering (plan IR) tests: the lowered slot-based Realizer must be a
perfect stand-in for the step-by-step interpreter.

  * differential property test — random DAGs × random valid schedules
    (splits, merges, slot-reuse-heavy chains, fused groups) produce
    bitwise-identical outputs interpreted vs lowered,
  * regression — lowering rejects (plan, analysis, graph) triples whose
    fingerprints disagree,
  * cache behavior — LRU bounds + eviction counters, capture/replay.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (FULL, LoweringError, OpSchedulerBase, PlanStore,
                        Realizer, ScheduleContext, lower, realize,
                        record_plan, static_analysis, trace)
from repro.core.module import Module, Op, Param
from repro.core.plan import OpHandle


D = 8


class Lin(Op):
    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class AddOp(Op):
    def kernel(self, p, a, b):
        return a + b


class RandomNet(Module):
    """Random DAG: chain of Lins with Add-merges of random earlier taps."""

    def __init__(self, seed, n_ops):
        super().__init__()
        rng = np.random.default_rng(seed)
        self.wiring = []
        for i in range(n_ops):
            if i >= 2 and rng.random() < 0.4:
                self.wiring.append(("add", int(rng.integers(i)),
                                    int(rng.integers(i))))
                setattr(self, f"op{i}", AddOp().named(f"add{i}"))
            else:
                self.wiring.append(("lin", int(rng.integers(i + 1)) - 1, -1))
                setattr(self, f"op{i}", Lin(D, D, f"lin{i}"))

    def forward(self, x):
        vals = [x]
        for i, (kind, a, b) in enumerate(self.wiring):
            op = getattr(self, f"op{i}")
            if kind == "add":
                vals.append(op(vals[a + 1], vals[b + 1]))
            else:
                vals.append(op(vals[a + 1]))
        return vals[-1]


class RandomScheduler(OpSchedulerBase):
    def __init__(self, seed, split_sizes, merge_prob):
        self.rng = np.random.default_rng(seed)
        self.split_sizes = split_sizes
        self.merge_prob = merge_prob

    def schedule(self, ctx):
        if self.split_sizes:
            ctx.split(self.split_sizes)
        parts = (list(range(len(self.split_sizes)))
                 if self.split_sizes else [FULL])
        while True:
            ready = [h for i in parts for h in ctx.get_ready_ops(i)]
            if not ready:
                break
            if self.split_sizes and self.rng.random() < self.merge_prob:
                by_oid = {}
                for h in ready:
                    by_oid.setdefault(h.oid, []).append(h)
                full = [v for v in by_oid.values()
                        if len(v) == len(self.split_sizes)]
                if full:
                    ctx.execute(tuple(full[self.rng.integers(len(full))]))
                    continue
            ctx.execute(ready[self.rng.integers(len(ready))])


def _setup(seed=0, n_ops=5):
    net = RandomNet(seed, n_ops)
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    params = net.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, D))
    return g, params, x


def _assert_same(a: dict, b: dict):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"output {k!r} diverged")


# ---------------------------------------------------------------------------
# differential: lowered == interpreted, bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_ops=st.integers(3, 8),
       split=st.sampled_from([(), (4, 4), (2, 6), (2, 2, 4)]),
       merge_prob=st.floats(0.0, 0.9))
def test_differential_random_graphs_and_schedules(seed, n_ops, split,
                                                  merge_prob):
    g, params, x = _setup(seed % 50, n_ops)
    sched = RandomScheduler(seed, split, merge_prob)
    plan = record_plan(g, sched, ScheduleContext(local_batch=8))
    want = Realizer(g, plan, lowered=False)(params, {"x": x})
    got = Realizer(g, plan, lowered=True)(params, {"x": x})
    _assert_same(want, got)


def test_differential_slot_reuse_heavy():
    """Long per-micro-batch chain: env keys die every step, so the slot
    allocator must recycle aggressively — and results must not change."""
    class Chain(Module):
        def __init__(self, n=10):
            super().__init__()
            self.n = n
            for i in range(n):
                setattr(self, f"l{i}", Lin(D, D, f"l{i}"))

        def forward(self, x):
            for i in range(self.n):
                x = getattr(self, f"l{i}")(x)
            return x

    net = Chain()
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))

    class PerPartThenMerge(OpSchedulerBase):
        def schedule(self, ctx):
            ctx.split([4, 4])
            oids = ctx.graph.topo_order()
            for oid in oids[:-1]:          # per-part chain
                for p in (0, 1):
                    ctx.execute(OpHandle(oid, p, ""))
            ctx.execute(tuple(OpHandle(oids[-1], p, "") for p in (0, 1)))

    plan = record_plan(g, PerPartThenMerge(), ScheduleContext(local_batch=8))
    lowered = lower(g, plan)
    # liveness-driven reuse: far fewer slots than live keys, and at least
    # one prealloc buffer created via the first-producer pad
    assert lowered.stats["slots_reused"] > 0
    assert lowered.n_slots < lowered.stats["n_env_keys"]
    assert lowered.stats["pad_inits"] == 1
    want = Realizer(g, plan, lowered=False)(params, {"x": x})
    _assert_same(want, lowered(params, {"x": x}))


def test_differential_fused_step():
    """A fused group replacement must see pre-resolved params and produce
    the group's external outputs identically in both backends."""
    class TwoLin(Module):
        def __init__(self):
            super().__init__()
            self.a = Lin(D, D, "a")
            self.b = Lin(D, D, "b")
            self.c = Lin(D, D, "c")

        def forward(self, x):
            return self.c(self.b(self.a(x)))

    net = TwoLin()
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))

    def fused_ab(info, xin):
        pa = info.params_of(0)
        pb = info.params_of(1)
        return jnp.tanh(jnp.tanh(xin @ pa["w"]) @ pb["w"])

    class FuseFirstTwo(OpSchedulerBase):
        def schedule(self, ctx):
            oids = ctx.graph.topo_order()
            ctx.execute((OpHandle(oids[0], FULL, "a"),
                         OpHandle(oids[1], FULL, "b")),
                        replace_func=fused_ab, replace_name="fused_ab")
            ctx.run_rest_sequential()

    plan = record_plan(g, FuseFirstTwo(), ScheduleContext(local_batch=8))
    want = Realizer(g, plan, lowered=False)(params, {"x": x})
    got = Realizer(g, plan, lowered=True)(params, {"x": x})
    _assert_same(want, got)
    # direct-mode reference
    ref = net.apply(params, x)
    np.testing.assert_allclose(np.asarray(got["out"]), np.asarray(ref),
                               atol=1e-6)


def test_differential_under_jit():
    g, params, x = _setup(3, 6)
    plan = record_plan(g, RandomScheduler(7, (4, 4), 0.5),
                       ScheduleContext(local_batch=8))
    rz_i = Realizer(g, plan, lowered=False)
    rz_l = Realizer(g, plan, lowered=True)
    out_i = jax.jit(lambda p, v: rz_i(p, {"x": v})["out"])(params, x)
    out_l = jax.jit(lambda p, v: rz_l(p, {"x": v})["out"])(params, x)
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_l),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# regression: fingerprint validation
# ---------------------------------------------------------------------------


def test_lowering_rejects_mismatched_analysis():
    g, params, x = _setup(0, 5)
    plan_a = record_plan(g, RandomScheduler(1, (4, 4), 0.3),
                         ScheduleContext(local_batch=8))
    plan_b = record_plan(g, RandomScheduler(2, (2, 6), 0.6),
                         ScheduleContext(local_batch=8))
    assert plan_a.fingerprint() != plan_b.fingerprint()
    ana_a = static_analysis(g, plan_a)
    with pytest.raises(LoweringError, match="belongs to plan"):
        lower(g, plan_b, ana_a)


def test_lowering_rejects_mismatched_graph():
    g1, _, _ = _setup(0, 5)
    g2, _, _ = _setup(1, 6)
    plan = record_plan(g1, RandomScheduler(1, (), 0.0),
                       ScheduleContext(local_batch=8))
    with pytest.raises(LoweringError, match="recorded for graph"):
        lower(g2, plan)


# ---------------------------------------------------------------------------
# caches: LRU bounds, eviction counters, capture/replay
# ---------------------------------------------------------------------------


def test_plan_store_lru_and_eviction_counter():
    g, params, x = _setup(0, 5)
    store = PlanStore(plan_capacity=2)
    plans = [record_plan(g, RandomScheduler(s, (4, 4), 0.4),
                         ScheduleContext(local_batch=8)) for s in range(5)]
    fps = {p.fingerprint() for p in plans}
    assert len(fps) >= 3                     # distinct schedules
    for p in plans:
        store.get_or_lower(g, p)
    assert store.n_plans <= 2
    assert store.stats["evictions"] >= len(fps) - 2
    # hit path
    lowered = store.get_or_lower(g, plans[-1])
    assert store.stats["hits"] >= 1
    _assert_same(Realizer(g, plans[-1], lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))


def test_plan_store_exec_lru_and_eviction_counter():
    store = PlanStore(exec_capacity=3)
    for i in range(7):
        store.get_or_build(("k", i), lambda i=i: (lambda: i))
    assert store.n_execs == 3
    assert store.stats["exec_evictions"] == 4
    assert store.stats["exec_misses"] == 7
    # most-recent keys survive
    assert store.get_or_build(("k", 6), lambda: None)() == 6
    assert store.stats["exec_hits"] == 1


def test_capture_replay_reuses_jaxpr():
    g, params, x = _setup(2, 6)
    plan = record_plan(g, RandomScheduler(5, (4, 4), 0.4),
                       ScheduleContext(local_batch=8))
    rz = Realizer(g, plan, lowered=True)
    jax.make_jaxpr(lambda p, v: rz(p, {"x": v}))(params, x)
    assert rz.lowered.stats.get("captures") == 1
    jax.make_jaxpr(lambda p, v: rz(p, {"x": v}))(params, x)
    assert rz.lowered.stats.get("replays", 0) >= 1
    assert rz.lowered.stats.get("captures") == 1   # no re-capture


def test_realize_helper_paths_agree():
    g, params, x = _setup(4, 7)
    plan = record_plan(g, RandomScheduler(9, (2, 6), 0.7),
                       ScheduleContext(local_batch=8))
    _assert_same(realize(g, plan, params, {"x": x}, lowered=False),
                 realize(g, plan, params, {"x": x}, lowered=True))
