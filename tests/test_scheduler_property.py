"""Property-based tests (hypothesis): the transparency contract.

ANY valid schedule — random topo order, random micro-batch split, random
merge points — must produce outputs allclose to sequential execution.
This is the invariant that makes the paper's decoupling safe.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (FULL, OpSchedulerBase, ScheduleContext, partition,
                        realize, record_plan, sequential_plan, trace)
from repro.core.module import Module, Op, Param
from repro.core.plan import OpHandle


class Lin(Op):
    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class Diamond(Module):
    """Non-trivial DAG: two parallel branches re-merging."""

    def __init__(self, d=8):
        super().__init__()
        self.stem = Lin(d, d, "stem")
        self.left = Lin(d, d, "left")
        self.right = Lin(d, d, "right")
        self.out = Lin(2 * d, 4, "out")

    def forward(self, x):
        h = self.stem(x)
        l, r = self.left(h), self.right(h)
        return self.out(jnp.concatenate([l, r], -1))


class CatOp(Op):
    def kernel(self, p, a, b):
        return jnp.concatenate([a, b], -1)


class DiamondExplicit(Module):
    """Same DAG with the concat as a schedulable op (trace-friendly)."""

    def __init__(self, d=8):
        super().__init__()
        self.stem = Lin(d, d, "stem")
        self.left = Lin(d, d, "left")
        self.right = Lin(d, d, "right")
        self.cat = CatOp().named("cat")
        self.out = Lin(2 * d, 4, "out")

    def forward(self, x):
        h = self.stem(x)
        return self.out(self.cat(self.left(h), self.right(h)))


@pytest.fixture(scope="module")
def setup():
    net = DiamondExplicit()
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    want = realize(g, sequential_plan(g), params, {"x": x})["out"]
    return g, params, x, want


class RandomScheduler(OpSchedulerBase):
    """Random valid schedule driven by a hypothesis-provided seed."""

    def __init__(self, seed, split_sizes, merge_prob):
        self.rng = np.random.default_rng(seed)
        self.split_sizes = split_sizes
        self.merge_prob = merge_prob

    def schedule(self, ctx):
        if self.split_sizes:
            ctx.split(self.split_sizes)
        parts = (list(range(len(self.split_sizes)))
                 if self.split_sizes else [FULL])
        while True:
            ready = [h for i in parts for h in ctx.get_ready_ops(i)]
            if not ready:
                break
            # maybe merge all micro-batch instances of one ready op
            if (self.split_sizes and self.rng.random() < self.merge_prob):
                by_oid = {}
                for h in ready:
                    by_oid.setdefault(h.oid, []).append(h)
                full = [v for v in by_oid.values()
                        if len(v) == len(self.split_sizes)]
                if full:
                    ctx.execute(tuple(self.rng.choice(len(full))
                                      is not None and full[
                                          self.rng.integers(len(full))]))
                    continue
            ctx.execute(ready[self.rng.integers(len(ready))])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000),
       split=st.sampled_from([(), (4, 4), (2, 6), (3, 5), (2, 2, 4)]),
       merge_prob=st.floats(0.0, 0.9))
def test_random_schedules_match_sequential(setup, seed, split, merge_prob):
    g, params, x, want = setup
    sched = RandomScheduler(seed, split, merge_prob)
    plan = record_plan(g, sched, ScheduleContext(local_batch=8))
    got = realize(g, plan, params, {"x": x})["out"]
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_schedules_on_partitioned_graph(setup, seed):
    g, params, x, want = setup
    from repro.core import SplitEveryOp
    coarse = partition(g, [SplitEveryOp()])
    sched = RandomScheduler(seed, (4, 4), 0.4)
    plan = record_plan(coarse, sched, ScheduleContext(local_batch=8))
    got = realize(coarse, plan, params, {"x": x})["out"]
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_dependency_violation_rejected(setup):
    g, params, x, want = setup

    class BadScheduler(OpSchedulerBase):
        def schedule(self, ctx):
            last = max(ctx.graph.nodes)
            ctx.execute(OpHandle(last, FULL, "out"))

    with pytest.raises(RuntimeError, match="dependency violation"):
        record_plan(g, BadScheduler(), ScheduleContext(local_batch=8))


def test_incomplete_schedule_rejected(setup):
    g, params, x, want = setup

    class LazyScheduler(OpSchedulerBase):
        def schedule(self, ctx):
            ctx.execute(ctx.get_ready_ops()[0])

    with pytest.raises(RuntimeError, match="incomplete"):
        record_plan(g, LazyScheduler(), ScheduleContext(local_batch=8))


def test_double_execution_rejected(setup):
    g, params, x, want = setup

    class DoubleScheduler(OpSchedulerBase):
        def schedule(self, ctx):
            h = ctx.get_ready_ops()[0]
            ctx.execute(h)
            ctx.execute(h)

    with pytest.raises(RuntimeError, match="already executed"):
        record_plan(g, DoubleScheduler(), ScheduleContext(local_batch=8))
