"""The cost-model autotuner: verdict determinism, persistence (zero
re-tunes across restart), registry resolution, auto-vs-hand parity, and
corrupt-verdict recovery."""
import warnings

import pytest

from repro.configs import get_smoke_config
from repro.core.autotune import (AutoPolicy, ExhaustiveOrder,
                                 TuningVerdict, _order_plan,
                                 context_fingerprint, pareto_front)
from repro.core.plan import scheduler_identity, strategy_salt
from repro.core.plan_serde import split_verdict_line, verdict_line
from repro.core.plan_store import PlanStore
from repro.core.policy import as_policy, resolve_strategy, with_graph
from repro.core.scheduler import ScheduleContext, record_plan
from repro.core.strategies import STRATEGIES, get_strategy
from repro.core.strategies.registry import (UnknownStrategyError,
                                            make_scheduler,
                                            register_strategy,
                                            strategy_names,
                                            tunable_candidates)
from repro.models.layers import MeshInfo
from repro.models.registry import build_model

ARCH = "chatglm3-6b"


def _seg_and_info(arch=ARCH, phase="train", B=8, S=32):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    if phase == "train":
        segs, _ = model.build_segments("train", B, S)
    else:
        segs, _ = model.build_segments(
            phase, B, 1 if phase == "decode" else S, s_max=S)
    pool = [s for s in segs if s.count > 1] or list(segs)
    seg = max(pool, key=lambda s: len(s.graph.nodes))
    info = ScheduleContext(local_batch=B, seq_len=S, phase=phase,
                           arch=cfg.name)
    return seg, info


# -- registry ----------------------------------------------------------------


def test_registry_names_and_resolution():
    names = strategy_names()
    for want in ("sequential", "nanoflow", "dbo", "sbo", "tokenweave",
                 "comet", "flux", "dynamic", "auto"):
        assert want in names
    assert get_strategy("sbo").name == "sbo"
    assert get_strategy("dynamic").identity()[0] == "dynamic"
    assert get_strategy("auto").identity()[0] == "auto"
    # STRATEGIES stays a name -> factory view for old call sites
    assert set(STRATEGIES) == set(names)
    assert STRATEGIES["sequential"]().name == "sequential"


def test_registry_unknown_name_is_typed_and_lists_choices():
    with pytest.raises(UnknownStrategyError) as ei:
        get_strategy("nope")
    assert isinstance(ei.value, KeyError)
    assert ei.value.unknown_name == "nope"
    msg = str(ei.value)
    for name in strategy_names():
        assert name in msg
    with pytest.raises(UnknownStrategyError):
        as_policy("also-nope")


def test_register_strategy_extends_every_consumer():
    class Mine(get_strategy("sequential").__class__):
        name = "mine_t"

    register_strategy("mine_t", Mine, {"k": (1, 2)}, overwrite=True)
    try:
        assert isinstance(make_scheduler("mine_t"), Mine)
        assert as_policy("mine_t")(ScheduleContext()).name == "mine_t"
        cands = list(tunable_candidates())
        assert ("mine_t", {"k": 1}) in cands
        assert ("mine_t", {"k": 2}) in cands
        with pytest.raises(ValueError):
            register_strategy("mine_t", Mine)    # no silent overwrite
    finally:
        from repro.core.strategies.registry import _REGISTRY
        _REGISTRY.pop("mine_t", None)


def test_dynamic_scheduler_is_deprecated_but_registry_path_is_silent():
    from repro import _deprecation
    from repro.core.strategies import DynamicScheduler
    _deprecation.reset()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        silent = get_strategy("dynamic", split_tokens=64)
        assert not rec
        DynamicScheduler()
        assert len(rec) == 1
        assert issubclass(rec[0].category, DeprecationWarning)
    _deprecation.reset()
    # the shim is behaviorally identical to the registry path
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert DynamicScheduler(split_tokens=64).identity() \
            == silent.identity()
    _deprecation.reset()


# -- verdict determinism -----------------------------------------------------


def test_verdict_is_deterministic():
    seg, info = _seg_and_info()
    assert context_fingerprint(info, seg.graph) \
        == context_fingerprint(info, seg.graph)
    a1, a2 = AutoPolicy(), AutoPolicy()
    s1 = a1(with_graph(info, seg.graph))
    s2 = a2(with_graph(info, seg.graph))
    v1, v2 = a1.lookup(info, seg.graph), a2.lookup(info, seg.graph)
    assert v1.winner == v2.winner
    assert v1.params == v2.params
    assert v1.scores == v2.scores
    assert v1.t_model == v2.t_model
    assert scheduler_identity(s1) == scheduler_identity(s2)
    # repeated resolution reuses the verdict: exactly one tune each
    a1(with_graph(info, seg.graph))
    assert a1.retunes == 1
    # the winner never models slower than the sequential baseline
    assert v1.t_model <= v1.t_sequential * (1 + 1e-9)


def test_verdict_payload_roundtrip_and_line_format():
    seg, info = _seg_and_info()
    a = AutoPolicy()
    a(with_graph(info, seg.graph))
    v = a.lookup(info, seg.graph)
    assert TuningVerdict.from_payload(v.to_payload()) == v
    fp, payload = split_verdict_line(verdict_line(v.context_fp,
                                                  v.to_payload()))
    assert fp == v.context_fp
    assert TuningVerdict.from_payload(payload) == v


def test_auto_policy_identity_salts_and_is_stable():
    s1 = strategy_salt(AutoPolicy())
    assert s1 == strategy_salt(AutoPolicy())
    assert s1.startswith("auto:")
    # calibration changes the identity -> different persisted namespace
    assert s1 != strategy_salt(AutoPolicy(bw_scale=0.125))
    assert s1 != strategy_salt(AutoPolicy(coll_latency_s=1e-3))
    # measurement knobs are refinements, not different policies
    assert s1 == strategy_salt(AutoPolicy(measure_top_k=3))


# -- persistence: restart inherits every decision ----------------------------


def test_verdict_persistence_zero_retunes_across_restart(tmp_path):
    seg, info = _seg_and_info()
    path = str(tmp_path / "plans.dfps")
    store = PlanStore()
    a = AutoPolicy()
    a.bind_store(store)
    a(with_graph(info, seg.graph))
    assert a.retunes == 1
    assert store.stats["verdicts_put"] == 1
    assert store.dirty
    store.save(path)

    store2 = PlanStore()
    store2.load(path)
    a2 = AutoPolicy()
    a2.bind_store(store2)
    sched = a2(with_graph(info, seg.graph))
    assert a2.retunes == 0
    assert store2.stats["verdict_hits"] == 1
    v, v2 = a.lookup(info, seg.graph), a2.lookup(info, seg.graph)
    assert v2 == v
    assert scheduler_identity(sched) \
        == scheduler_identity(a._scheduler_of(v.context_fp, v))
    # save again: verdicts pass through (the artifact never shrinks)
    p2 = str(tmp_path / "plans2.dfps")
    store2.save(p2)
    store3 = PlanStore()
    store3.load(p2)
    assert store3.get_verdict(v.context_fp) is not None


def test_corrupt_verdict_falls_back_to_cold_retune(tmp_path):
    seg, info = _seg_and_info()
    path = str(tmp_path / "plans.dfps")
    store = PlanStore()
    a = AutoPolicy()
    a.bind_store(store)
    a(with_graph(info, seg.graph))
    store.save(path)
    # flip bytes inside every verdict payload on disk
    with open(path) as f:
        lines = f.read().splitlines()
    with open(path, "w") as f:
        for ln in lines:
            if ln.startswith("V "):
                ln = ln[:-3] + "xxx"
            f.write(ln + "\n")
    store2 = PlanStore()
    store2.load(path)
    assert store2.stats["verdict_rejected"] >= 1
    a2 = AutoPolicy()
    a2.bind_store(store2)
    a2(with_graph(info, seg.graph))
    assert a2.retunes == 1          # cold re-tune, no crash
    assert a2.lookup(info, seg.graph).winner \
        == a.lookup(info, seg.graph).winner
    # a schema-corrupt but well-formed payload also re-tunes
    store3 = PlanStore()
    fp = a.lookup(info, seg.graph).context_fp
    store3.put_verdict(fp, {"version": 999, "garbage": True})
    a3 = AutoPolicy()
    a3.bind_store(store3)
    a3(with_graph(info, seg.graph))
    assert a3.retunes == 1


# -- parity: auto never loses to the hand-written policy ---------------------


@pytest.mark.parametrize("arch", ("chatglm3-6b", "deepseek-moe-16b"))
@pytest.mark.parametrize("phase,B,S", (("prefill", 8, 64),
                                       ("decode", 2, 32)))
def test_auto_never_loses_to_dynamic_policy(arch, phase, B, S):
    from repro.core.strategies.dynamic import dynamic_policy
    seg, info = _seg_and_info(arch, phase, B, S)
    auto = AutoPolicy()
    auto(with_graph(info, seg.graph))
    v = auto.lookup(info, seg.graph)
    # score dynamic's pick on the same union-partitioned graph with the
    # same objective the tuner used
    g = auto._tuning_graph(seg.graph)
    dyn = resolve_strategy(dynamic_policy(), info, graph=g)
    plan = record_plan(g, dyn, info)
    rep, _ = auto._score(g, plan, auto.tp)
    assert v.t_model <= rep.t_overlapped * (1 + 1e-9), (
        f"auto chose {v.winner} ({v.t_model}) but dynamic's "
        f"{dyn.name} is faster ({rep.t_overlapped})")


def test_exhaustive_order_replays_its_best_order():
    seg, info = _seg_and_info()
    auto = AutoPolicy()
    g = auto._tuning_graph(seg.graph)
    ex = ExhaustiveOrder(max_ops=len(g.nodes), max_orders=64)
    best = ex.best_order(g)
    assert best is not None
    plan = record_plan(g, ex, info)
    assert [s.handles[0].oid for s in plan.steps] == list(best[0])
    # the enumeration includes the plain topo order, so the best
    # enumerated order can never lose to it
    from repro.roofline.overlap import plan_overlap
    t_topo = plan_overlap(
        g, _order_plan(g, tuple(g.topo_order())), tp=ex.tp).t_overlapped
    assert best[1] <= t_topo * (1 + 1e-9)
    # over budget: falls back to sequential, never explodes
    tiny = ExhaustiveOrder(max_ops=1)
    assert tiny.best_order(g) is None
    plan2 = record_plan(g, tiny, info)
    assert len(plan2.steps) == len(g.nodes)


def test_pareto_front():
    pts = [("a", 1.0, 100), ("b", 2.0, 50), ("c", 2.0, 200),
           ("d", 0.5, 400)]
    assert pareto_front(pts) == [0, 1, 3]   # c dominated by b


# -- end to end through the facade -------------------------------------------


def test_compile_policy_auto_runs_and_explains(tmp_path):
    import repro.api

    prog = repro.api.compile(ARCH, policy="auto", smoke=True,
                             plan_store_path=str(tmp_path / "p.dfps"))
    assert isinstance(prog.policy, AutoPolicy)
    assert prog.policy._store is prog.store
    prog.prefill(global_batch=1, seq_len=16)
    assert prog.policy.retunes >= 1
    rows = prog.explain()
    assert rows and all("winner" in r for r in rows)
    assert all(r["speedup"] >= 1.0 - 1e-9 for r in rows)
    # a non-verdict policy still explains itself
    prog2 = repro.api.compile(ARCH, policy="sequential", smoke=True)
    (row,) = prog2.explain()
    assert row["policy"] == "sequential"


def test_program_save_load_roundtrips_verdicts(tmp_path):
    import repro.api

    prog = repro.api.compile(ARCH, policy="auto", smoke=True)
    prog.prefill(global_batch=1, seq_len=16)
    assert prog.policy.retunes >= 1
    assert prog.store.verdict_count >= 1
    bundle = str(tmp_path / "prog.dfpb")
    prog.save(bundle)

    prog2 = repro.api.Program.load(bundle)
    assert isinstance(prog2.policy, AutoPolicy)
    assert prog2.store.verdict_count == prog.store.verdict_count
    prog2.prefill(global_batch=1, seq_len=16)
    assert prog2.policy.retunes == 0, \
        "restart re-tuned despite persisted verdicts"
    assert prog2.stats["misses"] == 0, \
        f"loaded program re-lowered: {prog2.stats}"
    assert prog2.explain() == prog.explain()


def test_observe_feeds_measured_time_into_verdicts():
    seg, info = _seg_and_info()
    store = PlanStore()
    a = AutoPolicy()
    a.bind_store(store)
    a(with_graph(info, seg.graph))
    v0 = a.lookup(info, seg.graph)
    assert v0.measured_s == 0.0
    a.observe(phase=info.phase, arch=info.arch,
              local_batch=info.local_batch, seq_len=info.seq_len,
              seconds=1e-3)
    v1 = a.lookup(info, seg.graph)
    assert v1.measured_s == pytest.approx(1e-3)
    a.observe(phase=info.phase, arch=info.arch,
              local_batch=info.local_batch, seq_len=info.seq_len,
              seconds=2e-3)
    v2 = a.lookup(info, seg.graph)
    assert v2.measured_s == pytest.approx(0.8 * 1e-3 + 0.2 * 2e-3)
    # the refreshed verdict reached the store
    assert store.get_verdict(v0.context_fp)["measured_s"] > 0


def test_coll_latency_parameter_threads_from_hw():
    from repro import hw
    from repro.roofline import overlap
    assert overlap.COLL_LATENCY_S == hw.COLL_LATENCY_S
    seg, info = _seg_and_info("deepseek-moe-16b")
    auto = AutoPolicy()
    g = auto._tuning_graph(seg.graph)
    plan = record_plan(g, get_strategy("sequential"), info)
    rep0 = overlap.plan_overlap(g, plan, tp=16)
    rep1 = overlap.plan_overlap(g, plan, tp=16,
                                coll_latency_s=hw.COLL_LATENCY_S * 100)
    if rep0.coll_total > 0:
        assert rep1.t_sequential > rep0.t_sequential
    else:
        assert rep1.t_sequential == rep0.t_sequential
    # AutoPolicy calibration reaches the objective the tuner ranks with
    slow = AutoPolicy(coll_latency_s=hw.COLL_LATENCY_S * 100)
    rep_fast, _ = auto._score(g, plan, 16)
    rep_slow, _ = slow._score(g, plan, 16)
    assert rep_slow.t_sequential >= rep_fast.t_sequential
