"""PlanStore tests: the unified fingerprint-v2 plan/capture cache.

  * cross-bucket sharing — structurally identical (graph, plan) pairs at
    different shapes hit one canonical lowering; buckets 2..N are counted
    as shares and never re-run analysis + lowering,
  * differential — a specialized lowering agrees bitwise with the
    reference interpreter (``Realizer(lowered=False)``) on every bucket,
    including split/merge plans that exercise slice + pad rewriting,
  * fingerprint-v2 rejection — structural mismatches refuse to
    specialize (``LoweringError``) and the store falls back to a full
    lower; op-config / salt changes scope to distinct outer entries,
  * LRU — entry-count and byte-budget eviction with counters, canonical
    promotion after the canonical bucket is evicted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FULL, LoweringError, OpSchedulerBase, PlanStore,
                        Realizer, ScheduleContext, fingerprint_v2, lower,
                        record_plan, specialize, trace)
from repro.core.module import Module, Op, Param
from repro.core.plan import OpHandle, structural_key
from repro.core.plan_store import plan_nbytes

D = 8


class Lin(Op):
    def __init__(self, d_in, d_out, name):
        super().__init__()
        self.w = Param((d_in, d_out), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class Chain(Module):
    def __init__(self, n=4):
        super().__init__()
        self.n = n
        for i in range(n):
            setattr(self, f"l{i}", Lin(D, D, f"l{i}"))

    def forward(self, x):
        for i in range(self.n):
            x = getattr(self, f"l{i}")(x)
        return x


class SplitThenMerge(OpSchedulerBase):
    """Per-part chain ending in a merged step: exercises slice reads and
    the pad-created merge buffer, the shape-dependent halves of an
    instruction stream."""

    def __init__(self, sizes):
        self.sizes = sizes

    def schedule(self, ctx):
        ctx.split(self.sizes)
        oids = ctx.graph.topo_order()
        for oid in oids[:-1]:
            for p in range(len(self.sizes)):
                ctx.execute(OpHandle(oid, p, ""))
        ctx.execute(tuple(OpHandle(oids[-1], p, "")
                          for p in range(len(self.sizes))))


def _bucket(net, B, sizes, seed=0):
    g = trace(net, {"x": jax.ShapeDtypeStruct((B, D), jnp.float32)})
    plan = record_plan(g, SplitThenMerge(sizes),
                       ScheduleContext(local_batch=B))
    params = net.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, D))
    return g, plan, params, x


def _assert_same(a: dict, b: dict):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"output {k!r} diverged")


# ---------------------------------------------------------------------------
# cross-bucket sharing + differential agreement
# ---------------------------------------------------------------------------


def test_cross_bucket_share_counters_and_differential():
    net = Chain()
    store = PlanStore()
    for i, (B, sizes) in enumerate([(8, (4, 4)), (16, (8, 8)),
                                    (12, (4, 8))]):
        g, plan, params, x = _bucket(net, B, sizes)
        lowered = store.get_or_lower(g, plan, salt="t")
        _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                     lowered(params, {"x": x}))
    assert store.stats["misses"] == 1          # first bucket pays lowering
    assert store.stats["shares"] == 2          # buckets 2..3 specialize
    assert store.stats["hits"] == 0
    assert store.share_rate == pytest.approx(2 / 3)
    # re-requesting a known bucket is a hit, not a share
    g, plan, *_ = _bucket(net, 8, (4, 4))
    store.get_or_lower(g, plan, salt="t")
    assert store.stats["hits"] == 1


def test_specialized_plan_matches_fresh_lower():
    """Specialization must produce the same instruction semantics as a
    from-scratch lowering of the new bucket."""
    net = Chain()
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    g2, p2, params, x = _bucket(net, 16, (6, 10))
    canon = lower(g1, p1)
    spec = specialize(canon, g2, p2)
    fresh = lower(g2, p2)
    assert spec.fingerprint == fresh.fingerprint
    assert spec.n_slots == fresh.n_slots
    assert spec.input_slots == fresh.input_slots
    assert spec.output_slots == fresh.output_slots
    for a, b in zip(spec.instrs, fresh.instrs):
        assert a.reads == b.reads
        assert a.frees == b.frees
        # writes carry a numpy pad seed; compare structure
        assert len(a.writes) == len(b.writes)
        for (sa, ba), (sb, bb) in zip(a.writes, b.writes):
            assert sa == sb
            assert (ba is None) == (bb is None)
            if ba is not None:
                assert ba[:3] == bb[:3]
    _assert_same(fresh(params, {"x": x}), spec(params, {"x": x}))


def test_unsplit_plans_share_across_buckets():
    net = Chain()
    store = PlanStore()

    class Seq(OpSchedulerBase):
        pass

    for B in (4, 8, 32):
        g = trace(net, {"x": jax.ShapeDtypeStruct((B, D), jnp.float32)})
        plan = record_plan(g, Seq(), ScheduleContext(local_batch=B))
        store.get_or_lower(g, plan)
    assert store.stats["misses"] == 1
    assert store.stats["shares"] == 2


# ---------------------------------------------------------------------------
# fingerprint v2: rejection + scoping
# ---------------------------------------------------------------------------


def test_specialize_rejects_structural_mismatch():
    net4, net5 = Chain(4), Chain(5)
    g1, p1, *_ = _bucket(net4, 8, (4, 4))
    g2, p2, *_ = _bucket(net5, 8, (4, 4))
    assert structural_key(g1, p1) != structural_key(g2, p2)
    canon = lower(g1, p1)
    with pytest.raises(LoweringError, match="cannot specialize"):
        specialize(canon, g2, p2)


def test_split_count_is_structural():
    """Same graph, different micro-batch *count*: never shared.  (The
    decode-tier analogue: a batch tier whose scheduler changes the split
    count becomes its own canonical instead of specializing.)"""
    net = Chain()
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    g2, p2, *_ = _bucket(net, 9, (3, 3, 3))
    assert structural_key(g1, p1) != structural_key(g2, p2)
    store = PlanStore()
    store.get_or_lower(g1, p1)
    store.get_or_lower(g2, p2)
    assert store.stats["misses"] == 2
    assert store.stats["shares"] == 0
    # distinct outer keys never reach the specialize attempt
    assert store.stats["specialize_rejects"] == 0


def test_specialize_fallback_is_counted(monkeypatch):
    """When a canonical exists but specialize rejects (structure drift),
    the store falls back to a cold lower and counts the reject."""
    from repro.core import plan_store as plan_store_mod
    net = Chain()
    store = PlanStore()
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    store.get_or_lower(g1, p1)

    def always_reject(*a, **k):
        raise LoweringError("forced drift")
    monkeypatch.setattr(plan_store_mod, "specialize", always_reject)
    g2, p2, params, x = _bucket(net, 16, (8, 8))
    lowered = store.get_or_lower(g2, p2)
    assert store.stats["specialize_rejects"] == 1
    assert store.stats["misses"] == 2           # fell back to a cold lower
    _assert_same(Realizer(g2, p2, lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))


def test_fused_closure_config_scopes_outer_key():
    """Two same-class schedulers whose fused kernels close over different
    config must not alias: partial kwargs enter the structural key."""
    import functools

    def scaled(info, x, factor=1.0):
        p = info.params_of(0)
        return jnp.tanh(x @ p["w"]) * factor

    class FuseFirst(OpSchedulerBase):
        def __init__(self, factor):
            self.fn = functools.partial(scaled, factor=factor)

        def schedule(self, ctx):
            oids = ctx.graph.topo_order()
            ctx.execute((OpHandle(oids[0], FULL, ""),),
                        replace_func=self.fn, replace_name="scaled")
            ctx.run_rest_sequential()

    net = Chain(3)
    store = PlanStore()
    outs = {}
    for factor in (2.0, 100.0):
        g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
        plan = record_plan(g, FuseFirst(factor),
                           ScheduleContext(local_batch=8))
        params = net.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
        lowered = store.get_or_lower(g, plan, salt="FuseFirst")
        outs[factor] = np.asarray(lowered(params, {"x": x})["out"])
    assert store.stats["misses"] == 2       # different closures: no alias
    assert store.stats["shares"] == 0 and store.stats["hits"] == 0
    assert not np.allclose(outs[2.0], outs[100.0])
    # same closure config at a new bucket still shares
    g = trace(net, {"x": jax.ShapeDtypeStruct((16, D), jnp.float32)})
    plan = record_plan(g, FuseFirst(2.0), ScheduleContext(local_batch=16))
    store.get_or_lower(g, plan, salt="FuseFirst")
    assert store.stats["shares"] == 1


def test_op_config_and_salt_scope_outer_key():
    net = Chain()
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    g2, p2, *_ = _bucket(net, 16, (8, 8))
    cfg_a = (("attn_impl", "xla"), ("tp", 1))
    cfg_b = (("attn_impl", "pallas"), ("tp", 1))
    assert fingerprint_v2(g1, p1, op_config=cfg_a) != \
        fingerprint_v2(g1, p1, op_config=cfg_b)
    assert fingerprint_v2(g1, p1, salt="a") != fingerprint_v2(g1, p1,
                                                              salt="b")
    store = PlanStore()
    store.get_or_lower(g1, p1, op_config=cfg_a)
    store.get_or_lower(g2, p2, op_config=cfg_b)   # same structure, new cfg
    assert store.stats["misses"] == 2             # must NOT share
    store.get_or_lower(g2, p2, op_config=cfg_a)   # matching cfg: shares
    assert store.stats["shares"] == 1


# ---------------------------------------------------------------------------
# LRU: byte budget, canonical promotion
# ---------------------------------------------------------------------------


def test_lru_eviction_under_byte_budget():
    net = Chain()
    one = plan_nbytes(lower(*_bucket(net, 8, (4, 4))[:2]))
    store = PlanStore(plan_budget_bytes=int(one * 2.5))
    buckets = [(8, (4, 4)), (16, (8, 8)), (12, (4, 8)), (20, (10, 10)),
               (24, (12, 12))]
    for B, sizes in buckets:
        g, plan, params, x = _bucket(net, B, sizes)
        lowered = store.get_or_lower(g, plan)
        _assert_same(Realizer(g, plan, lowered=False)(params, {"x": x}),
                     lowered(params, {"x": x}))
    assert store.stats["evictions"] >= len(buckets) - 2
    assert store.n_plans <= 2
    assert store.stats["plan_bytes"] <= int(one * 2.5)
    # byte accounting survives eviction churn
    assert store.stats["plan_bytes"] == sum(
        e[1] for e in store._plans.values())


def test_canonical_promotion_after_eviction():
    """Evicting the canonical bucket must not kill sharing: a surviving
    bucket of the same outer entry is promoted to canonical."""
    net = Chain()
    store = PlanStore(plan_capacity=1)
    g1, p1, *_ = _bucket(net, 8, (4, 4))
    g2, p2, *_ = _bucket(net, 16, (8, 8))
    g3, p3, params, x = _bucket(net, 12, (6, 6))
    store.get_or_lower(g1, p1)            # canonical (miss)
    store.get_or_lower(g2, p2)            # share; evicts bucket 1
    assert store.stats["evictions"] == 1
    lowered = store.get_or_lower(g3, p3)  # must still share, off bucket 2
    assert store.stats["shares"] == 2
    assert store.stats["misses"] == 1
    _assert_same(Realizer(g3, p3, lowered=False)(params, {"x": x}),
                 lowered(params, {"x": x}))


def test_full_eviction_of_outer_entry_recovers():
    net = Chain()
    store = PlanStore(plan_capacity=1)

    class Seq(OpSchedulerBase):
        pass

    g1 = trace(Chain(2), {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    p1 = record_plan(g1, Seq(), ScheduleContext(local_batch=8))
    store.get_or_lower(g1, p1)
    g2, p2, *_ = _bucket(net, 8, (4, 4))
    store.get_or_lower(g2, p2)            # different structure: evicts g1
    # g1's outer entry is gone entirely; asking again is a clean miss
    store.get_or_lower(g1, p1)
    assert store.stats["misses"] == 3
    assert store.stats["shares"] == 0


# ---------------------------------------------------------------------------
# capture/replay survives specialization
# ---------------------------------------------------------------------------


def test_specialized_plans_capture_independently():
    net = Chain()
    store = PlanStore()
    g1, p1, params1, x1 = _bucket(net, 8, (4, 4))
    g2, p2, params2, x2 = _bucket(net, 16, (8, 8))
    l1 = store.get_or_lower(g1, p1)
    l2 = store.get_or_lower(g2, p2)
    assert store.stats["shares"] == 1
    jax.make_jaxpr(lambda p, v: l1(p, {"x": v}))(params1, x1)
    jax.make_jaxpr(lambda p, v: l2(p, {"x": v}))(params2, x2)
    assert l1.stats.get("captures") == 1
    assert l2.stats.get("captures") == 1   # own replay cache, own captures
    jax.make_jaxpr(lambda p, v: l2(p, {"x": v}))(params2, x2)
    assert l2.stats.get("replays", 0) >= 1
