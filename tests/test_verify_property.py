"""Property test: the verifier and the runtime agree.

Over random DAGs × random valid schedules (splits, merges, fused
groups) plus random plan mutations (drop / duplicate / swap steps):

  * a freshly recorded plan always verifies clean, interprets, lowers,
    and both backends agree bitwise,
  * if the interpreter rejects a mutated plan, the verifier flagged at
    least one error-severity diagnostic for it (no false negatives),
  * if the verifier says a mutated plan is clean, the interpreter
    executes it and reproduces the unmutated plan's outputs (no false
    positives on reordered-but-valid schedules).

Duplicated steps are the one asymmetry: the interpreter happily
recomputes them, the verifier flags VFY004 — so the converse direction
(flagged => rejected) is intentionally NOT a property.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import Realizer, ScheduleContext, record_plan
from repro.core.plan import ExecutionPlan
from repro.core.verify import verify
from test_lowering import RandomScheduler, _assert_same, _setup


def _mutate(plan, kind, rng):
    steps = list(plan.steps)
    if len(steps) < 2:
        return plan
    i = int(rng.integers(len(steps)))
    j = int(rng.integers(len(steps)))
    if kind == "drop":
        del steps[i]
    elif kind == "dup":
        steps.insert(i, steps[i])
    else:                                      # swap
        steps[i], steps[j] = steps[j], steps[i]
    return ExecutionPlan(steps, plan.split_sizes, plan.graph_fingerprint)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_ops=st.integers(3, 8),
       split=st.sampled_from([(), (4, 4), (2, 6), (2, 2, 4)]),
       merge_prob=st.floats(0.0, 0.9))
def test_recorded_plans_always_verify_clean(seed, n_ops, split, merge_prob):
    g, params, x = _setup(seed % 50, n_ops)
    plan = record_plan(g, RandomScheduler(seed, split, merge_prob),
                       ScheduleContext(local_batch=8))
    rep = verify(g, plan)
    assert rep.ok, rep.pretty()
    want = Realizer(g, plan, lowered=False)(params, {"x": x})
    rz = Realizer(g, plan, lowered=True)
    assert not verify(g, plan, lowered=rz.lowered, lint=False).errors
    _assert_same(want, rz(params, {"x": x}))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_ops=st.integers(3, 8),
       split=st.sampled_from([(), (4, 4), (2, 6)]),
       merge_prob=st.floats(0.0, 0.9),
       kind=st.sampled_from(["drop", "dup", "swap"]))
def test_verifier_agrees_with_interpreter_on_mutations(seed, n_ops, split,
                                                       merge_prob, kind):
    g, params, x = _setup(seed % 50, n_ops)
    plan = record_plan(g, RandomScheduler(seed, split, merge_prob),
                       ScheduleContext(local_batch=8))
    rng = np.random.default_rng(seed + 1)
    mut = _mutate(plan, kind, rng)
    rep = verify(g, mut)
    try:
        got = Realizer(g, mut, lowered=False)(params, {"x": x})
        executed = True
    except Exception:                          # noqa: BLE001
        executed = False
    if not executed:
        # runtime rejection implies at least one typed error diagnostic
        assert rep.errors, (kind, rep.pretty())
    if rep.ok:
        # verifier-clean implies the runtime executes AND the mutation
        # was semantically neutral (e.g. a swap of independent steps)
        assert executed
        want = Realizer(g, plan, lowered=False)(params, {"x": x})
        _assert_same(want, got)
