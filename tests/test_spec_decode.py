"""Speculative multi-token decode + jitted on-device sampling tests.

The contract under test (PR 9 acceptance criteria):

  * speculative greedy decode is bitwise identical to plain greedy
    decode for every (proposer, k, backend) combination — acceptance /
    rollback is lossless, including across preemption-resume and a
    PlanStore warm restart with zero ``lower()`` calls on verify
    buckets;
  * sampled runs are reproducible from ``(seed, rid, position)`` alone:
    batch composition, tier, and restarts don't change the tokens, and
    speculative sampled decode equals plain sampled decode bitwise;
  * seeds are runtime arguments — they never salt an executable key;
  * paged rollback under injected allocation denials falls back to
    plain decode for the iteration and leaks nothing;
  * chunked prefill packs same-width chunk slabs from different
    requests into one bucketed call;
  * ``SpecConfig(k="auto")`` consults ``AutoPolicy.spec_draft_k``,
    which explores the registered ``spec_decode`` candidates and then
    exploits measured acceptance, persisting its scoreboard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import PlanStore
from repro.core.autotune import AutoPolicy
from repro.core.strategies import get_strategy
from repro.core.strategies.registry import get_entry
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import (
    FaultInjector,
    NGramProposer,
    PagedCache,
    Request,
    SamplingConfig,
    ServeConfig,
    ServeEngine,
    SpecConfig,
)
from repro.serve.sampling import GREEDY, sample_tokens, sampling_salt


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    # one shared store: every engine below replays the same lowered
    # plans and compiled steps instead of re-jitting per test
    return cfg, model, params, PlanStore(exec_capacity=256)


def make_engine(setup, scheduler="sequential", store=None, **kw):
    _, model, params, shared = setup
    kw.setdefault("max_batch", 4)
    kw.setdefault("s_max", 64)
    kw.setdefault("prefill_buckets", (16, 32))
    sched = get_strategy(scheduler) if isinstance(scheduler, str) \
        else scheduler
    return ServeEngine(model, params, sched, ServeConfig(**kw),
                       plan_store=shared if store is None else store)


def prompts_for(n, seed=0, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 100, int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def run_outputs(eng, reqs):
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.ok for r in done), [r.result for r in done if not r.ok]
    return {r.rid: list(r.output) for r in done}


def trace(n=4, seed=3, max_new=10, stagger=True, **req_kw):
    """Staggered max_new so rows finish at different times and the
    engine walks down through the decode tiers mid-run."""
    reqs = []
    for i, pr in enumerate(prompts_for(n, seed=seed)):
        mn = max_new + (2 * i if stagger else 0)
        reqs.append(Request(rid=i, prompt=pr.copy(), max_new_tokens=mn,
                            **req_kw))
    return reqs


# -- sampling unit tests -----------------------------------------------------

def test_greedy_sample_tokens_is_argmax():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((4, 7, 50)),
                         jnp.float32)
    toks = sample_tokens(logits, GREEDY, seeds=jnp.zeros((4, 1), jnp.uint32),
                         rids=jnp.zeros((4, 1), jnp.int32),
                         positions=jnp.zeros((4, 7), jnp.int32))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(np.asarray(logits), axis=-1))
    # None resolves to greedy (the historical engine default)
    toks2 = sample_tokens(logits, None, seeds=0, rids=0, positions=0)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_sampled_tokens_depend_only_on_seed_rid_position():
    """The determinism contract: batch composition doesn't matter, only
    the (seed, rid, position) triple each element carries."""
    cfg = SamplingConfig(temperature=0.7, top_k=30)
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((4, 200)), jnp.float32)
    seeds = jnp.asarray([1, 1, 2, 2], jnp.uint32)
    rids = jnp.asarray([0, 1, 0, 1], jnp.int32)
    pos = jnp.asarray([5, 5, 9, 9], jnp.int32)
    full = np.asarray(sample_tokens(logits, cfg, seeds=seeds, rids=rids,
                                    positions=pos))
    # permuted batch: same per-element triples -> same tokens
    perm = np.asarray([2, 0, 3, 1])
    shuf = np.asarray(sample_tokens(logits[perm], cfg, seeds=seeds[perm],
                                    rids=rids[perm], positions=pos[perm]))
    np.testing.assert_array_equal(full[perm], shuf)
    # each row sampled alone equals the row inside the batch
    for i in range(4):
        solo = sample_tokens(logits[i:i + 1], cfg, seeds=seeds[i:i + 1],
                             rids=rids[i:i + 1], positions=pos[i:i + 1])
        assert int(np.asarray(solo)[0]) == int(full[i])
    # the position must enter the key: across many positions at least
    # one draw differs from the position-5 draw
    many = np.asarray(sample_tokens(
        jnp.broadcast_to(logits[0], (16, 200)), cfg,
        seeds=jnp.full((16,), 1, jnp.uint32),
        rids=jnp.zeros((16,), jnp.int32),
        positions=jnp.arange(16, dtype=jnp.int32)))
    assert len(set(many.tolist())) > 1


def test_sampling_salt_and_validation():
    assert sampling_salt(None) == "greedy"
    assert sampling_salt(GREEDY) == "greedy"
    assert sampling_salt(SamplingConfig(temperature=0.8, top_k=20,
                                        top_p=0.9)) == "t0.8k20p0.9"
    with pytest.raises(ValueError):
        SamplingConfig(temperature=-1.0)
    with pytest.raises(ValueError):
        SamplingConfig(top_p=0.0)
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(proposer="nope")
    SpecConfig(k="auto")                       # valid


def test_ngram_proposer_drafts_continuations():
    prop = NGramProposer()
    # trailing 3-gram [1,2,3] occurred at 0; continuation is [4,1,2]
    d = prop.draft([[1, 2, 3, 4, 1, 2, 3]], 3)
    np.testing.assert_array_equal(d, [[4, 1, 2]])
    # no earlier occurrence: repeat the last token
    d = prop.draft([[5]], 4)
    np.testing.assert_array_equal(d, [[5, 5, 5, 5]])
    # short continuation pads with its own last token
    d = prop.draft([[7, 8, 7, 8]], 4)
    assert d.shape == (1, 4)


# -- bitwise spec-greedy == plain-greedy -------------------------------------

@pytest.fixture(scope="module")
def plain_greedy(setup):
    """Plain greedy outputs for the standard trace, per backend."""
    out = {}
    for cache in ("dense", "paged"):
        eng = make_engine(setup, cache=_backend(cache))
        out[cache] = run_outputs(eng, trace())
    assert out["dense"] == out["paged"]
    return out


def _backend(cache):
    return PagedCache(page_size=16) if cache == "paged" else None


@pytest.mark.parametrize("cache", ("dense", "paged"))
@pytest.mark.parametrize("proposer,k", [("ngram", 2), ("ngram", 4),
                                        ("self", 2), ("self", 4)])
def test_spec_greedy_bitwise_equals_plain(setup, plain_greedy, proposer, k,
                                          cache):
    eng = make_engine(setup, cache=_backend(cache),
                      spec=SpecConfig(proposer=proposer, k=k))
    got = run_outputs(eng, trace())
    assert got == plain_greedy[cache]
    st = eng.stats
    assert st["spec_steps"] > 0
    assert len(st["tier_steps"]) > 1           # staggered trace: tiers moved


def test_spec_greedy_with_eos_mid_draft(setup, plain_greedy):
    """An eos token accepted inside a draft window must cut the stream
    exactly where plain decode would have stopped."""
    # pick an eos that plain greedy emits mid-output for some request
    eos, rid = None, None
    for r, out in plain_greedy["dense"].items():
        if len(out) > 3:
            eos, rid = out[2], r
            break
    assert eos is not None
    plain = make_engine(setup)
    want = run_outputs(plain, trace(eos_id=eos))
    spec = make_engine(setup, spec=SpecConfig(proposer="ngram", k=4))
    got = run_outputs(spec, trace(eos_id=eos))
    assert got == want
    assert len(want[rid]) <= len(plain_greedy["dense"][rid])


def test_spec_survives_preemption_resume(setup):
    """Preempt-and-requeue under a memory-pressure window: the resumed
    speculative rows still match an uninterrupted plain run bitwise."""
    plain = make_engine(setup)
    want = run_outputs(plain, trace(seed=14, stagger=False, max_new=6))

    faults = FaultInjector(pressure=((2, 5, 3),))   # capacity 4 -> 1
    eng = make_engine(setup, faults=faults,
                      spec=SpecConfig(proposer="ngram", k=2))
    got = run_outputs(eng, trace(seed=14, stagger=False, max_new=6))
    assert got == want
    assert eng.stats["preempted"] >= 1


# -- sampled determinism -----------------------------------------------------

SAMPLED = SamplingConfig(temperature=0.8, top_k=20)


def test_sampled_runs_reproducible_across_batches_and_restart(setup):
    """Fixed (seed, rid, position) triples pin every sampled token: the
    same requests produce the same streams whether submitted together,
    in waves, or into a freshly built engine."""
    def reqs():
        return [Request(rid=i, prompt=pr.copy(), max_new_tokens=8,
                        seed=100 + i)
                for i, pr in enumerate(prompts_for(4, seed=5))]

    eng = make_engine(setup, sampling=SAMPLED)
    together = run_outputs(eng, reqs())
    assert any(together[i] != together[j]
               for i in together for j in together if i != j)

    eng2 = make_engine(setup, sampling=SAMPLED)      # "restart"
    waves = {}
    rs = reqs()
    waves.update(run_outputs(eng2, rs[:1]))          # different batch
    waves.update(run_outputs(eng2, rs[1:]))          # compositions
    assert waves == together


def test_spec_sampled_equals_plain_sampled(setup):
    """Speculative decode is lossless under sampling: the verify step
    re-samples each position with the key plain decode would have used,
    so the accepted stream is bitwise identical."""
    def reqs():
        return [Request(rid=i, prompt=pr.copy(), max_new_tokens=8,
                        seed=7 * i)
                for i, pr in enumerate(prompts_for(4, seed=6))]

    plain = make_engine(setup, sampling=SAMPLED)
    want = run_outputs(plain, reqs())
    spec = make_engine(setup, sampling=SAMPLED,
                      spec=SpecConfig(proposer="ngram", k=3))
    got = run_outputs(spec, reqs())
    assert got == want


def test_engine_seed_default_and_request_override(setup):
    """Request(seed=) overrides ServeConfig(seed=); an explicit request
    seed equal to the engine seed is indistinguishable from relying on
    the default."""
    pr = prompts_for(1, seed=8)[0]

    def run_one(engine_seed, req_seed):
        eng = make_engine(setup, sampling=SAMPLED, seed=engine_seed)
        return run_outputs(eng, [Request(rid=0, prompt=pr.copy(),
                                         max_new_tokens=6,
                                         seed=req_seed)])[0]

    assert run_one(11, None) == run_one(0, 11) == run_one(11, 11)
    assert run_one(11, None) != run_one(12, None)


def test_seed_never_salts_executable_keys(setup):
    """Seeds are runtime args: engines differing only in seed must
    produce identical executable-cache key sets."""
    keys = []
    for seed in (0, 123):
        store = PlanStore()
        eng = make_engine(setup, store=store, sampling=SAMPLED, seed=seed,
                          spec=SpecConfig(proposer="ngram", k=2))
        eng.warmup()
        run_outputs(eng, [Request(rid=0, prompt=prompts_for(1)[0],
                                  max_new_tokens=4, seed=seed)])
        keys.append(sorted(map(repr, store._execs.keys())))
    assert keys[0] == keys[1]
    assert any("spec_verify" in k for k in keys[0])


# -- warm restart ------------------------------------------------------------

def test_spec_warm_restart_zero_lowers_on_verify_buckets(setup, tmp_path,
                                                         monkeypatch):
    """A restarted engine must restore/specialize every verify bucket
    from the persisted store — never a cold ``lower()``."""
    path = str(tmp_path / "spec.dfps")
    spec_cfg = SpecConfig(proposer="ngram", k=4)
    store = PlanStore(path=path)
    eng = make_engine(setup, store=store, spec=spec_cfg)
    eng.warmup()
    run_outputs(eng, trace(seed=9))
    assert store.save() >= 1

    from repro.core import plan_store as plan_store_mod

    def bomb(*a, **k):
        raise AssertionError("warm restart re-lowered a verify bucket")
    monkeypatch.setattr(plan_store_mod, "lower", bomb)
    store2 = PlanStore.open(path)
    eng2 = make_engine(setup, store=store2, spec=spec_cfg)
    eng2.warmup()                                  # would bomb on lower
    builds = eng2.stats["spec_builds"]
    assert builds and all(b["misses"] == 0 for b in builds.values()), builds
    assert sum(b["shares"] + b["restore_hits"]
               for b in builds.values()) > 0, builds
    # and the restarted engine actually serves traffic on those plans
    got = run_outputs(eng2, trace(seed=9))
    plain = make_engine(setup)
    assert got == run_outputs(plain, trace(seed=9))


# -- paged rollback under faults ---------------------------------------------

def test_paged_rollback_under_alloc_denial(setup):
    """Mid-run allocation denials make the verify reservation fail: the
    engine falls back to plain decode for that iteration, stays bitwise
    correct, and frees every page at the end."""
    plain = make_engine(setup, cache=_backend("paged"))
    want = run_outputs(plain, trace(seed=10, max_new=12))

    faults = FaultInjector(alloc_fail=(4, 5, 6, 7))
    eng = make_engine(setup, cache=_backend("paged"), faults=faults,
                      spec=SpecConfig(proposer="ngram", k=4))
    got = run_outputs(eng, trace(seed=10, max_new=12))
    assert got == want
    st = eng.stats
    assert st["spec_fallbacks"] >= 1, st
    assert st["spec_steps"] > 0, st
    assert int(eng.cache.blocks_used.sum()) == 0      # no page leak
    assert eng.cache.row_owner == {}


# -- batched chunked prefill -------------------------------------------------

def test_chunked_prefill_packs_same_width_slabs(setup):
    """Two chunked prompts admitted together ride one bucketed chunk
    call per step (a real batch dimension), and the outputs match the
    one-at-a-time path bitwise."""
    rng = np.random.default_rng(20)
    prompts = [rng.integers(0, 100, 40).astype(np.int32) for _ in range(2)]

    def reqs():
        return [Request(rid=i, prompt=p.copy(), max_new_tokens=6)
                for i, p in enumerate(prompts)]

    solo = make_engine(setup, prefill_batch=1)
    want = run_outputs(solo, reqs())
    packed = make_engine(setup)
    got = run_outputs(packed, reqs())
    assert got == want
    chunk_calls = [e for e in packed.dispatch_log if e[0] == "chunk"]
    assert any(len(e[1]) > 1 for e in chunk_calls), packed.dispatch_log
    assert packed.stats["chunk_steps"] < solo.stats["chunk_steps"]


# -- draft-k autotuning ------------------------------------------------------

def test_spec_decode_registry_param_space():
    entry = get_entry("spec_decode")
    assert dict(entry.param_space)["draft_k"] == (2, 4, 8)
    assert not entry.tunable            # not a scheduler candidate
    entry.factory(draft_k=4)            # knob carrier builds a scheduler


def test_auto_policy_spec_draft_k_explore_then_exploit():
    policy = AutoPolicy()
    store = PlanStore()
    policy.bind_store(store)
    arch, cands = "toy-arch", (2, 4, 8)
    # exploration: untried candidates first, in order
    seen = []
    for _ in cands:
        k = policy.spec_draft_k(arch=arch, candidates=cands)
        seen.append(k)
        policy.observe(phase="spec_decode", arch=arch, local_batch=4,
                       seq_len=k, seconds=0.01,
                       stats={"draft_k": k,
                              "acceptance_rate": 0.9 if k == 4 else 0.1})
    assert seen == [2, 4, 8]
    # exploitation: k=4 has by far the best accepted-tokens/s
    assert policy.spec_draft_k(arch=arch, candidates=cands) == 4
    # the scoreboard persisted; a fresh policy on the same store resumes
    fresh = AutoPolicy()
    fresh.bind_store(store)
    assert fresh.spec_draft_k(arch=arch, candidates=cands) == 4


def test_spec_auto_k_engine_stays_bitwise_greedy(setup, plain_greedy):
    """k='auto' with the auto policy: whatever k the picker explores,
    greedy outputs never change."""
    eng = make_engine(setup, scheduler=get_strategy("auto"),
                      spec=SpecConfig(proposer="ngram", k="auto"))
    got = run_outputs(eng, trace())
    assert got == plain_greedy["dense"]
    assert eng.stats["spec_steps"] > 0


# -- guard rails -------------------------------------------------------------

def test_spec_rejects_recurrent_state_models():
    cfg = get_smoke_config("mamba2-2.7b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="positional"):
        ServeEngine(model, params, get_strategy("sequential"),
                    ServeConfig(max_batch=2, s_max=64,
                                prefill_buckets=(32,),
                                spec=SpecConfig(proposer="ngram", k=2)))


def test_spec_k_must_fit_smallest_bucket(setup):
    with pytest.raises(ValueError, match="verify width"):
        make_engine(setup, spec=SpecConfig(proposer="ngram", k=16))
