"""Request-lifecycle hardening tests: admission control, deadlines,
preempt-and-requeue, fault isolation, drain/shutdown, and the chaos
harness.

The contract under test (the robustness acceptance criteria): under
injected allocation failures, dispatch exceptions, and memory pressure,
every submitted request terminates in exactly one of
{Finished, Shed, Failed} with matching stats counters, zero leaked KV
rows (pool fully free after drain), and preempted-then-resumed requests
produce bitwise-identical tokens to an uninterrupted run."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.strategies import get_strategy
from repro.models.layers import MeshInfo
from repro.models.registry import build_model
from repro.serve import (
    BoundedQueue,
    CacheRowError,
    ChunkingDisabled,
    EmptyPrompt,
    EngineDraining,
    Failed,
    FaultInjector,
    Finished,
    KVCacheManager,
    Overloaded,
    PromptOverflow,
    RejectedRequest,
    Request,
    ServeConfig,
    ServeEngine,
    Shed,
)
from repro.serve.admission import (
    AdmissionContext,
    AdmitAll,
    DeadlineGate,
    PriorityFloor,
    admission_chain,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("chatglm3-6b")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 1, 32, s_max=64)
    params = model._init_from_segments(segs, jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("s_max", 64)
    kw.setdefault("prefill_buckets", (16, 32))
    return ServeEngine(model, params, get_strategy("sequential"),
                       ServeConfig(**kw))


def prompts_for(n, seed=0, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 100, int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def assert_lifecycle_clean(eng, submitted):
    """Every submitted request reached exactly one terminal state, the
    counters agree, and the KV pool leaked nothing."""
    st = eng.stats
    assert len(eng.finished) == submitted, (len(eng.finished), submitted)
    for r in eng.finished:
        assert isinstance(r.result, (Finished, Shed, Failed)), r
        assert r.done_s > 0, r
        assert r.row == -1, r
    kinds = {"finished": 0, "shed": 0, "failed": 0}
    for r in eng.finished:
        kinds[{Finished: "finished", Shed: "shed",
               Failed: "failed"}[type(r.result)]] += 1
    assert kinds["finished"] == st["finished"], (kinds, st)
    assert kinds["shed"] == st["shed"], (kinds, st)
    assert kinds["failed"] == st["failed"], (kinds, st)
    assert st["submitted"] == submitted
    assert st["finished"] + st["shed"] + st["failed"] == submitted
    # zero leaked rows: the pool is fully free and owner-less
    assert len(eng.cache.free_rows) == eng.cfg.max_batch
    assert eng.cache.row_owner == {}
    assert not eng.active and not eng._chunking and not eng.waiting


# -- typed submit rejects (satellite: RejectedRequest hierarchy) ------------

def test_rejected_request_hierarchy(setup):
    cfg, model, params = setup
    eng = make_engine(model, params)
    with pytest.raises(EmptyPrompt):
        eng.submit(Request(rid=0, prompt=np.zeros(0, np.int32)))
    # every typed reject is still a ValueError with the old message
    with pytest.raises(ValueError, match="s_max"):
        eng.submit(Request(rid=1, prompt=np.zeros(64, np.int32)))
    with pytest.raises(PromptOverflow):
        eng.submit(Request(rid=2, prompt=np.zeros(64, np.int32)))
    eng2 = make_engine(model, params, chunked_prefill=False)
    with pytest.raises(ValueError, match="largest prefill bucket"):
        eng2.submit(Request(rid=3, prompt=np.arange(40, dtype=np.int32)))
    with pytest.raises(ChunkingDisabled):
        eng2.submit(Request(rid=4, prompt=np.arange(40, dtype=np.int32)))
    assert issubclass(Overloaded, RejectedRequest)
    assert issubclass(RejectedRequest, ValueError)
    # rejects never queued anything
    assert not eng.waiting and not eng2.waiting


# -- admission policies ------------------------------------------------------

def test_bounded_queue_sheds_typed_overloaded(setup):
    cfg, model, params = setup
    eng = make_engine(model, params,
                      admission=BoundedQueue(3), prefill_batch=2)
    n = 8
    decisions = [eng.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
                 for i, pr in enumerate(prompts_for(n, seed=1))]
    shed = [d for d in decisions if isinstance(d, Shed)]
    assert shed and all(isinstance(d.reason, Overloaded) for d in shed)
    done = eng.run()
    assert_lifecycle_clean(eng, n)
    st = eng.stats
    assert st["shed"] == len(shed) > 0
    assert st["finished"] == n - len(shed)
    # shed requests carry the typed result, admitted ones all finished
    for r in done:
        if isinstance(r.result, Shed):
            assert isinstance(r.result.reason, Overloaded)
            assert r.output == []
        else:
            assert len(r.output) == 3


def test_priority_floor_and_chain_identity():
    chain = admission_chain(DeadlineGate(), BoundedQueue(4),
                            PriorityFloor(2, when_queue_over=1))
    # identities are stable, reproducible tuples (mirroring
    # StrategyPolicy): two equal chains agree, different params differ
    chain2 = admission_chain(DeadlineGate(), BoundedQueue(4),
                             PriorityFloor(2, when_queue_over=1))
    assert chain.identity() == chain2.identity()
    assert chain.identity() != admission_chain(BoundedQueue(5)).identity()
    ctx = AdmissionContext(queue_depth=2, active=0, chunking=0,
                           free_rows=0, max_batch=4, prompt_len=8,
                           priority=0, waited_s=0.0,
                           deadline_left_s=None, ttft_left_s=None)
    d = chain(ctx)
    assert isinstance(d, Shed)          # below the priority floor
    assert AdmitAll()(ctx).ok


def test_deadline_expired_in_queue_sheds(setup):
    """The built-in DeadlineGate runs even under the default policy: a
    request whose deadline expired while queued sheds instead of
    wasting decode steps."""
    cfg, model, params = setup
    eng = make_engine(model, params, max_batch=1, prefill_batch=1)
    live = Request(rid=0, prompt=prompts_for(1, seed=2)[0],
                   max_new_tokens=8)
    dead = Request(rid=1, prompt=prompts_for(1, seed=3)[0],
                   max_new_tokens=2, deadline_s=0.0)
    eng.submit(live)
    eng.submit(dead)                   # expires while rid 0 holds the row
    eng.run()
    assert_lifecycle_clean(eng, 2)
    assert isinstance(live.result, Finished)
    assert isinstance(dead.result, Shed)
    assert eng.stats["deadline_missed"] == 1


# -- chaos: allocation failures ---------------------------------------------

def test_injected_alloc_failures_delay_but_never_lose(setup):
    cfg, model, params = setup
    faults = FaultInjector(alloc_fail=(0, 1, 3))
    eng = make_engine(model, params, faults=faults)
    n = 5
    for i, pr in enumerate(prompts_for(n, seed=4)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=4))
    done = eng.run()
    assert_lifecycle_clean(eng, n)
    assert all(isinstance(r.result, Finished) for r in done)
    assert eng.stats["alloc_denied"] == 3
    assert faults.counts.get("alloc_fail") == 3
    # denial only delays: outputs match a fault-free engine exactly
    clean = make_engine(model, params)
    for i, pr in enumerate(prompts_for(n, seed=4)):
        clean.submit(Request(rid=i, prompt=pr, max_new_tokens=4))
    want = {r.rid: r.output for r in clean.run()}
    assert {r.rid: r.output for r in done} == want


# -- chaos: dispatch faults + isolation -------------------------------------

def test_poisoned_prefill_isolated_to_one_request(setup):
    """A poisoned request inside a batched prefill group fails alone;
    its groupmates retry and produce exactly their fault-free tokens."""
    cfg, model, params = setup
    n = 4
    faults = FaultInjector(poison={1: "prefill"})
    eng = make_engine(model, params, faults=faults, prefill_batch=4)
    for i, pr in enumerate(prompts_for(n, seed=5)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, n)
    assert isinstance(done[1].result, Failed)
    assert "poisoned" in done[1].result.reason
    clean = make_engine(model, params)
    for i, pr in enumerate(prompts_for(n, seed=5)):
        if i != 1:
            clean.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
    want = {r.rid: r.output for r in clean.run()}
    for rid, out in want.items():
        assert done[rid].output == out, rid


def test_poisoned_decode_and_harvest_isolated(setup):
    cfg, model, params = setup
    for site in ("decode", "harvest"):
        faults = FaultInjector(poison={2: site})
        eng = make_engine(model, params, faults=faults)
        n = 4
        for i, pr in enumerate(prompts_for(n, seed=6)):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=4))
        done = {r.rid: r for r in eng.run()}
        assert_lifecycle_clean(eng, n)
        assert isinstance(done[2].result, Failed), site
        survivors = [r for rid, r in done.items() if rid != 2]
        assert all(isinstance(r.result, Finished) for r in survivors), site
        assert all(len(r.output) == 4 for r in survivors), site


def test_generic_dispatch_fault_fails_only_that_dispatch(setup):
    """An untargeted InjectedFault kills the requests in that dispatch
    (blast radius: the batch) but the engine survives and serves later
    submissions."""
    cfg, model, params = setup
    faults = FaultInjector(dispatch_fail=(("prefill", 0),))
    eng = make_engine(model, params, faults=faults, prefill_batch=2)
    for i, pr in enumerate(prompts_for(2, seed=7)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
    eng.run()
    assert all(isinstance(r.result, Failed) for r in eng.finished)
    # the engine is still alive: a second wave is served normally
    for i, pr in enumerate(prompts_for(2, seed=8), start=2):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, 4)
    assert isinstance(done[2].result, Finished)
    assert isinstance(done[3].result, Finished)


def test_poisoned_chunked_prefill_releases_row(setup):
    cfg, model, params = setup
    faults = FaultInjector(poison={0: "chunk"})
    eng = make_engine(model, params, faults=faults)
    long_pr = (np.arange(40, dtype=np.int32) * 7 + 3) % 100
    eng.submit(Request(rid=0, prompt=long_pr, max_new_tokens=3))
    eng.submit(Request(rid=1, prompt=prompts_for(1, seed=9)[0],
                       max_new_tokens=3))
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, 2)
    assert isinstance(done[0].result, Failed)
    assert isinstance(done[1].result, Finished)


# -- preempt-and-requeue -----------------------------------------------------

def test_priority_preemption_resumes_bitwise_identical(setup):
    """A higher-priority request arriving on a full pool evicts the
    low-priority decoding row; the victim re-admits as a re-prefill
    over prompt+generated and its final tokens are bitwise-identical
    to an uninterrupted run."""
    cfg, model, params = setup
    pr_low = prompts_for(1, seed=10)[0]
    pr_high = prompts_for(1, seed=11)[0]

    solo = make_engine(model, params, max_batch=1)
    solo.submit(Request(rid=0, prompt=pr_low.copy(), max_new_tokens=10))
    want = solo.run()[0].output

    eng = make_engine(model, params, max_batch=1)
    low = Request(rid=0, prompt=pr_low.copy(), max_new_tokens=10,
                  priority=0)
    eng.submit(low)
    for _ in range(4):                  # let the victim produce tokens
        eng.step()
    high = Request(rid=1, prompt=pr_high.copy(), max_new_tokens=3,
                   priority=5)
    eng.submit(high)
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, 2)
    assert low.preemptions >= 1
    assert eng.stats["preempted"] >= 1
    assert eng.stats["resumed"] >= 1
    assert isinstance(done[0].result, Finished)
    assert isinstance(done[1].result, Finished)
    assert done[0].output == want, "preempted run diverged"
    # the high-priority request actually cut the line: its first token
    # arrived before the preempted request finished
    assert done[1].first_token_s < done[0].done_s


def test_preempted_long_resume_goes_chunked(setup):
    """A resume whose prompt+generated exceeds the largest bucket
    re-prefills through the chunked path and still matches solo."""
    cfg, model, params = setup
    pr_low = prompts_for(1, seed=12, lo=28, hi=31)[0]   # near the bucket

    solo = make_engine(model, params, max_batch=1)
    solo.submit(Request(rid=0, prompt=pr_low.copy(), max_new_tokens=12))
    want = solo.run()[0].output

    eng = make_engine(model, params, max_batch=1)
    low = Request(rid=0, prompt=pr_low.copy(), max_new_tokens=12)
    eng.submit(low)
    for _ in range(8):                  # > bucket - len(prompt) tokens
        eng.step()
    eng.submit(Request(rid=1, prompt=prompts_for(1, seed=13)[0],
                       max_new_tokens=2, priority=9))
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, 2)
    assert low.preemptions >= 1
    assert len(low.prompt) + 12 > eng.cfg.prefill_buckets[-1]
    assert done[0].output == want
    assert eng.stats["chunk_steps"] > 0    # the resume chunked


def test_pressure_window_preempts_and_recovers(setup):
    """An injected memory-pressure window shrinks effective capacity;
    the engine evicts decoding rows to fit, re-admits them after the
    window, and every request still produces its fault-free tokens."""
    cfg, model, params = setup
    n = 4
    clean = make_engine(model, params)
    for i, pr in enumerate(prompts_for(n, seed=14)):
        clean.submit(Request(rid=i, prompt=pr, max_new_tokens=6))
    want = {r.rid: r.output for r in clean.run()}

    faults = FaultInjector(pressure=((2, 5, 3),))   # capacity 4 -> 1
    eng = make_engine(model, params, faults=faults)
    for i, pr in enumerate(prompts_for(n, seed=14)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=6))
    done = {r.rid: r for r in eng.run()}
    assert_lifecycle_clean(eng, n)
    assert eng.stats["preempted"] >= 1
    assert all(isinstance(r.result, Finished) for r in done.values())
    assert {rid: r.output for rid, r in done.items()} == want


# -- stranded work: run(max_iters), drain, shutdown -------------------------

def test_run_max_iters_surfaces_stranded_rows(setup):
    cfg, model, params = setup
    eng = make_engine(model, params)
    for i, pr in enumerate(prompts_for(3, seed=15)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=50))
    done = eng.run(max_iters=2)
    # nothing silently stranded: every request terminated, rows free
    assert_lifecycle_clean(eng, 3)
    st = eng.stats
    assert st["stranded"] + st["shed"] == 3
    assert st["stranded"] > 0
    assert any(isinstance(r.result, Failed)
               and "max_iters" in r.result.reason for r in done)


def test_drain_finishes_inflight_sheds_queue(setup):
    cfg, model, params = setup
    eng = make_engine(model, params, max_batch=2, prefill_batch=2)
    for i, pr in enumerate(prompts_for(4, seed=16)):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=4))
    eng.step()                          # two admitted, two queued
    report = eng.drain()
    assert_lifecycle_clean(eng, 4)
    assert report["stranded"] == []
    assert report["free_rows"] == 2
    assert eng.stats["finished"] == 2 and eng.stats["shed"] == 2
    with pytest.raises(EngineDraining):
        # during the drain submits are hard-rejected; afterwards the
        # engine re-opens
        eng._draining = True
        eng.submit(Request(rid=9, prompt=prompts_for(1, seed=17)[0]))
    eng._draining = False
    eng.submit(Request(rid=10, prompt=prompts_for(1, seed=17)[0],
                       max_new_tokens=2))
    eng.run()
    assert eng.stats["finished"] == 3


def test_drain_timeout_reports_and_releases_stranded(setup):
    cfg, model, params = setup
    eng = make_engine(model, params)
    eng.submit(Request(rid=0, prompt=prompts_for(1, seed=18)[0],
                       max_new_tokens=500))   # will not finish in time
    eng.step()
    report = eng.drain(timeout=0.0)
    assert report["stranded"] == [0]
    assert_lifecycle_clean(eng, 1)
    assert eng.stats["stranded"] == 1


def test_shutdown_mid_chunked_prefill_releases_and_checkpoints(
        setup, tmp_path):
    """Satellite: shutdown() while a chunked prefill is in flight must
    release the _chunking row and still checkpoint the PlanStore (dirty
    flag honored — a second shutdown writes nothing)."""
    cfg, model, params = setup
    path = str(tmp_path / "chaos.dfps")
    eng = make_engine(model, params, plan_store_path=path)
    long_pr = (np.arange(40, dtype=np.int32) * 5 + 1) % 100
    eng.submit(Request(rid=0, prompt=long_pr, max_new_tokens=3))
    eng._admit()                        # stages + dispatches one chunk
    assert eng._chunking, "precondition: a chunked prefill is in flight"
    wrote = eng.shutdown()
    assert wrote >= 1                   # the chunk lowering checkpointed
    assert (tmp_path / "chaos.dfps").exists()
    assert_lifecycle_clean(eng, 1)
    assert isinstance(eng.finished[0].result, Failed)
    assert eng.shutdown() == 0          # clean store: no rewrite


# -- cache row bookkeeping (satellite: typed errors) ------------------------

def test_release_and_move_row_typed_errors(setup):
    cfg, model, params = setup
    cache = KVCacheManager(model, 4, 64)
    row = cache.allocate(7)
    cache.release(row)
    with pytest.raises(CacheRowError, match="double release|not allocated"):
        cache.release(row)
    with pytest.raises(CacheRowError):
        cache.release(99)
    r0 = cache.allocate(1)
    with pytest.raises(CacheRowError, match="src == dst"):
        cache.move_row(r0, r0)
    with pytest.raises(CacheRowError, match="not an active row"):
        cache.move_row(3, 2)
    cache.allocate(2)                   # row 1 now owned -> not free
    with pytest.raises(CacheRowError, match="not free"):
        cache.move_row(r0, 1)


# -- the full chaos soup -----------------------------------------------------

def test_chaos_soup_every_request_terminates_exactly_once(setup):
    """Everything at once: bounded queue, deadlines, priorities,
    allocation denials, a poisoned request, a generic dispatch fault,
    a straggler iteration, and a memory-pressure window.  Every request
    must reach exactly one terminal state with matching counters and a
    fully-free pool."""
    cfg, model, params = setup
    faults = FaultInjector(alloc_fail=(2,), poison={5: "decode"},
                           dispatch_fail=(("chunk", 1),),
                           slow_iters=(3,), slow_s=0.01,
                           pressure=((6, 8, 2),))
    eng = make_engine(model, params, admission=BoundedQueue(6),
                      faults=faults, prefill_batch=2)
    rng = np.random.default_rng(19)
    n = 10
    for i in range(n):
        if i == 4:
            pr = (np.arange(44, dtype=np.int32) * 3 + 1) % 100  # chunked
        else:
            pr = rng.integers(0, 100, int(rng.integers(4, 14))) \
                .astype(np.int32)
        eng.submit(Request(rid=i, prompt=pr,
                           max_new_tokens=int(rng.integers(2, 7)),
                           priority=int(rng.integers(0, 3)),
                           deadline_s=None if i % 4 else 30.0))
    eng.run()
    assert_lifecycle_clean(eng, n)
    st = eng.stats
    assert st["failed"] >= 1            # the poisoned + faulted requests
    assert st["alloc_denied"] >= 1
    assert faults.counts.get("slow") == 1
    # a second, fault-free wave confirms the engine is still healthy
    for i, pr in enumerate(prompts_for(3, seed=20), start=n):
        eng.submit(Request(rid=i, prompt=pr, max_new_tokens=3))
    eng.run()
    assert_lifecycle_clean(eng, n + 3)
