"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def tol(dtype):
    return dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(4, 32), (64, 96), (128, 256), (7, 40)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_sweep(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (d,)).astype(dtype)
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, g), np.float32),
        np.asarray(ref.rmsnorm(x, g), np.float32), **tol(dtype))


@pytest.mark.parametrize("n,d", [(8, 16), (33, 64), (256, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_add_rmsnorm_sweep(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d)).astype(dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), (n, d)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(2), (d,)).astype(dtype)
    s1, h1 = ops.fused_add_rmsnorm(x, y, g)
    s2, h2 = ref.fused_add_rmsnorm(x, y, g)
    np.testing.assert_allclose(np.asarray(s1, np.float32),
                               np.asarray(s2, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), **tol(dtype))


def test_fused_add_rmsnorm_grad_matches_autodiff():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    y = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
    g = jax.random.normal(jax.random.PRNGKey(2), (32,))

    def lk(x, y, g):
        s, h = ops.fused_add_rmsnorm(x, y, g)
        return jnp.sum(jnp.sin(s) + h * h)

    def lr(x, y, g):
        s, h = ref.fused_add_rmsnorm(x, y, g)
        return jnp.sum(jnp.sin(s) + h * h)

    gk = jax.grad(lk, argnums=(0, 1, 2))(x, y, g)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, y, g)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,hd", [(1, 32, 2, 16), (2, 64, 4, 32),
                                      (2, 128, 1, 64), (1, 96, 3, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, hd, causal):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    np.testing.assert_allclose(
        ops.flash_attention(q, k, v, causal=causal),
        ref.flash_attention(q, k, v, causal=causal), atol=2e-5, rtol=1e-4)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 2, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 32)).astype(jnp.bfloat16)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v), np.float32),
        np.asarray(ref.flash_attention(q, k, v), np.float32),
        atol=3e-2, rtol=3e-2)


@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([16, 48, 64]), sk=st.sampled_from([16, 64, 96]))
def test_flash_cross_attention_rectangular(sq, sk):
    """Non-square q/k lengths (cross-attention shapes)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, sq, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, sk, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, sk, 2, 16))
    np.testing.assert_allclose(
        ops.flash_attention(q, k, v, causal=False),
        ref.flash_attention(q, k, v, causal=False), atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,hd", [(2, 128, 4, 32), (4, 64, 2, 16)])
def test_decode_attention_sweep(B, S, H, hd):
    kc = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    vc = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    for clen in (jnp.int32(1), jnp.int32(S // 2), jnp.int32(S)):
        np.testing.assert_allclose(
            ops.decode_attention(q, kc, vc, clen),
            ref.decode_attention(q, kc, vc, clen), atol=2e-5, rtol=1e-4)


def test_decode_attention_ragged_lengths():
    """Per-request cache lengths (continuous batching)."""
    B, S, H, hd = 4, 64, 2, 16
    kc = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    vc = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    q = jax.random.normal(jax.random.PRNGKey(2), (B, 1, H, hd))
    clen = jnp.asarray([3, 17, 64, 1], jnp.int32)
    np.testing.assert_allclose(
        ops.decode_attention(q, kc, vc, clen),
        ref.decode_attention(q, kc, vc, clen), atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# grouped expert FFN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,N,D,F", [(2, 16, 24, 32), (4, 64, 48, 96),
                                     (1, 128, 64, 256)])
def test_grouped_ffn_sweep(E, N, D, F):
    k = jax.random.PRNGKey
    x = jax.random.normal(k(0), (E, N, D)) * 0.5
    w1 = jax.random.normal(k(1), (E, D, F)) * 0.1
    w3 = jax.random.normal(k(2), (E, D, F)) * 0.1
    w2 = jax.random.normal(k(3), (E, F, D)) * 0.1
    np.testing.assert_allclose(ops.grouped_ffn(x, w1, w3, w2),
                               ref.grouped_ffn(x, w1, w3, w2),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,chunk", [(32, 8), (64, 16), (64, 64), (48, 16)])
def test_ssd_scan_sweep(L, chunk):
    b, H, P, G, N = 2, 4, 8, 1, 16
    k = jax.random.PRNGKey
    x = jax.random.normal(k(0), (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k(1), (b, L, H)))
    A = -jnp.exp(jax.random.normal(k(2), (H,)))
    B = jax.random.normal(k(3), (b, L, G, N)) * 0.5
    C = jax.random.normal(k(4), (b, L, G, N)) * 0.5
    D = jnp.ones((H,))
    np.testing.assert_allclose(
        ops.ssd_scan(x, dt, A, B, C, D, chunk=chunk),
        ref.ssd_scan(x, dt, A, B, C, D), atol=2e-3, rtol=1e-2)


def test_ssd_scan_multi_group():
    b, L, H, P, G, N = 1, 32, 4, 8, 2, 8
    k = jax.random.PRNGKey
    x = jax.random.normal(k(0), (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(k(1), (b, L, H)))
    A = -jnp.exp(jax.random.normal(k(2), (H,)))
    B = jax.random.normal(k(3), (b, L, G, N)) * 0.5
    C = jax.random.normal(k(4), (b, L, G, N)) * 0.5
    D = jnp.zeros((H,))
    np.testing.assert_allclose(
        ops.ssd_scan(x, dt, A, B, C, D, chunk=8),
        ref.ssd_scan(x, dt, A, B, C, D), atol=2e-3, rtol=1e-2)


def test_ssd_matches_model_reference():
    """The Pallas SSD must agree with SSDScanOp's chunked jnp ref."""
    from repro.configs import get_smoke_config
    from repro.models.mamba2 import SSDScanOp, ssm_dims
    from repro.models.layers import MeshInfo
    cfg = get_smoke_config("mamba2-2.7b")
    mesh = MeshInfo(tp=1)
    op_x = SSDScanOp(cfg, mesh, impl="xla")
    op_p = SSDScanOp(cfg, mesh, impl="pallas")
    _, d_in_loc, _, H_loc, ch_loc = ssm_dims(cfg, 1)
    p = {n: pp.initializer()(jax.random.PRNGKey(i), pp.shape, pp.dtype)
         for i, (n, pp) in enumerate(op_x._params.items())}
    B, L = 2, 16
    xbc = jax.random.normal(jax.random.PRNGKey(9), (B, L, ch_loc))
    dt = jax.random.normal(jax.random.PRNGKey(10), (B, L, H_loc))
    np.testing.assert_allclose(
        np.asarray(op_p.kernel(p, xbc, dt), np.float32),
        np.asarray(op_x.kernel(p, xbc, dt), np.float32),
        atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# tokenweave fused collective (single shard: collectives = identity)
# ---------------------------------------------------------------------------


def test_tokenweave_fused_unsharded():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 32))
    y = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    g = jax.random.normal(jax.random.PRNGKey(2), (32,))
    s, h = ops.fused_ar_add_rmsnorm(y, x, g)
    s2, h2 = ref.fused_add_rmsnorm(x, y, g)
    np.testing.assert_allclose(s, s2, atol=1e-5)
    np.testing.assert_allclose(h, h2, atol=1e-5)
