"""Plan verifier & schedule linter: every diagnostic code fires on a
deliberately broken schedule / mutated lowered plan with op + step
provenance, strict mode catches seeded memory hazards and tampered
restored artifacts that checksum + fingerprint alone miss, and the
autotuner prunes (never crashes on) broken registered strategies.
"""
import collections
import copy
import dataclasses
import hashlib
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FULL, OpSchedulerBase, PlanStore, Realizer,
                        ScheduleContext, lower, record_plan,
                        static_analysis, trace)
from repro.core.graph import VBATCH
from repro.core.module import FnOp, Module, Op, Param
from repro.core.plan import (ExecutionPlan, OpHandle, PlanStep,
                             graph_fingerprint)
from repro.core.scheduler import ScheduleError
from repro.core.verify import (CODES, Diagnostic, PlanVerificationError,
                               VerifyReport, enforce, format_missing,
                               lint_plan, lint_table, verify,
                               verify_lowered, verify_plan)

D = 8


class Lin(Op):
    def __init__(self, name):
        super().__init__()
        self.w = Param((D, D), jnp.float32)
        self.named(name)

    def kernel(self, p, x):
        return jnp.tanh(x @ p["w"])


class Chain(Module):
    def __init__(self, n=4):
        super().__init__()
        self.n = n
        for i in range(n):
            setattr(self, f"l{i}", Lin(f"l{i}"))

    def forward(self, x):
        for i in range(self.n):
            x = getattr(self, f"l{i}")(x)
        return x


class PerPart(OpSchedulerBase):
    """Every op per micro-batch, topo order — the canonical valid split
    plan the mutation tests below break one invariant at a time."""

    def __init__(self, sizes=(4, 4)):
        self.sizes = sizes

    def schedule(self, ctx):
        ctx.split(list(self.sizes))
        for oid in ctx.graph.topo_order():
            for p in range(len(self.sizes)):
                ctx.execute(OpHandle(oid, p, ctx.graph.nodes[oid].name))


class SplitThenMerge(OpSchedulerBase):
    """Per-part chain ending in a merged step: exercises the prealloc
    merge buffer (pad-create + dus + assemble) in the lowered IR."""

    def __init__(self, sizes=(4, 4)):
        self.sizes = sizes

    def schedule(self, ctx):
        ctx.split(list(self.sizes))
        oids = ctx.graph.topo_order()
        for oid in oids[:-1]:
            for p in range(len(self.sizes)):
                ctx.execute(OpHandle(oid, p, ""))
        ctx.execute(tuple(OpHandle(oids[-1], p, "")
                          for p in range(len(self.sizes))))


def _setup(n=4, sizes=(4, 4), B=8, sched=None):
    net = Chain(n)
    g = trace(net, {"x": jax.ShapeDtypeStruct((B, D), jnp.float32)})
    plan = record_plan(g, sched or PerPart(sizes),
                       ScheduleContext(local_batch=B))
    return net, g, plan


def _codes(diags):
    return {d.code for d in diags}


def _replan(plan, steps=None, sizes=None):
    return ExecutionPlan(list(plan.steps) if steps is None else steps,
                         plan.split_sizes if sizes is None else sizes,
                         plan.graph_fingerprint)


# ---------------------------------------------------------------------------
# the clean baseline: a recorded plan never carries diagnostics
# ---------------------------------------------------------------------------


def test_clean_plan_has_no_diagnostics():
    _, g, plan = _setup()
    rep = verify(g, plan, lowered=lower(g, plan), lint=True)
    assert rep.ok and not rep.diagnostics
    assert rep.pretty() == "verification clean: no diagnostics"
    rep.raise_if_errors()                      # no-op on a clean report


def test_diagnostic_str_ops_and_code_table():
    d = Diagnostic("error", "VFY005", -1, (OpHandle(3, 1, "moe"),),
                   "msg", "hintx")
    assert str(d).startswith("[ERROR VFY005] plan (moe[mb=1]): msg")
    assert "hint: hintx" in str(d)
    w = Diagnostic("warning", "VFY009", 2, (OpHandle(3, FULL, "w"),), "m")
    assert "step 2" in str(w) and w.ops == "w"
    for code, (sev, desc) in CODES.items():
        assert sev in ("error", "warning") and desc, code


# ---------------------------------------------------------------------------
# layer 1: plan-level data-flow (VFY001-VFY009)
# ---------------------------------------------------------------------------


def test_vfy001_wrong_graph_and_unknown_op():
    _, g, plan = _setup(4)
    _, g2, _ = _setup(6)
    d = next(d for d in verify_plan(g2, plan) if d.code == "VFY001")
    assert d.step_index == -1
    assert plan.graph_fingerprint in d.message
    # a step naming an op the graph has never seen, with its provenance
    ghost = PlanStep("exec", (OpHandle(999, 0, "ghost"),))
    diags = verify_plan(g, _replan(plan, list(plan.steps) + [ghost]))
    d = next(d for d in diags if d.code == "VFY001")
    assert d.step_index == len(plan.steps)
    assert "ghost" in d.message and d.op_handles == ghost.handles


def test_vfy002_invalid_split_sizes():
    _, g, plan = _setup()
    d = next(d for d in verify_plan(g, _replan(plan, sizes=(8, 0)))
             if d.code == "VFY002")
    assert d.step_index == -1 and "(8, 0)" in d.message


def test_vfy003_read_before_write_with_provenance():
    _, g, plan = _setup()
    steps = list(plan.steps)
    steps[0], steps[2] = steps[2], steps[0]    # l1[0] before l0[0]
    diags = verify_plan(g, _replan(plan, steps))
    assert _codes(diags) == {"VFY003"}         # no downstream cascade
    d = diags[0]
    assert d.step_index == 0
    assert "l1" in d.ops and "mb=0" in d.ops
    assert "producer" in d.fix_hint


def test_vfy004_double_execution():
    _, g, plan = _setup()
    diags = verify_plan(g, _replan(plan, list(plan.steps) + [plan.steps[0]]))
    d = next(d for d in diags if d.code == "VFY004")
    assert d.step_index == len(plan.steps)
    assert "l0" in d.message and "l0" in d.ops


def test_vfy005_missing_execution():
    _, g, plan = _setup()
    diags = verify_plan(g, _replan(plan, list(plan.steps)[:-1]))
    d = next(d for d in diags if d.code == "VFY005")
    assert d.step_index == -1
    assert d.ops == "Chain/l3[mb=1]"           # exact missing instance
    assert "1 op(s) missing" in d.message
    # ...and the virtual final-output step reports the consequence
    assert any(d.code == "VFY003" and d.step_index == len(plan.steps) - 1
               for d in diags)


def test_vfy006_merged_step_coverage_and_mixing():
    net, g, plan = _setup(sched=SplitThenMerge((4, 4)))
    last = plan.steps[-1]
    assert last.kind == "merged"
    partial = dataclasses.replace(last, handles=last.handles[:1])
    diags = verify_plan(g, _replan(plan, list(plan.steps[:-1]) + [partial]))
    d = next(d for d in diags if d.code == "VFY006")
    assert d.step_index == len(plan.steps) - 1
    assert "micro-batches [0]" in d.message
    # merged step spanning two different ops
    other = plan.steps[0].handles[0]
    mixed = dataclasses.replace(last, handles=(last.handles[0], other))
    diags = verify_plan(g, _replan(plan, list(plan.steps[:-1]) + [mixed]))
    d = next(d for d in diags if d.code == "VFY006")
    assert "mixes 2 different ops" in d.message


def test_vfy007_merged_read_infeasible_on_virtual_batch():
    class MergeFirst(OpSchedulerBase):
        def schedule(self, ctx):
            ctx.split([4, 4])
            oids = ctx.graph.topo_order()
            ctx.execute(tuple(OpHandle(oids[0], p, "") for p in (0, 1)))
            for oid in oids[1:]:
                for p in (0, 1):
                    ctx.execute(OpHandle(oid, p, ""))

    _, g, plan = _setup(n=2, sched=MergeFirst())
    assert verify(g, plan).ok                 # sliceable batch dim: legal
    t_mid = g.nodes[g.topo_order()[0]].outputs[0]
    g.tensors[t_mid] = dataclasses.replace(g.tensors[t_mid],
                                           batch_dim=VBATCH)
    d = next(d for d in verify_plan(g, plan) if d.code == "VFY007")
    assert "virtual-batch" in d.message
    assert "Chain/l0" in d.message            # the unsliceable tensor
    assert d.step_index == 1                  # the per-mb consumer step


def test_vfy008_fused_group_not_convex():
    net = Chain(3)
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    oids = g.topo_order()

    def h(i):
        return OpHandle(oids[i], FULL, g.nodes[oids[i]].name)

    steps = [PlanStep("fused", (h(0), h(2)), "bad_fuse", None),
             PlanStep("exec", (h(1),))]
    diags = verify_plan(g, ExecutionPlan(steps, (), graph_fingerprint(g)))
    d = next(d for d in diags if d.code == "VFY008")
    assert d.step_index == 0
    assert "l0" in d.ops and "l2" in d.ops
    assert "not dependency-closed" in d.message


def test_vfy009_dead_op_is_warning_not_error():
    class Dead(Module):
        def __init__(self):
            super().__init__()
            self.live = Lin("live")
            self.dead = Lin("dead")

        def forward(self, x):
            self.dead(x)                       # traced, never consumed
            return self.live(x)

    g = trace(Dead(), {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    plan = record_plan(g, PerPart((4, 4)), ScheduleContext(local_batch=8))
    rep = verify(g, plan)
    assert rep.ok                              # warnings never fail
    d = next(d for d in rep.warnings if d.code == "VFY009")
    assert d.ops == "Dead/dead"


# ---------------------------------------------------------------------------
# layer 2: lowered-IR memory safety (VFY101-VFY105)
# ---------------------------------------------------------------------------


def _lowered_setup(n=4, sizes=(4, 4), sched=None):
    _, g, plan = _setup(n, sizes, sched=sched)
    return g, plan, lower(g, plan)


def _with_instr(low, i, **attrs):
    instrs = list(low.instrs)
    mut = copy.copy(instrs[i])
    for k, v in attrs.items():
        setattr(mut, k, v)
    instrs[i] = mut
    return dataclasses.replace(low, instrs=tuple(instrs))


def _seed_use_after_death(low):
    """Free, one instruction early, the slot the last reading instruction
    still needs — the canonical silent liveness corruption."""
    i = max(j for j, ins in enumerate(low.instrs) if ins.reads)
    slot = low.instrs[i].reads[0][0]
    bad = _with_instr(low, i - 1,
                      frees=tuple(low.instrs[i - 1].frees) + (slot,))
    return i, bad


def test_vfy101_invalid_slot_read():
    g, plan, low = _lowered_setup()
    i = next(j for j, ins in enumerate(low.instrs) if ins.reads)
    ins = low.instrs[i]
    bad = _with_instr(low, i, reads=((low.n_slots + 3, ins.reads[0][1]),)
                      + tuple(ins.reads[1:]))
    d = next(d for d in verify_lowered(bad) if d.code == "VFY101")
    assert d.step_index == i and "invalid slot" in d.message
    assert d.op_handles                        # instr provenance


def test_vfy101_vfy104_use_after_death_and_premature_free():
    g, plan, low = _lowered_setup()
    i, bad = _seed_use_after_death(low)
    diags = verify_lowered(bad)
    d104 = next(d for d in diags if d.code == "VFY104")
    assert d104.step_index == i - 1 and "premature free" in d104.message
    d101 = next(d for d in diags if d.code == "VFY101")
    assert d101.step_index == i and "use-after-death" in d101.message


def test_vfy102_write_clobbers_live_input_slot():
    g, plan, low = _lowered_setup()
    x_slot = low.input_slots[0][1]
    (w_slot, buf0), *rest = low.instrs[0].writes
    assert w_slot != x_slot
    bad = _with_instr(low, 0, writes=((x_slot, buf0),) + tuple(rest))
    d = next(d for d in verify_lowered(bad) if d.code == "VFY102")
    assert d.step_index == 0
    assert "clobbering live" in d.message and "aliasing" in d.message


def test_vfy103_merge_buffer_hazards():
    g, plan, low = _lowered_setup(sched=SplitThenMerge((4, 4)))
    assert low.stats["pad_inits"] == 1
    i = next(j for j, ins in enumerate(low.instrs)
             if any(b is not None for _s, b in ins.writes))
    writes = tuple((s, None) for s, _b in low.instrs[i].writes)
    diags = verify_lowered(_with_instr(low, i, writes=writes))
    msgs = [d.message for d in diags if d.code == "VFY103"]
    assert any("never writes the prealloc buffer" in m for m in msgs)
    assert any("assembles merge buffer" in m for m in msgs)


def test_vfy105_metadata_mismatch():
    g, plan, low = _lowered_setup()
    d = next(d for d in verify_lowered(
        dataclasses.replace(low, instrs=low.instrs[:-1]))
        if d.code == "VFY105")
    assert d.step_index == -1
    assert "re-lower" in d.fix_hint


# ---------------------------------------------------------------------------
# layer 3: lint warnings (VFY201-VFY203)
# ---------------------------------------------------------------------------


class Ordered(OpSchedulerBase):
    """Execute ops unsplit in an explicit name order."""

    def __init__(self, names):
        self.names = names

    def schedule(self, ctx):
        byname = {ctx.graph.nodes[o].name.split("/")[-1]: o
                  for o in ctx.graph.topo_order()}
        for nm in self.names:
            ctx.execute(OpHandle(byname[nm], FULL, nm))


class TwoColl(Module):
    """Two independent collective->consumer chains joined at the end."""

    def __init__(self):
        super().__init__()
        self.n1 = FnOp(lambda x: x * 1.0, "coll1", resource="network")
        self.n2 = FnOp(lambda x: x * 2.0, "coll2", resource="network")
        self.c1 = Lin("c1")
        self.c2 = Lin("c2")
        self.join = FnOp(lambda a, b: a + b, "join")

    def forward(self, x):
        return self.join(self.c1(self.n1(x)), self.c2(self.n2(x)))


class OneColl(Module):
    """One collective chain plus an independent compute branch."""

    def __init__(self):
        super().__init__()
        self.coll = FnOp(lambda x: x * 1.0, "coll", resource="network")
        self.use = Lin("use")
        self.side = Lin("side")
        self.join = FnOp(lambda a, b: a + b, "join")

    def forward(self, x):
        return self.join(self.use(self.coll(x)), self.side(x))


def _traced_plan(net, order):
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    plan = record_plan(g, Ordered(order), ScheduleContext(local_batch=8))
    return g, plan


def test_vfy201_two_collectives_share_one_window():
    g, plan = _traced_plan(TwoColl(),
                           ("coll1", "coll2", "c1", "c2", "join"))
    assert verify(g, plan).ok                  # correct, just slow
    d = next(d for d in lint_plan(g, plan) if d.code == "VFY201")
    assert d.step_index == 0
    assert "coll1" in d.message and "coll2" in d.message
    assert "serialize" in d.message


def test_vfy202_exposed_collective_with_reorderable_work():
    g, plan = _traced_plan(OneColl(), ("coll", "use", "side", "join"))
    d = next(d for d in lint_plan(g, plan) if d.code == "VFY202")
    assert d.step_index == 0
    assert "coll" in d.message and "side" in d.message
    # the reorder the hint asks for silences the warning
    g2, plan2 = _traced_plan(OneColl(), ("coll", "side", "use", "join"))
    assert not lint_plan(g2, plan2)


def test_vfy203_degenerate_split():
    _, g, plan = _setup(2, sizes=(15, 1), B=16)
    d = next(d for d in lint_plan(g, plan) if d.code == "VFY203")
    assert d.step_index == -1 and "93%" in d.message


# ---------------------------------------------------------------------------
# modes + enforcement + formatting
# ---------------------------------------------------------------------------


def test_strict_mode_catches_seeded_use_after_death():
    """Acceptance: the mutation is invisible to plan fingerprints (the
    instruction stream is not part of them) — only strict verification
    stops it."""
    g, plan, low = _lowered_setup()
    assert verify(g, plan, lowered=low, mode="strict").ok
    _, bad = _seed_use_after_death(low)
    assert bad.fingerprint == low.fingerprint   # fingerprint can't see it
    with pytest.raises(PlanVerificationError) as ei:
        verify(g, plan, lowered=bad, mode="strict")
    assert {"VFY101", "VFY104"} <= _codes(ei.value.report.errors)
    assert "use-after-death" in str(ei.value)


def test_enforce_modes():
    bad = VerifyReport((Diagnostic("error", "VFY003", 0, (), "boom"),))
    enforce(VerifyReport(), "strict")          # clean: all modes silent
    enforce(bad, "off")
    enforce(bad, "report")
    with pytest.raises(PlanVerificationError, match="unit"):
        enforce(bad, "strict", what="unit")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        enforce(bad, "warn")
    assert len(rec) == 1
    assert issubclass(rec[0].category, RuntimeWarning)
    assert "VFY003" in str(rec[0].message)
    with pytest.raises(ValueError, match="verify mode"):
        enforce(bad, "nope")


def test_record_plan_verify_threading():
    net = Chain(3)
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    plan = record_plan(g, PerPart((4, 4)), ScheduleContext(local_batch=8),
                       verify="strict")
    assert verify(g, plan).ok


def test_schedule_incomplete_reports_count_and_caps_list():
    net = Chain(12)
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})

    class Nothing(OpSchedulerBase):
        def schedule(self, ctx):
            ctx.split([4, 4])

    with pytest.raises(ScheduleError) as ei:
        record_plan(g, Nothing(), ScheduleContext(local_batch=8))
    msg = str(ei.value)
    assert "schedule incomplete" in msg
    assert "12 op(s) missing" in msg
    assert "… and 4 more" in msg
    assert "l0[mb=0,1]" in msg


def test_format_missing():
    missing = [(f"op{i}", {0, 1}) for i in range(10)]
    s = format_missing(missing)
    assert s.startswith("10 op(s) missing: ")
    assert "op0[mb=0,1]" in s and "op8" not in s
    assert "… and 2 more" in s
    assert format_missing([("solo", {FULL})]) == "1 op(s) missing: solo"


def test_lint_table_render():
    d = Diagnostic("error", "VFY005", -1, (OpHandle(0, 1, "op"),), "gone")
    rows = [("a/b", VerifyReport((d,))), ("c/d", VerifyReport())]
    s = lint_table(rows)
    assert "a/b" in s and "VFY005" in s and "c/d" not in s
    s2 = lint_table(rows, include_clean=True)
    assert "c/d" in s2 and "clean" in s2
    assert lint_table([("x", VerifyReport())]) == "all plans clean"


# ---------------------------------------------------------------------------
# satellite: AnalysisResult.ref_count is a precomputed Counter
# ---------------------------------------------------------------------------


def test_ref_count_precomputed_and_correct():
    _, g, plan = _setup()
    ana = static_analysis(g, plan)
    assert ana._ref_counts is not None         # built by the analysis
    want = collections.Counter(
        (t, p) for rs in ana.reads for (t, p, _m, _k) in rs)
    assert want                                # non-trivial plan
    for key, n in want.items():
        assert ana.ref_count(key) == n
    assert ana.ref_count((99999, 0)) == 0


# ---------------------------------------------------------------------------
# acceptance: tampered restored artifact that fingerprints alone miss
# ---------------------------------------------------------------------------


def test_tampered_artifact_rejected_by_semantic_verify(tmp_path):
    net = Chain()
    g = trace(net, {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)})
    plan = record_plan(g, SplitThenMerge((4, 4)),
                       ScheduleContext(local_batch=8))
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
    store = PlanStore()
    store.get_or_lower(g, plan, salt="t")
    path = str(tmp_path / "store.dfps")
    store.save(path)

    # tamper: free one slot an instruction early, re-encode, RECOMPUTE
    # the checksum — entry checksum and plan fingerprint both still pass
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    head, ver, fp2, _check, payload = lines[1].split(" ", 4)
    obj = json.loads(payload)
    instrs = obj["buckets"][0]["instrs"]
    li = max(i for i, ins in enumerate(instrs) if ins[0])
    victim_slot = instrs[li][0][0][0]
    instrs[li - 1][2] = list(instrs[li - 1][2]) + [victim_slot]
    payload = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    check = hashlib.sha256(payload.encode()).hexdigest()[:16]
    bad = str(tmp_path / "bad.dfps")
    with open(bad, "w", encoding="utf-8") as f:
        f.write(lines[0] + "\n")
        f.write(f"{head} {ver} {fp2} {check} {payload}\n")

    # checksum + fingerprint alone would happily serve the tampered IR
    blind = PlanStore.open(bad, verify_restored=False)
    blind.get_or_lower(g, plan, salt="t")
    assert blind.stats["restore_hits"] == 1
    assert blind.stats["restore_verify_rejected"] == 0

    # semantic restore verification rejects it and degrades to a cold
    # lower that still computes the right value
    warm = PlanStore.open(bad)
    assert warm.stats["restore_rejected"] == 0     # checksum passes
    lowered = warm.get_or_lower(g, plan, salt="t")
    assert warm.stats["restore_verify_rejected"] >= 1
    assert warm.stats["restore_rejected"] >= 1
    assert warm.stats["misses"] == 1
    want = Realizer(g, plan, lowered=False)(params, {"x": x})
    got = lowered(params, {"x": x})
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_array_equal(np.asarray(want[k]),
                                      np.asarray(got[k]))


# ---------------------------------------------------------------------------
# acceptance: AutoPolicy prunes broken strategies, records the reason
# ---------------------------------------------------------------------------


def test_autopolicy_prunes_broken_strategies_without_raising():
    from repro.configs import get_smoke_config
    from repro.core.autotune import AutoPolicy, TuningVerdict
    from repro.core.policy import with_graph
    from repro.core.strategies.registry import (_REGISTRY,
                                                register_strategy)
    from repro.models.layers import MeshInfo
    from repro.models.registry import build_model

    class Rogue(OpSchedulerBase):
        """Records a full schedule, then drops the last step behind the
        recorder's bookkeeping — a silently hazardous plan only the
        verifier can catch."""
        name = "rogue_vt"

        def schedule(self, ctx):
            ctx.run_rest_sequential()
            ctx.steps.pop()

    class Boom(OpSchedulerBase):
        name = "boom_vt"

        def schedule(self, ctx):
            raise RuntimeError("intentionally broken")

    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg, MeshInfo(tp=1, dp=1))
    segs, _ = model.build_segments("prefill", 8, 32, s_max=32)
    seg = max(segs, key=lambda s: len(s.graph.nodes))
    info = ScheduleContext(local_batch=8, seq_len=32, phase="prefill",
                           arch=cfg.name)
    register_strategy("rogue_vt", Rogue, overwrite=True)
    register_strategy("boom_vt", Boom, overwrite=True)
    try:
        a = AutoPolicy()
        sched = a(with_graph(info, seg.graph))   # must not raise
        assert sched.name not in ("rogue_vt", "boom_vt")
        v = a.lookup(info, seg.graph)
        reasons = {lbl: code for (lbl, code, _m) in v.pruned}
        assert reasons.get("boom_vt") == "RuntimeError"
        assert reasons.get("rogue_vt", "").startswith("VFY")
        assert v.winner not in ("rogue_vt", "boom_vt")
        boom_msg = next(m for (lbl, _c, m) in v.pruned if lbl == "boom_vt")
        assert "intentionally broken" in boom_msg
        # prune provenance survives the verdict persistence round-trip
        assert TuningVerdict.from_payload(v.to_payload()).pruned == v.pruned
        assert "pruned" in a.explain()[0] or True  # explain stays usable
    finally:
        _REGISTRY.pop("rogue_vt", None)
        _REGISTRY.pop("boom_vt", None)


# ---------------------------------------------------------------------------
# frontend threading: Program.verify() and the lint CLI
# ---------------------------------------------------------------------------


def test_program_verify_reports():
    import repro
    net = Chain(3)
    ex = {"x": jax.ShapeDtypeStruct((8, D), jnp.float32)}
    prog = repro.api.compile(net, policy="sequential", example_inputs=ex,
                             verify="strict")
    params = net.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
    prog(params, {"x": x})
    rep = prog.verify()
    assert rep.ok
    labels = [lbl for lbl, _ in prog.verify_reports()]
    assert labels and all("graph/" in lbl for lbl in labels)


def test_lint_cli_smoke(capsys):
    from repro.lint import lint_arch, main
    rows = lint_arch("transformer", strategies=["sequential"],
                     phases=("prefill",))
    assert rows
    assert all(rep.ok for _, rep in rows)
    assert all(lbl.startswith("smollm-135m/sequential/prefill/")
               for lbl, _ in rows)
    assert main(["transformer", "--strategy", "sequential",
                 "--phase", "prefill"]) == 0
    assert main(["transformer", "--codes"]) == 0
    out = capsys.readouterr().out
    assert "error(s)" in out and "VFY003" in out
