"""Roofline analyzer tests: HLO parsing on real compiled modules +
synthetic fragments with known answers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import (analyze, collective_bytes,
                                computation_multipliers, parse_module)
from repro.roofline.model import roofline_terms, wire_bytes
from repro import hw


def test_dot_flops_exact():
    """jit a known matmul; the analyzer must count 2*M*N*K flops."""
    M, K, N = 64, 32, 48

    def f(a, b):
        return a @ b

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32)).compile().as_text()
    r = analyze(hlo)
    assert r["flops"] == 2 * M * N * K


def test_while_trip_count_multiplies():
    """A scan of 7 matmuls must count 7x the body's flops."""
    M = 32

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile().as_text()
    r = analyze(hlo)
    assert r["flops"] == 7 * 2 * M * M * M


def test_collective_bytes_psum():
    import os
    # single-device psum lowers away; use a synthetic fragment instead
    hlo = """\
HloModule test, is_scheduled=true

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  ROOT %ar = f32[16,128]{1,0} all-reduce(%p), to_apply=%add
}
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 16 * 128 * 4
    assert cb["total"] == 16 * 128 * 4


def test_collectives_inside_while_multiply():
    hlo = """\
HloModule test, is_scheduled=true

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond (t: (s32[], f32[8])) -> pred[] {
  %t = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (t2: (s32[], f32[8])) -> (s32[], f32[8]) {
  %t2 = (s32[], f32[8]) parameter(0)
  %i2 = s32[] get-tuple-element(%t2), index=0
  %x = f32[8] get-tuple-element(%t2), index=1
  %one = s32[] constant(1)
  %i3 = s32[] add(%i2, %one)
  %ag = f32[8]{0} all-gather(%x), dimensions={0}
  ROOT %out = (s32[], f32[8]) tuple(%i3, %ag)
}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%zero, %p)
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 5 * 8 * 4


def test_dus_fusion_charged_as_update():
    """In-place cache update inside a scan must cost ~2x the slice, not
    the whole buffer."""
    S, d = 1024, 64

    def f(cache, xs):
        def body(c, inp):
            x, i = inp
            return jax.lax.dynamic_update_slice(c, x[None], (i, 0)), None
        c, _ = jax.lax.scan(body, cache,
                            (xs, jnp.arange(4, dtype=jnp.int32)))
        return c

    hlo = jax.jit(f, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((S, d), jnp.float32),
        jax.ShapeDtypeStruct((4, d), jnp.float32)).compile().as_text()
    r = analyze(hlo)
    # full-buffer accounting would be >= 4 * S * d * 4 = 1 MiB; the
    # in-place model stays well under one buffer's size
    assert r["hbm_bytes"] < S * d * 4, r["hbm_bytes"]


def test_roofline_terms_math():
    rl = roofline_terms(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=1.97e12,                    # 10 ms of compute
        hlo_bytes=8.19e9,                     # 10 ms of HBM
        coll_payload={"all-reduce": 1e9, "total": 1e9},
        n_params=1e9, n_active=1e9, tokens=1e6, train=True, axis_size=16)
    assert abs(rl.t_compute - 0.01) < 1e-4
    assert abs(rl.t_memory - 0.01) < 1e-4
    want_wire = 1e9 * 2.0 * 15 / 16
    assert abs(rl.t_collective - want_wire / (4 * 50e9)) < 1e-6
    assert rl.bottleneck in ("compute", "memory", "collective")
    assert rl.t_bound == max(rl.t_compute, rl.t_memory, rl.t_collective)


def test_wire_bytes_ring_factors():
    w = wire_bytes({"all-reduce": 100, "all-gather": 100,
                    "all-to-all": 100}, axis_size=4)
    assert abs(w - (200 * 0.75 + 100 * 0.75 + 25 * 0.75)) < 1e-9
