"""Multi-device SPMD tests (subprocess isolation: each case forces its
own host-device count before importing jax, keeping the main test
session single-device as required)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(n, body, timeout=420):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax
        assert jax.device_count() == {n}
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src") + os.pathsep + REPO)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_tp_sharded_matches_single_device():
    """TP=4 forward under shard_map == tp=1 forward (same global math)."""
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.models.layers import MeshInfo
        from repro.models.base import build_forward
        from repro.core.strategies import get_strategy
        from repro.core.scheduler import ScheduleContext
        from repro.launch.sharding import (global_param_specs,
                                           global_batch_specs,
                                           shard_specs_of)
        import dataclasses

        cfg = dataclasses.replace(get_smoke_config("chatglm3-6b"),
                                  n_heads=4, n_kv=2, d_model=32, d_ff=64)
        mesh = jax.make_mesh((1, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        B, S = 2, 16

        # single-device reference
        m1 = build_model(cfg, MeshInfo(tp=1, dp=1))
        segs1, binputs1 = m1.build_segments("train", B, S)
        fwd1 = build_forward(segs1, get_strategy("sequential"),
                             ScheduleContext(local_batch=B, seq_len=S,
                                             phase="train"))
        p1 = m1._init_from_segments(segs1, jax.random.PRNGKey(0),
                                    global_=True)
        batch = {"ids": jax.random.randint(jax.random.PRNGKey(2),
                                           (B, S), 0, 100),
                 "labels": jax.random.randint(jax.random.PRNGKey(3),
                                              (B, S), 0, 100),
                 "positions": jnp.broadcast_to(
                     jnp.arange(S, dtype=jnp.int32), (B, S))}
        out1 = fwd1(p1, batch)
        want = float(jnp.sum(out1["loss_sum"]) / jnp.sum(out1["token_count"]))

        # TP=4 under shard_map, global params initialized identically
        m4 = build_model(cfg, MeshInfo(tp=4, dp=1))
        segs4, _ = m4.build_segments("train", B, S)
        fwd4 = build_forward(segs4, get_strategy("sequential"),
                             ScheduleContext(local_batch=B, seq_len=S,
                                             phase="train"))
        pg = m4._init_from_segments(segs4, jax.random.PRNGKey(0),
                                    global_=True)
        _, pshd = global_param_specs(m4, segs4, mesh)
        p_specs = shard_specs_of(pshd)

        def step(params, batch):
            out = fwd4(params, batch)
            return (jnp.sum(out["loss_sum"]),
                    jnp.sum(out["token_count"]))

        fm = jax.shard_map(step, mesh=mesh,
                           in_specs=(p_specs,
                                     {"ids": P(), "labels": P(),
                                      "positions": P()}),
                           out_specs=(P(), P()), check_vma=False)
        pg_dev = jax.device_put(pg, pshd)
        ls, cnt = jax.jit(fm)(pg_dev, batch)
        got = float(ls / cnt)
        # NOTE: tp=1 vs tp=4 differ in param INIT layout for sharded dims,
        # so exact equality needs identical global init: both used
        # global_=True from the same fold_in keys => identical tables.
        assert abs(got - want) < 5e-2 * max(abs(want), 1.0), (got, want)
        print("TP4 OK", got, want)
    """)


def test_moe_token_sharded_vs_replicated():
    """EP token-sharded (a2a) MoE == replicated (slice+psum) MoE."""
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import MoEConfig, ArchConfig
        from repro.models.moe import MoEBlock
        from repro.models.layers import MeshInfo
        cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                         n_heads=2, n_kv=2, d_ff=32, vocab=64,
                         moe=MoEConfig(n_experts=4, top_k=2,
                                       d_ff_expert=8, n_shared=1,
                                       capacity_factor=4.0))
        mesh = jax.make_mesh((4,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        minfo = MeshInfo(tp=4, dp=1)
        blk_ts = MoEBlock(cfg, minfo, token_sharded=True)
        blk_rp = MoEBlock(cfg, minfo, token_sharded=False)
        params = blk_ts.init(jax.random.PRNGKey(0), global_=True)
        params_rp = blk_rp.init(jax.random.PRNGKey(0), global_=True)
        # expert weights: global (V=4 experts total); token_sharded blocks
        # see the same expert set
        B, S, d = 2, 8, 16
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d),
                              jnp.bfloat16)

        def ts(params, x):
            # x arrives seq-sharded (B, S/4, d)
            return blk_ts.apply(params, x)

        def rp(params, x):
            return blk_rp.apply(params, x)

        from repro.launch.sharding import spec_to_p
        import jax.tree_util as jtu
        pspec_ts = jtu.tree_map(spec_to_p, blk_ts.param_pspecs(),
                                is_leaf=lambda v: isinstance(v, tuple))
        pspec_rp = jtu.tree_map(spec_to_p, blk_rp.param_pspecs(),
                                is_leaf=lambda v: isinstance(v, tuple))
        f_ts = jax.shard_map(ts, mesh=mesh,
                             in_specs=(pspec_ts, P(None, "model", None)),
                             out_specs=P(None, "model", None),
                             check_vma=False)
        f_rp = jax.shard_map(rp, mesh=mesh,
                             in_specs=(pspec_rp, P()), out_specs=P(),
                             check_vma=False)
        from jax.sharding import NamedSharding
        put = lambda t, s: jax.device_put(t, jtu.tree_map(
            lambda sp: NamedSharding(mesh, sp), s,
            is_leaf=lambda v: isinstance(v, P)))
        y_ts = jax.jit(f_ts)(put(params, pspec_ts),
                             jax.device_put(x, NamedSharding(
                                 mesh, P(None, "model", None))))
        y_rp = jax.jit(f_rp)(put(params_rp, pspec_rp), x)
        np.testing.assert_allclose(np.asarray(y_ts, np.float32),
                                   np.asarray(y_rp, np.float32),
                                   atol=3e-2, rtol=3e-2)
        print("MoE modes agree")
    """)


def test_tokenweave_fused_collective_4dev():
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops, ref
        mesh = jax.make_mesh((4,), ("model",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        B, S, d = 2, 16, 32
        y_parts = jax.random.normal(jax.random.PRNGKey(0), (4, B, S, d))
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
        g = jax.random.normal(jax.random.PRNGKey(2), (d,))

        def f(yp, x, g):
            return ops.fused_ar_add_rmsnorm(yp[0], x, g, axis="model")

        fm = jax.shard_map(f, mesh=mesh, in_specs=(P("model"), P(), P()),
                           out_specs=(P(), P()), check_vma=False)
        s, h = jax.jit(fm)(y_parts, x, g)
        s2, h2 = ref.fused_add_rmsnorm(x, y_parts.sum(0), g)
        np.testing.assert_allclose(s, s2, atol=1e-4)
        np.testing.assert_allclose(h, h2, atol=1e-4)
        print("tokenweave 4dev OK")
    """)


def test_pipeline_driver_4stages():
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        Ws = jnp.stack([jnp.eye(8) * (i + 1) for i in range(4)])
        mbs = jax.random.normal(jax.random.PRNGKey(0), (6, 3, 8))

        def f(ws, mb):
            return pipeline_apply(lambda w, x: x @ w, ws[0], mb, axis="pod")

        fm = jax.shard_map(f, mesh=mesh, in_specs=(P("pod"), P()),
                           out_specs=P("pod"), check_vma=False)
        out = jax.jit(fm)(Ws, mbs)
        np.testing.assert_allclose(out[18:24], mbs @ (jnp.eye(8) * 24.0),
                                   atol=1e-4)
        print("pipeline OK")
    """)


def test_grad_reduction_rules_dp():
    """DP=2: per-replica grads psum; loss normalized by global tokens."""
    run_devices(2, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.registry import build_model
        from repro.models.layers import MeshInfo
        from repro.core.strategies import get_strategy
        from repro.train import TrainStepConfig, build_train_step
        from repro.optim import AdamWConfig
        mesh = jax.make_mesh((2, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = get_smoke_config("smollm-135m")
        model = build_model(cfg, MeshInfo(tp=1, dp=2))
        B_loc, S = 2, 16
        step, segs, binputs, init_opt = build_train_step(
            model, get_strategy("sequential"), B_loc, S,
            TrainStepConfig(optimizer=AdamWConfig(lr=1e-3), remat=False,
                            warmup=1, total_steps=5))
        params = model._init_from_segments(segs, jax.random.PRNGKey(0))
        opt = init_opt(params)
        batch = {"ids": jax.random.randint(jax.random.PRNGKey(1),
                                           (2 * B_loc, S), 0, 100),
                 "labels": jax.random.randint(jax.random.PRNGKey(2),
                                              (2 * B_loc, S), 0, 100),
                 "positions": jnp.broadcast_to(
                     jnp.arange(S, dtype=jnp.int32), (2 * B_loc, S))}
        bspec = {"ids": P("data"), "labels": P("data"),
                 "positions": P("data")}
        fm = jax.shard_map(step, mesh=mesh,
                           in_specs=(P(), P(), bspec, P()),
                           out_specs=(P(), P(),
                                      {"loss": P(), "grad_norm": P(),
                                       "lr": P(), "tokens": P()}),
                           check_vma=False)
        p2, o2, m = jax.jit(fm)(params, opt, batch, jnp.int32(0))
        assert float(m["tokens"]) == 2 * B_loc * S
        # reference: single-device over the full batch
        step1, segs1, _, init_opt1 = build_train_step(
            build_model(cfg, MeshInfo(tp=1, dp=1)),
            get_strategy("sequential"), 2 * B_loc, S,
            TrainStepConfig(optimizer=AdamWConfig(lr=1e-3), remat=False,
                            warmup=1, total_steps=5))
        p1 = build_model(cfg, MeshInfo(tp=1, dp=1))._init_from_segments(
            segs1, jax.random.PRNGKey(0))
        o1 = init_opt1(p1)
        p1n, _, m1 = jax.jit(step1)(p1, o1, batch, jnp.int32(0))
        assert abs(float(m["loss"]) - float(m1["loss"])) < 1e-3
        # updated params agree (grad psum == full-batch grad)
        for a, b in zip(jax.tree_util.tree_leaves(p2),
                        jax.tree_util.tree_leaves(p1n)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3)
        print("DP grad reduction OK")
    """)


def test_fsdp_resident_decode_linear_matches_gathered():
    """DataShardedLinearOp (resident ZeRO decode path) == gather path."""
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        import jax.tree_util as jtu
        from repro.models.layers import (MeshInfo, ShardedLinear)
        from repro.launch.sharding import spec_to_p
        mesh = jax.make_mesh((4, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        d_in, d_out, B = 32, 16, 4
        x = jax.random.normal(jax.random.PRNGKey(0), (B, 1, d_in))

        w = jax.random.normal(jax.random.PRNGKey(1), (d_in, d_out))
        outs = {}
        for resident in (False, True):
            minfo = MeshInfo(tp=1, dp=4, fsdp=True, fsdp_resident=resident)
            lin = ShardedLinear(d_in, d_out, "proj", minfo,
                                dtype=jnp.float32)
            params = lin.init(jax.random.PRNGKey(1), global_=True)
            # identical weight in both storage layouts
            child = "lin" if resident else "gather"
            params = {child: {"w": w}}
            pspec = jtu.tree_map(spec_to_p, lin.param_pspecs(),
                                 is_leaf=lambda v: isinstance(v, tuple))
            f = jax.shard_map(lambda p, x: lin.apply(p, x), mesh=mesh,
                              in_specs=(pspec, P()), out_specs=P(),
                              check_vma=False)
            pd = jax.device_put(params, jtu.tree_map(
                lambda sp: NamedSharding(mesh, sp), pspec,
                is_leaf=lambda v: isinstance(v, P)))
            outs[resident] = np.asarray(jax.jit(f)(pd, x))
        np.testing.assert_allclose(outs[False], outs[True],
                                   atol=1e-5, rtol=1e-5)
        print("resident decode linear OK")
    """)


def test_ff_sharded_experts_match_dense_experts():
    """FFShardedExpertGEMM partials + psum == full expert FFN."""
    run_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        import jax.tree_util as jtu
        from repro.configs.base import MoEConfig
        from repro.models.moe import ExpertGEMMOp, FFShardedExpertGEMM
        from repro.models.layers import MeshInfo
        from repro.launch.sharding import spec_to_p
        mesh = jax.make_mesh((4, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        m = MoEConfig(n_experts=2, top_k=1, d_ff_expert=16)
        d = 8
        buf = jax.random.normal(jax.random.PRNGKey(0), (2, 4, d))

        dense = ExpertGEMMOp(d, m, MeshInfo(tp=1, dp=4), dtype=jnp.float32)
        pd = dense.init(jax.random.PRNGKey(1), global_=True)
        want = dense.apply(pd, buf)

        ff = FFShardedExpertGEMM(d, m, MeshInfo(tp=1, dp=4, fsdp=True),
                                 dtype=jnp.float32)
        pf = ff.init(jax.random.PRNGKey(1), global_=True)
        pspec = jtu.tree_map(spec_to_p, ff.param_pspecs(),
                             is_leaf=lambda v: isinstance(v, tuple))

        def f(p, x):
            return jax.lax.psum(ff.apply(p, x), "data")

        fm = jax.shard_map(f, mesh=mesh, in_specs=(pspec, P()),
                           out_specs=P(), check_vma=False)
        pdev = jax.device_put(pf, jtu.tree_map(
            lambda sp: NamedSharding(mesh, sp), pspec,
            is_leaf=lambda v: isinstance(v, P)))
        got = jax.jit(fm)(pdev, buf)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-4, rtol=1e-4)
        print("ff-sharded experts OK")
    """)


def test_decode_tier_steps_share_one_lowering_tp2():
    """build_global_decode_tiers under a tp=2 mesh: one canonical decode
    lowering, every further batch tier a PlanStore share — the launch
    layer's half of the tiered-serve story."""
    run_devices(2, """
        import jax
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.core import PlanStore
        from repro.core.strategies import get_strategy
        from repro.launch.steps import build_global_decode_tiers
        from repro.models.layers import MeshInfo
        from repro.models.registry import build_model

        mesh = jax.make_mesh((1, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        cfg = get_smoke_config("chatglm3-6b")
        model = build_model(cfg, MeshInfo(tp=2, dp=1))
        store = PlanStore()
        shape = ShapeConfig("decode_smoke", seq_len=32, global_batch=4,
                            kind="decode")
        tiers = build_global_decode_tiers(model, get_strategy("sequential"),
                                          shape, mesh, plan_store=store)
        assert set(tiers) == {1, 2, 4}, sorted(tiers)
        st = store.stats
        # first tier lowers each segment once; tiers 2 and 4 specialize
        assert st["misses"] == 3, st
        assert st["shares"] == 6, st
        # the derived-tier step must actually compile and keep its
        # tier-sized global batch
        fn, in_sdss, _, donate, _ = tiers[2]
        assert in_sdss[1]["ids"].shape == (2, 1), in_sdss[1]["ids"].shape
        jax.jit(fn).lower(*in_sdss).compile()
        print("decode tiers OK")
    """)
